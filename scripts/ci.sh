#!/usr/bin/env bash
# CI entry point: dev deps → tier-1 tests → quick benchmark smoke.
#
# Mirrors what the GitHub Actions workflow (.github/workflows/ci.yml)
# runs; keep the two in sync by having the workflow call this script.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev deps are optional (tests importorskip them); ignore install failures
# in hermetic/offline containers.
python -m pip install -r requirements-dev.txt 2>/dev/null \
  || echo "ci.sh: dev-dep install skipped (offline?)"

echo "=== tier-1 tests ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "=== benchmark smoke (quick scale) ==="
REPRO_BENCH_SCALE=quick PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run threshold_sensitivity

echo "=== async event engine smoke (2 virtual seconds) ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.sim.events.engine --horizon-ms 2000

echo "=== chaos smoke (fault injection: crash storm with retries) ==="
# A faulted edge_sim run must realize failures (nonzero retry totals in
# the per-policy fault table), and an all-inert FaultConfig must leave
# the scanned engine BITWISE identical to faults=None — the fault
# layer's gate-off contract, asserted end-to-end.
CHAOS_LOG="$(mktemp)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python examples/edge_sim.py --rounds 6 --clients 12 --topk 6 \
    --faults "crash=0.5,retries=2" | tee "$CHAOS_LOG" > /dev/null
python - "$CHAOS_LOG" <<'PY'
import sys
rows = [l.split() for l in open(sys.argv[1])
        if l.split() and l.split()[0] in ("fedfog", "fogfaas", "rcs")
        and len(l.split()) == 7]
assert rows, "chaos smoke: fault table missing from edge_sim output"
retries = sum(int(r[5]) for r in rows)
assert retries > 0, f"chaos smoke: crash storm produced no retries: {rows}"
print(f"chaos smoke: {retries} retries across {len(rows)} policies")
PY
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import numpy as np
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim.faults import FaultConfig

cfg = dict(task="emnist", num_clients=12, rounds=4, top_k=6, hidden=(16,))
h0 = FedFogSimulator(SimulatorConfig(**cfg, faults=None)).run_scanned()
h1 = FedFogSimulator(SimulatorConfig(**cfg, faults=FaultConfig())).run_scanned()
assert set(h0) == set(h1)
for k in h0:
    assert np.array_equal(np.asarray(h0[k]), np.asarray(h1[k])), k
print("chaos smoke: faults-off bitwise identity holds")
PY

echo "=== sharded delta-pipeline selftest (8 fake devices, gate matrix) ==="
# shard_map kernel == single-device kernel == jnp oracle, with exactly
# ONE client-crossing all-reduce per compiled case (exit 1 on any miss).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.kernels.delta_pipeline.sharded_selftest --devices 8

echo "=== fog-tier sharded selftest (8 fake devices, pod x client x zero) ==="
# Two-level edge -> fog -> cloud reduction over the same gate matrix:
# exactly ONE delta-sized all-reduce per tier (edge psum confined to a
# pod slice + fog psum across pods), per-tier contract asserted via the
# extended assert_inter_client_contract (exit 1 on any miss).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.kernels.delta_pipeline.fog_selftest --devices 8

echo "=== serving smoke (continuous batching: short trace, one decode executable) ==="
# A short Poisson trace through the slot-scheduled engine must complete
# every request, hold the slot-conservation invariant, and do it all on
# exactly TWO AOT executables (admit, decode) — the one-executable
# contract as slots churn mid-flight.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import jax
from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import (
    ContinuousBatchingEngine, EngineConfig, TraceConfig, make_trace,
)

cfg = get_reduced("llama3.2-1b", loss_chunk=0)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = ContinuousBatchingEngine(
    model, params,
    EngineConfig(slots=4, page_size=4, prompt_len=8, max_gen=6,
                 max_requests=16),
)
trace = make_trace(
    jax.random.PRNGKey(1),
    TraceConfig(n_requests=12, rate_per_s=300.0, prompt_len=8,
                min_gen=2, max_gen=6, slo_ms=8000.0),
    cfg,
)
rep = eng.serve(trace)
assert rep.completed == trace.n_requests, rep.counters
assert rep.n_compiles == {"admit": 1, "decode": 1}, rep.n_compiles
c = rep.counters  # conservation() already asserted inside serve()
assert c["arrived"] == c["completed"] + c["rejected"]
print(f"serving smoke: {rep.completed}/{rep.n_requests} completed, "
      f"{rep.tokens_generated} tokens in {rep.decode_steps} decode steps "
      f"on {sum(rep.n_compiles.values())} executables "
      f"(p95={rep.percentiles['p95']:.0f}ms)")
PY

echo "=== simulator perf gate (engines + serving vs BENCH_simulator.json) ==="
# Gate-only against the committed baseline (exit non-zero on a >25%
# per-row regression). The baseline is NOT rewritten on ordinary runs —
# re-basing every pass would let sub-threshold regressions compound
# silently. Re-record deliberately with REPRO_BENCH_RECORD=1 (e.g. when
# the workload definition changes or on a new machine class); skip the
# gate entirely with REPRO_BENCH_COMPARE=0.
BENCH_ARGS="--compare BENCH_simulator.json"
if [[ "${REPRO_BENCH_RECORD:-0}" == 1 || ! -f BENCH_simulator.json ]]; then
  BENCH_ARGS="--json BENCH_simulator.json"
elif [[ "${REPRO_BENCH_COMPARE:-1}" != 1 ]]; then
  BENCH_ARGS=""
fi
# The cold pass populates a persistent compile cache
# (REPRO_COMPILE_CACHE_DIR) that the warm pass below — a FRESH process —
# must hit: serialized sweep executables make the second process skip
# tracing and XLA compilation entirely (n_compiles=0). Bench history
# (benchmarks.history) is pointed at a temp file so a CI smoke never
# pollutes the real BENCH_history.jsonl trajectory.
CACHE_DIR="${REPRO_COMPILE_CACHE_DIR:-$(mktemp -d)}"
HIST_FILE="$(mktemp)"
REPRO_BENCH_HISTORY="$HIST_FILE" REPRO_COMPILE_CACHE_DIR="$CACHE_DIR" \
  REPRO_BENCH_SCALE=quick PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run simulator_engine serving $BENCH_ARGS

echo "=== warm-start pass (fresh process, persistent cache at $CACHE_DIR) ==="
WARM_LOG="$(mktemp)"
REPRO_BENCH_WARM=1 REPRO_COMPILE_CACHE_DIR="$CACHE_DIR" \
  REPRO_BENCH_SCALE=quick PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run simulator_engine | tee "$WARM_LOG"
for row in sweep_warm async_events_warm; do
  grep "simulator_engine/$row" "$WARM_LOG" | grep -q "n_compiles=0" || {
    echo "ci.sh: warm pass MISSED the persistent compile cache ($row)"
    exit 1
  }
done

echo "=== observability smoke (in-scan tap streams rows mid-run) ==="
# A short scanned run with a JSONL tracker must produce streamed per-
# round rows (the io_callback taps fire DURING the compiled scan), and
# in-file order must show streamed rows BEFORE each policy's summary
# row — proof the rows appeared mid-run, not in a final flush.
TRACK_FILE="$(mktemp)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python examples/edge_sim.py --rounds 10 --clients 12 --topk 6 \
    --track "jsonl:$TRACK_FILE" --track-every 3 > /dev/null
python - "$TRACK_FILE" <<'PY'
import json, sys
rows = [json.loads(l) for l in open(sys.argv[1])]
streamed = [r for r in rows if r.get("event") == "round"]
summaries = [i for i, r in enumerate(rows) if r.get("summary")]
assert len(streamed) >= 9, f"expected >=9 streamed rows, got {len(streamed)}"
assert summaries, "expected tracker summary rows"
first_summary = summaries[0]
n_before = sum(1 for i, r in enumerate(rows)
               if i < first_summary and r.get("event") == "round")
assert n_before >= 3, "streamed rows must precede the first summary"
print(f"observability smoke: {len(streamed)} streamed rows, "
      f"{len(summaries)} summaries, {n_before} rows before first summary")
PY

echo "=== bench history trajectory (temp file from the cold pass) ==="
REPRO_BENCH_HISTORY="$HIST_FILE" PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.history --table

echo "=== dryrun smoke (1 reduced cell on the 512-fake-device mesh) ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k \
    --reduced --limit 1 --force --out "$(mktemp -d)/dryrun"

echo "ci.sh: OK"
