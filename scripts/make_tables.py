"""Generate the EXPERIMENTS.md dry-run + roofline markdown tables from
results/dryrun/*.json (and the §Perf comparison rows from results/perf/).

    PYTHONPATH=src python scripts/make_tables.py > results/tables.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import analyze_cell, model_flops_total  # noqa: E402
from repro.configs import ARCH_IDS  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "../results/dryrun")
PERF = os.path.join(os.path.dirname(__file__), "../results/perf")


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b:.0f}"


def dryrun_table(mesh):
    title = {"single": "single pod (16×16, 256 chips)", "multi": "multi-pod (2×16×16, 512 chips)"}[mesh]
    print(f"\n### Dry-run matrix — {title}\n")
    print("| arch | shape | status | compile_s | temp GB/dev | dot-FLOPs/dev |"
          " coll bytes/dev | plan (C×zero×model) |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = os.path.join(DRY, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                print(f"| {arch} | {shape} | MISSING | | | | | |")
                continue
            r = load(p)
            if r["status"] == "SKIP":
                print(f"| {arch} | {shape} | SKIP — {r['skip_reason'][:45]} |"
                      " | | | | |")
                continue
            plan = r.get("plan", {})
            plan_s = (f"{plan.get('slots','?')}×{plan.get('zero','?')}×"
                      f"{'·'.join(map(str, plan.get('model_split', [])))}"
                      f"{'F' if plan.get('fsdp') else ''}")
            print(
                f"| {arch} | {shape} | {r['status']} | {r.get('compile_s','')} |"
                f" {r.get('memory',{}).get('temp_size_in_bytes',0)/1e9:.1f} |"
                f" {fmt_bytes(r.get('dot_flops',0))} |"
                f" {fmt_bytes(r.get('collective_total',0))} | {plan_s} |"
            )


def roofline_table():
    print("\n### Roofline — single pod (16×16, 256 chips, v5e constants)\n")
    print("| arch | shape | compute s | memory s (out-only) | collective s |"
          " dominant | MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = os.path.join(DRY, f"{arch}__{shape}__single.json")
            if not os.path.exists(p):
                continue
            r = load(p)
            if r["status"] != "OK":
                print(f"| {arch} | {shape} | SKIP | | | | | | |")
                continue
            # prefer the outputs-only memory metric when present
            if "hbm_bytes_out" in r:
                r = dict(r)
                r["hbm_bytes"] = r["hbm_bytes_out"]
            a = analyze_cell(r)
            print(
                f"| {arch} | {shape} | {a['t_compute']:.3g} |"
                f" {a['t_memory']:.3g} | {a['t_collective']:.3g} |"
                f" {a['dominant']} | {a['model_flops']:.2e} |"
                f" {a['useful_ratio']:.3f} | {a['roofline_fraction']:.4f} |"
            )


def perf_rows():
    if not os.path.isdir(PERF):
        return
    print("\n### §Perf variant measurements (hillclimb runs)\n")
    print("| variant | status | temp GB/dev | dot-FLOPs/dev | coll bytes/dev |"
          " all-to-all | all-reduce | all-gather |")
    print("|---|---|---|---|---|---|---|---|")
    for d in sorted(glob.glob(os.path.join(PERF, "*"))):
        for p in sorted(glob.glob(os.path.join(d, "*.json"))):
            r = load(p)
            cb = r.get("collective_bytes", {})
            print(
                f"| {os.path.basename(d)} | {r['status']} |"
                f" {r.get('memory',{}).get('temp_size_in_bytes',0)/1e9:.1f} |"
                f" {fmt_bytes(r.get('dot_flops',0))} |"
                f" {fmt_bytes(r.get('collective_total',0))} |"
                f" {fmt_bytes(cb.get('all-to-all',0))} |"
                f" {fmt_bytes(cb.get('all-reduce',0))} |"
                f" {fmt_bytes(cb.get('all-gather',0))} |"
            )


if __name__ == "__main__":
    dryrun_table("single")
    dryrun_table("multi")
    roofline_table()
    perf_rows()
