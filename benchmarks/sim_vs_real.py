"""Paper Tables VII/VIII: simulator vs real execution.

The paper validates its DES against a 16-node Raspberry Pi cluster
(deviation <8% latency, <5.4% energy). Without Pis, the honest analogue on
this host: the DES *predicts* round latency from device/network constants;
the "real" system is the actual federated round EXECUTED on CPU with wall
clocks. We calibrate the DES compute constant on the smallest client count
(as the paper calibrates to its hardware), then report deviation at the
larger scales — testing whether the simulator extrapolates, exactly like
Table VIII's 8/16/32-client sweep.

Sweep-native since PR 3: the DES predictions come from one multi-seed
``run_sweep`` (client counts as grid points), so the predicted latency is
a seed-averaged quantity rather than a single trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import FedFogSimulator, SimulatorConfig

SIZES = (8, 16, 32)
ROUNDS = 4  # enough to reach warm-round latency


def _real_round_ms(sim: FedFogSimulator, n: int) -> float:
    """Wall-clock of actually executing one synchronous round's client
    training sequentially (edge devices run in parallel; the synchronous
    round is bounded by the slowest = here, mean per-client × 1 under
    homogeneous CPU — we time per-client work)."""
    params = sim.params
    key = jax.random.PRNGKey(0)

    def one_client(cid):
        return sim._client_update(
            sim.data_cfg, params, jnp.int32(cid), jnp.int32(1), key,
            jnp.zeros((), bool),
        )

    fn = jax.jit(one_client)
    jax.block_until_ready(fn(0))  # compile
    t0 = time.time()
    for cid in range(min(n, 8)):  # sample of clients
        jax.block_until_ready(fn(cid))
    per_client_ms = (time.time() - t0) / min(n, 8) * 1e3
    return per_client_ms


def run() -> list[Row]:
    p = preset()
    base = SimulatorConfig(task="emnist", num_clients=8, rounds=ROUNDS,
                           top_k=8, seed=0)
    # DES predictions: all sizes × seeds as compiled sweep programs.
    res, _ = timed_sweep(
        base, seeds=range(p["seeds"]),
        cases=[{"num_clients": n, "top_k": n} for n in SIZES],
        rounds=ROUNDS,
    )
    lat = res.metric("round_latency_ms")  # (G, S, R)
    sims = {n: float(lat[g, :, -1].mean()) for g, n in enumerate(SIZES)}

    rows = []
    reals = {}
    for n in SIZES:
        sim = FedFogSimulator(
            SimulatorConfig(task="emnist", num_clients=n, rounds=ROUNDS,
                            top_k=n, seed=0)
        )
        reals[n] = _real_round_ms(sim, n)
    # calibrate on the smallest size (paper: calibrate constants to hardware)
    scale = sims[SIZES[0]] / max(reals[SIZES[0]], 1e-9)
    devs = {}
    for g, n in enumerate(SIZES):
        predicted = sims[n]
        measured = reals[n] * scale
        devs[n] = abs(predicted - measured) / max(measured, 1e-9)
        rows.append(
            Row(
                f"tableVIII/N{n}",
                reals[n] * 1e3,
                fmt(
                    sim_latency_ms=predicted,
                    sim_latency_ci95=float(
                        1.96 * lat[g, :, -1].std(ddof=1)
                        / np.sqrt(lat.shape[1])
                    ) if lat.shape[1] > 1 else float("nan"),
                    real_calibrated_ms=measured,
                    deviation=devs[n],
                    seeds=p["seeds"],
                ),
            )
        )
    rows.append(
        Row(
            "tableVIII/summary",
            0.0,
            fmt(
                max_deviation=max(devs.values()),
                paper_deviation_bound=0.08,
            ),
        )
    )
    return rows
