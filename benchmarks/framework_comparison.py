"""Paper Fig. 5/6: FedFog vs FogFaaS vs Vanilla FL vs RCS on both tasks.

Reported per framework: final accuracy (mean ± 95% CI over seeds), mean
round latency, total energy. Paper claims: FedFog lowest latency, 20-30%
less energy, highest accuracy.

Sweep-native since PR 3: per task, ONE compiled program per policy runs
the whole seed batch (vmap over seeds of the scanned engine).
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig

POLICIES = ("fedfog", "fogfaas", "vanilla", "rcs")


def run() -> list[Row]:
    p = preset()
    rows = []
    for task in ("emnist", "har"):
        cfg = SimulatorConfig(
            task=task, num_clients=p["clients"], rounds=p["rounds"],
            top_k=p["topk"], seed=0,
        )
        res, uspc = timed_sweep(
            cfg, seeds=range(p["seeds"]),
            axes={"policy": list(POLICIES)},
        )
        acc_mean, acc_ci = res.mean_ci("accuracy")
        lat_mean, _ = res.mean_std("round_latency_ms", reduce="mean")
        en_mean, _ = res.mean_std("energy_j", reduce="sum")
        cold_mean, _ = res.mean_std("cold_starts", reduce="sum")
        stats = {}
        for g, policy in enumerate(POLICIES):
            stats[policy] = dict(
                acc=float(acc_mean[g, -1]),
                lat=float(lat_mean[g]),
                en=float(en_mean[g]),
            )
            rows.append(
                Row(
                    f"fig5/{task}/{policy}",
                    uspc,
                    fmt(
                        acc=stats[policy]["acc"],
                        acc_ci95=float(acc_ci[g, -1]),
                        latency_ms=stats[policy]["lat"],
                        energy_j=stats[policy]["en"],
                        cold=float(cold_mean[g]),
                        seeds=p["seeds"],
                    ),
                )
            )
        fed = stats["fedfog"]
        others_lat = min(m["lat"] for k, m in stats.items() if k != "fedfog")
        others_en = min(m["en"] for k, m in stats.items() if k != "fedfog")
        rows.append(
            Row(
                f"fig5/{task}/summary",
                0.0,
                fmt(
                    fedfog_lowest_latency=int(fed["lat"] <= others_lat),
                    energy_saving_vs_best_other=1 - fed["en"] / others_en,
                ),
            )
        )
    return rows
