"""Paper Fig. 5/6: FedFog vs FogFaaS vs Vanilla FL vs RCS on both tasks.

Reported per framework: final accuracy, mean round latency, total energy.
Paper claims: FedFog lowest latency, 20-30% less energy, highest accuracy.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_rounds
from repro.fl.simulator import FedFogSimulator, SimulatorConfig

POLICIES = ("fedfog", "fogfaas", "vanilla", "rcs")


def run() -> list[Row]:
    p = preset()
    rows = []
    for task in ("emnist", "har"):
        metrics = {}
        for policy in POLICIES:
            sim = FedFogSimulator(
                SimulatorConfig(
                    task=task, num_clients=p["clients"], rounds=p["rounds"],
                    top_k=p["topk"], policy=policy, seed=0,
                )
            )
            h, uspc = timed_rounds(sim, p["rounds"])
            metrics[policy] = h
            rows.append(
                Row(
                    f"fig5/{task}/{policy}",
                    uspc,
                    fmt(
                        acc=h["final_accuracy"],
                        latency_ms=h["mean_latency_ms"],
                        energy_j=h["total_energy_j"],
                        cold=h["total_cold_starts"],
                    ),
                )
            )
        fed = metrics["fedfog"]
        others_lat = min(m["mean_latency_ms"] for k, m in metrics.items() if k != "fedfog")
        others_en = min(m["total_energy_j"] for k, m in metrics.items() if k != "fedfog")
        rows.append(
            Row(
                f"fig5/{task}/summary",
                0.0,
                fmt(
                    fedfog_lowest_latency=int(fed["mean_latency_ms"] <= others_lat),
                    energy_saving_vs_best_other=1 - fed["total_energy_j"] / others_en,
                ),
            )
        )
    return rows
