"""Benchmark harness — one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the repo contract. Scale with
REPRO_BENCH_SCALE=quick|default|full. Select suites with
``python -m benchmarks.run [suite ...]``. ``--json out.json`` additionally
records the rows (plus scale/timings) as JSON — used by scripts/ci.sh to
keep a ``BENCH_simulator.json`` perf baseline across PRs.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

SUITES = [
    "threshold_sensitivity",  # Table II
    "drift_recovery",  # Table IV
    "robustness",  # Table V
    "ablation",  # Table VI
    "framework_comparison",  # Fig 5/6
    "scalability",  # Fig 8/9
    "orchestration",  # Table IX / Fig 12
    "pareto",  # Fig 2
    "privacy_tradeoff",  # Fig 3
    "hyperparam_sensitivity",  # Fig 10
    "sim_vs_real",  # Tables VII/VIII
    "async_vs_sync",  # event-driven engine: async rules vs round barrier
    "simulator_engine",  # scanned/sweep/async vs looped engine throughput
    "dryrun_sharding",  # dist layer: compile time + collective census
    "kernels_bench",
    "roofline",  # §Roofline (reads results/dryrun)
]


def main() -> None:
    import importlib

    argv = list(sys.argv[1:])
    json_out = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_out = argv[i + 1]
        except IndexError:
            sys.exit("--json requires an output path")
        del argv[i : i + 2]

    wanted = argv or SUITES
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for suite in wanted:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{suite}")
            for row in mod.run():
                print(row.csv(), flush=True)
                records.append(
                    {
                        "suite": suite,
                        "name": row.name,
                        "us_per_call": row.us_per_call,
                        "derived": row.derived,
                    }
                )
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{suite}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            records.append({"suite": suite, "name": f"{suite}/ERROR",
                            "error": f"{type(e).__name__}:{e}"})
        print(
            f"# {suite} done in {time.time() - t0:.1f}s", file=sys.stderr, flush=True
        )
        if records and "wall_s" not in records[-1]:
            records[-1]["wall_s"] = round(time.time() - t0, 2)
    if json_out:
        payload = {
            "scale": os.environ.get("REPRO_BENCH_SCALE", "default"),
            "suites": wanted,
            "failures": failures,
            "rows": records,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {json_out}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
