"""Benchmark harness — one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the repo contract. Scale with
REPRO_BENCH_SCALE=quick|default|full. Select suites with
``python -m benchmarks.run [suite ...]``.
"""
from __future__ import annotations

import sys
import time
import traceback

SUITES = [
    "threshold_sensitivity",  # Table II
    "drift_recovery",  # Table IV
    "robustness",  # Table V
    "ablation",  # Table VI
    "framework_comparison",  # Fig 5/6
    "scalability",  # Fig 8/9
    "orchestration",  # Table IX / Fig 12
    "pareto",  # Fig 2
    "privacy_tradeoff",  # Fig 3
    "hyperparam_sensitivity",  # Fig 10
    "sim_vs_real",  # Tables VII/VIII
    "simulator_engine",  # scanned/sweep vs looped engine throughput
    "dryrun_sharding",  # dist layer: compile time + collective census
    "kernels_bench",
    "roofline",  # §Roofline (reads results/dryrun)
]


def main() -> None:
    import importlib

    wanted = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    failures = 0
    for suite in wanted:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{suite}")
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{suite}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(
            f"# {suite} done in {time.time() - t0:.1f}s", file=sys.stderr, flush=True
        )
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
