"""Benchmark harness — one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the repo contract. Scale with
REPRO_BENCH_SCALE=quick|default|full. Select suites with
``python -m benchmarks.run [suite ...]``. ``--json out.json`` additionally
records the rows (plus scale/timings) as JSON — used by scripts/ci.sh to
keep a ``BENCH_simulator.json`` perf baseline across PRs.

``--compare baseline.json`` prints per-row ``us_per_call`` deltas vs a
previously recorded baseline and exits non-zero when any row regresses
more than the tolerance (default 25%, override with
``--compare-tolerance PCT``). Baselines are machine-specific: compare
against numbers recorded on the same class of machine, and re-record
with ``--json`` when the workload definition changes.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

SUITES = [
    "threshold_sensitivity",  # Table II
    "drift_recovery",  # Table IV
    "robustness",  # Table V
    "ablation",  # Table VI
    "framework_comparison",  # Fig 5/6
    "scalability",  # Fig 8/9
    "orchestration",  # Table IX / Fig 12
    "pareto",  # Fig 2
    "privacy_tradeoff",  # Fig 3
    "hyperparam_sensitivity",  # Fig 10
    "sim_vs_real",  # Tables VII/VIII
    "async_vs_sync",  # event-driven engine: async rules vs round barrier
    "robustness_faults",  # fault & recovery: crash grid, deadline, failover
    "simulator_engine",  # scanned/sweep/async vs looped engine throughput
    "serving",  # continuous batching vs sequential per-request oracle
    "dryrun_sharding",  # dist layer: compile time + collective census
    "kernels_bench",
    "roofline",  # §Roofline (reads results/dryrun)
]


def compare_to_baseline(records, baseline_path, tolerance_pct=25.0) -> int:
    """Print per-row deltas vs a recorded baseline; return the number of
    rows that regressed (slowed down) by more than ``tolerance_pct``.

    Rows are matched by ``name``. The compare is tolerant of shape drift
    in the row set — only SHARED rows can regress:

      * current rows with no baseline entry print ``NEW``;
      * baseline rows the current run did not produce (a renamed or
        removed row in a suite that DID run) warn and are skipped;
      * baseline rows belonging to suites that were not part of this run
        at all (a subset invocation) are ignored silently;
      * zero-baseline rows (summary rows) are skipped — their data lives
        in ``derived``.
    """
    with open(baseline_path) as f:
        base_rows = {
            r["name"]: r for r in json.load(f).get("rows", [])
            if "us_per_call" in r
        }
    run_suites = {rec.get("suite") for rec in records}
    regressions = 0
    print(f"# compare vs {baseline_path} (tolerance {tolerance_pct:.0f}%)")
    for rec in records:
        name = rec.get("name")
        if "us_per_call" not in rec:
            continue
        base = base_rows.pop(name, None)
        if base is None:
            # A row the baseline file predates (e.g. a freshly added
            # benchmark): informational, NOT a regression. It gains a
            # baseline the next time the file is re-recorded with
            # REPRO_BENCH_RECORD=1.
            print(
                f"{name}: NEW (no baseline row — not a regression; "
                f"re-record with REPRO_BENCH_RECORD=1 to baseline it)"
            )
            continue
        old, new = base["us_per_call"], rec["us_per_call"]
        if old <= 0.0:
            continue  # summary rows carry their data in `derived`
        delta = (new - old) / old * 100.0
        flag = ""
        if delta > tolerance_pct:
            flag = "  << REGRESSION"
            regressions += 1
        print(f"{name}: {old:.0f} -> {new:.0f} us/call ({delta:+.1f}%){flag}")
    for name, base in base_rows.items():
        if base.get("suite") not in run_suites:
            continue  # suite not part of this invocation: not comparable
        print(
            f"{name}: skipped (baseline row not produced by this run — "
            f"renamed or removed? re-record with REPRO_BENCH_RECORD=1)"
        )
    return regressions


def main() -> None:
    import importlib

    argv = list(sys.argv[1:])

    def take_flag(flag):
        if flag not in argv:
            return None
        i = argv.index(flag)
        try:
            value = argv[i + 1]
        except IndexError:
            sys.exit(f"{flag} requires an argument")
        del argv[i : i + 2]
        return value

    json_out = take_flag("--json")
    compare_path = take_flag("--compare")
    tolerance = float(take_flag("--compare-tolerance") or 25.0)

    wanted = argv or SUITES
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for suite in wanted:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{suite}")
            for row in mod.run():
                print(row.csv(), flush=True)
                records.append(
                    {
                        "suite": suite,
                        "name": row.name,
                        "us_per_call": row.us_per_call,
                        "derived": row.derived,
                    }
                )
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{suite}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            records.append({"suite": suite, "name": f"{suite}/ERROR",
                            "error": f"{type(e).__name__}:{e}"})
        print(
            f"# {suite} done in {time.time() - t0:.1f}s", file=sys.stderr, flush=True
        )
        if records and "wall_s" not in records[-1]:
            records[-1]["wall_s"] = round(time.time() - t0, 2)
    regressions = 0
    if compare_path:
        # Compare BEFORE --json possibly rewrites the same baseline file.
        regressions = compare_to_baseline(records, compare_path, tolerance)
    if json_out and regressions:
        # Never replace a baseline with the run that just failed against
        # it — that would reset the perf ratchet to the regressed numbers.
        print(
            f"# NOT writing {json_out}: run regressed vs {compare_path}",
            file=sys.stderr,
        )
    payload = {
        "scale": os.environ.get("REPRO_BENCH_SCALE", "default"),
        "suites": wanted,
        "failures": failures,
        "rows": records,
    }
    if json_out and not regressions:
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {json_out}", file=sys.stderr)
    if json_out or os.environ.get("REPRO_BENCH_HISTORY"):
        # Longitudinal record: the snapshot baseline above gets
        # overwritten on every re-record; the history file keeps every
        # run (including gate-only --compare runs, when
        # REPRO_BENCH_HISTORY points somewhere) so `python -m
        # benchmarks.history --table` shows the per-row trajectory
        # across PRs.
        from benchmarks.history import append_record

        hist = append_record(payload)
        print(f"# appended history entry to {hist}", file=sys.stderr)
    if regressions:
        print(
            f"# {regressions} row(s) regressed > {tolerance:.0f}%",
            file=sys.stderr,
        )
    if failures or regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
