"""Paper Table V: robustness under adversarial conditions.

Five settings: clean, label-flip (20%), Gaussian-noise updates (20%),
dropout (20%), model replacement (single client). Paper's ordering of
degradation severity: model_replacement > label_flip > noise > dropout.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_rounds
from repro.fl.simulator import FedFogSimulator, SimulatorConfig

ATTACKS = [
    ("clean", "none", 0.0),
    ("label_flip", "label_flip", 0.20),
    ("noise", "noise", 0.20),
    ("dropout", "dropout", 0.20),
    ("model_replacement", "model_replacement", 0.05),
]


def run() -> list[Row]:
    p = preset()
    rows, finals = [], {}
    for name, kind, frac in ATTACKS:
        sim = FedFogSimulator(
            SimulatorConfig(
                task="emnist",
                num_clients=p["clients"],
                rounds=p["rounds"],
                top_k=p["topk"],
                attack=kind,
                attack_fraction=frac,
                seed=0,
            )
        )
        h, uspc = timed_rounds(sim, p["rounds"])
        finals[name] = h["final_accuracy"]
        rows.append(Row(f"tableV/{name}", uspc, fmt(final_acc=h["final_accuracy"])))
    clean = finals["clean"]
    drops = {k: clean - v for k, v in finals.items() if k != "clean"}
    order = sorted(drops, key=lambda k: -drops[k])
    rows.append(
        Row(
            "tableV/summary",
            0.0,
            fmt(
                clean=clean,
                **{f"drop_{k}": v for k, v in drops.items()},
                severity_order=">".join(order),
            ),
        )
    )
    return rows
