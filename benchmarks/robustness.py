"""Paper Table V: robustness under adversarial conditions.

Five settings: clean, label-flip (20%), Gaussian-noise updates (20%),
dropout (20%), model replacement (single client). Paper's ordering of
degradation severity: model_replacement > label_flip > noise > dropout.

Runs on the sweep API: one compiled program per attack setting.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig

ATTACKS = [
    ("clean", "none", 0.0),
    ("label_flip", "label_flip", 0.20),
    ("noise", "noise", 0.20),
    ("dropout", "dropout", 0.20),
    ("model_replacement", "model_replacement", 0.05),
]


def run() -> list[Row]:
    p = preset()
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"],
    )
    res, uspc = timed_sweep(
        base,
        seeds=[0],
        cases=[
            {"attack": kind, "attack_fraction": frac}
            for _, kind, frac in ATTACKS
        ],
    )
    rows, finals = [], {}
    for i, (name, _, _) in enumerate(ATTACKS):
        finals[name] = float(res.final("accuracy")[i, 0])
        rows.append(Row(f"tableV/{name}", uspc, fmt(final_acc=finals[name])))
    clean = finals["clean"]
    drops = {k: clean - v for k, v in finals.items() if k != "clean"}
    order = sorted(drops, key=lambda k: -drops[k])
    rows.append(
        Row(
            "tableV/summary",
            0.0,
            fmt(
                clean=clean,
                **{f"drop_{k}": v for k, v in drops.items()},
                severity_order=">".join(order),
            ),
        )
    )
    return rows
