"""Paper Fig. 2: accuracy-latency Pareto frontier.

Sweeps the participation budget for FedFog / FogFaaS / RCS; each point is
(mean latency, final accuracy). Paper claim: FedFog dominates (higher
accuracy at lower latency).

Runs on the sweep API: the policy × budget grid via ``axes`` — one
compiled program per grid point.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig


def run() -> list[Row]:
    p = preset()
    budgets = [max(4, p["clients"] // 6), p["clients"] // 3, p["clients"] // 2]
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"]
    )
    res, uspc = timed_sweep(
        base,
        seeds=[0],
        axes={"policy": ["fedfog", "rcs", "fogfaas"], "top_k": budgets},
    )
    rows = []
    points: dict[str, list[tuple[float, float]]] = {}
    for g, ov in enumerate(res.configs):
        s = res.stats(g)
        lat = float(s["mean_latency_ms"][0])
        acc = float(s["final_accuracy"][0])
        points.setdefault(ov["policy"], []).append((lat, acc))
        rows.append(
            Row(
                f"fig2/{ov['policy']}/k{ov['top_k']}",
                uspc,
                fmt(latency_ms=lat, acc=acc),
            )
        )
    # dominance check: for each fedfog point, does any other policy point
    # have BOTH lower latency and higher accuracy?
    dominated = 0
    for lat, acc in points["fedfog"]:
        for pol in ("rcs", "fogfaas"):
            if any(l < lat and a > acc for l, a in points[pol]):
                dominated += 1
                break
    rows.append(
        Row(
            "fig2/summary",
            0.0,
            fmt(fedfog_points_dominated=dominated, of=len(points["fedfog"])),
        )
    )
    return rows
