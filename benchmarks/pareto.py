"""Paper Fig. 2: accuracy-latency Pareto frontier.

Sweeps the participation budget for FedFog / FogFaaS / RCS; each point is
(mean latency, final accuracy). Paper claim: FedFog dominates (higher
accuracy at lower latency).
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_rounds
from repro.fl.simulator import FedFogSimulator, SimulatorConfig


def run() -> list[Row]:
    p = preset()
    budgets = [max(4, p["clients"] // 6), p["clients"] // 3, p["clients"] // 2]
    rows = []
    points = {}
    for policy in ("fedfog", "rcs", "fogfaas"):
        for k in budgets:
            sim = FedFogSimulator(
                SimulatorConfig(
                    task="emnist", num_clients=p["clients"],
                    rounds=p["rounds"], top_k=k, policy=policy, seed=0,
                )
            )
            h, uspc = timed_rounds(sim, p["rounds"])
            points.setdefault(policy, []).append(
                (h["mean_latency_ms"], h["final_accuracy"])
            )
            rows.append(
                Row(
                    f"fig2/{policy}/k{k}",
                    uspc,
                    fmt(latency_ms=h["mean_latency_ms"], acc=h["final_accuracy"]),
                )
            )
    # dominance check: for each fedfog point, does any other policy point
    # have BOTH lower latency and higher accuracy?
    dominated = 0
    for lat, acc in points["fedfog"]:
        for pol in ("rcs", "fogfaas"):
            if any(l < lat and a > acc for l, a in points[pol]):
                dominated += 1
                break
    rows.append(
        Row(
            "fig2/summary",
            0.0,
            fmt(fedfog_points_dominated=dominated, of=len(points["fedfog"])),
        )
    )
    return rows
