"""Dryrun/sharding timing: compile time + collective counts per arch.

Spawns ``repro.dist.selftest`` subprocesses (the fake-device flag must be
set before jax initializes, so cells can't run in-process) that build the
mesh plan, jit one FedFog round with the full ShardingRules wiring on an
8-device host mesh, and report compile seconds plus the per-kind
collective census from ``analyze_hlo``. Tracks the perf trajectory of
the distribution layer itself: a regression in rule coverage shows up as
extra collectives; a compile-time regression shows up directly.

Scale: quick = 2 archs, default = 4, full = all 10.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Row, SCALE, fmt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCHES = {
    "quick": ["llama3.2-1b", "rwkv6-1.6b"],
    "default": ["llama3.2-1b", "mixtral-8x7b", "hymba-1.5b", "rwkv6-1.6b"],
    "full": [
        "qwen2.5-14b", "yi-9b", "gemma3-12b", "llama3.2-1b",
        "moonshot-v1-16b-a3b", "mixtral-8x7b", "seamless-m4t-medium",
        "hymba-1.5b", "rwkv6-1.6b", "internvl2-2b",
    ],
}


def _cell(arch: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dist.selftest", "--json", "--no-check",
         "--arch", arch, "--devices", "8"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"{arch}: selftest rc={proc.returncode}: {proc.stderr[-500:]}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run() -> list[Row]:
    rows = []
    for arch in ARCHES[SCALE]:
        res = _cell(arch)
        counts = res["collective_counts"]
        rows.append(
            Row(
                name=f"dryrun_sharding/{arch}",
                us_per_call=res["compile_s"] * 1e6,
                derived=fmt(
                    inter_client_ar=res["inter_client_all_reduces"],
                    all_reduce=counts.get("all-reduce", 0),
                    all_gather=counts.get("all-gather", 0),
                    all_to_all=counts.get("all-to-all", 0),
                    permute=counts.get("collective-permute", 0),
                    collective_mb=sum(res["collective_bytes"].values()) / 1e6,
                    ok=res["ok"],
                ),
            )
        )
    return rows
