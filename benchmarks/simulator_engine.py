"""Simulator engine throughput: seed-style Python loop vs scan-compiled
engine vs vmapped sweep.

Three ways to run the same S-seed × R-round × N-client experiment:

  looped : the seed repo's engine — a fresh ``FedFogSimulator`` per seed,
           one jitted dispatch per round, a ``float()`` host sync per
           metric per round, recompilation per simulator instance.
  scanned: ``run_scanned()`` per seed — whole run in one ``lax.scan``
           program, one device→host transfer per seed.
  sweep  : ``run_sweep()`` — ONE compiled program for the entire seed
           batch (vmap over seeds of the scanned engine).
  async  : ``run_sweep(engine="async")`` in the sync-equivalent cohort
           configuration — the event-driven engine (queue pops, dispatch/
           complete events, buffered aggregation) doing the same work, so
           its row is the event-machinery overhead AND an events/sec
           throughput number for the perf baseline (BENCH_simulator.json).

Wall-clock includes compilation — that is the honest end-to-end cost a
benchmark suite pays, and amortizing compilation across the seed batch is
precisely the sweep engine's advantage. Also reports the max absolute
accuracy-history deviation between engines as a correctness cross-check.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, SCALE, fmt, preset
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import run_sweep

N_SEEDS = {"quick": 2, "default": 4, "full": 8}


def run() -> list[Row]:
    import dataclasses

    p = preset()
    n_seeds = N_SEEDS[SCALE]
    rounds = p["rounds"]
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=rounds, top_k=p["topk"]
    )
    sim_rounds = n_seeds * rounds

    # --- seed-style Python loop (fresh sim + per-round dispatch/sync) -- #
    t0 = time.time()
    looped = [
        FedFogSimulator(dataclasses.replace(base, seed=s)).run(rounds)
        for s in range(n_seeds)
    ]
    t_loop = time.time() - t0

    # --- scan-compiled engine, still one sim per seed ------------------ #
    t0 = time.time()
    scanned = [
        FedFogSimulator(dataclasses.replace(base, seed=s)).run_scanned(rounds)
        for s in range(n_seeds)
    ]
    t_scan = time.time() - t0

    # --- vmapped sweep: the whole seed batch as one XLA program -------- #
    t0 = time.time()
    res = run_sweep(base, seeds=range(n_seeds), rounds=rounds)
    t_sweep = time.time() - t0

    # --- event-driven engine, sync-equivalent cohort config ------------ #
    from repro.sim.events import AsyncConfig

    t0 = time.time()
    res_async = run_sweep(
        base, seeds=range(n_seeds), rounds=rounds,
        engine="async", async_cfg=AsyncConfig(staleness_exponent=0.0),
    )
    t_async = time.time() - t0
    # one dispatch + its completions + the flush ≈ (topk+2) events/round
    sim_events = int((res_async.metric("valid") > 0).sum()) + n_seeds * rounds * (
        p["topk"] + 1
    )

    # correctness cross-check: all four engines tell the same story
    acc_loop = np.asarray([h["accuracy"] for h in looped])
    acc_scan = np.asarray([h["accuracy"] for h in scanned])
    acc_sweep = np.asarray(res.metric("accuracy")[0])
    acc_async = np.asarray(res_async.metric("accuracy")[0])[:, :rounds]
    dev_scan = float(np.abs(acc_loop - acc_scan).max())
    dev_sweep = float(np.abs(acc_loop - acc_sweep).max())
    dev_async = float(np.abs(acc_loop - acc_async).max())

    shape = fmt(seeds=n_seeds, rounds=rounds, clients=p["clients"])
    return [
        Row(
            "simulator_engine/looped",
            t_loop / sim_rounds * 1e6,
            f"wall_s={t_loop:.2f};{shape}",
        ),
        Row(
            "simulator_engine/scanned",
            t_scan / sim_rounds * 1e6,
            f"wall_s={t_scan:.2f};max_acc_dev={dev_scan:.2g};{shape}",
        ),
        Row(
            "simulator_engine/sweep",
            t_sweep / sim_rounds * 1e6,
            f"wall_s={t_sweep:.2f};max_acc_dev={dev_sweep:.2g};{shape}",
        ),
        Row(
            "simulator_engine/async_events",
            t_async / sim_rounds * 1e6,
            f"wall_s={t_async:.2f};max_acc_dev={dev_async:.2g};"
            f"events_per_sec={sim_events / max(t_async, 1e-9):.0f};{shape}",
        ),
        Row(
            "simulator_engine/summary",
            0.0,
            fmt(
                scanned_speedup_vs_loop=t_loop / max(t_scan, 1e-9),
                sweep_speedup_vs_loop=t_loop / max(t_sweep, 1e-9),
                async_overhead_vs_sweep=t_async / max(t_sweep, 1e-9),
            ),
        ),
    ]
