"""Simulator engine throughput: seed-style Python loop vs scan-compiled
engine vs compile-once grouped sweep vs coalesced async engine.

The workload is the paper's actual benchmark shape — a NUMERIC config
grid (G learning rates) × S seeds × R rounds — run four ways:

  looped : the seed repo's engine — a fresh ``FedFogSimulator`` per
           (grid point, seed), one jitted dispatch per round, a
           ``float()`` host sync per metric per round, recompilation per
           simulator instance (G·S compiles).
  scanned: ``run_scanned()`` per seed on the base config — whole run in
           one ``lax.scan`` program, one device→host transfer per seed
           (continuity row: same shape as the historical baseline).
  sweep  : ``run_sweep()`` with structural/numeric grouping — the G grid
           points share ONE compiled program vmapped over (G, S); the
           row's derived fields split wall time into trace/compile/
           execute via the AOT ``jit(...).lower(...).compile()`` path.
  async  : ``run_sweep(engine="async")`` in the sync-equivalent cohort
           configuration — the event-driven engine (coalesced batched
           event stepping) doing the base config's work. Two explicitly
           named throughput columns: ``events_per_sec_exec`` is computed
           on EXECUTE time (compile attributed separately — the honest
           steady-state throughput of the event machinery) and
           ``events_per_sec_wall`` keeps the cold-wall definition of the
           pre-coalescing baselines (whose ``events_per_sec`` was
           wall-based) — compare each only against its own definition.

Two additional WARM-START rows measure the persistent compile cache
(``REPRO_COMPILE_CACHE_DIR``; a temp dir is used when unset):

  sweep_warm / async_events_warm : the same sweep/async workloads
           replayed after clearing the IN-PROCESS cache, so every
           executable comes back through disk deserialization — the
           cost a second process running the same grid pays
           (``n_compiles=0``, wall → exec). ``REPRO_BENCH_WARM=1``
           emits ONLY these rows (no cold engines), which is how
           scripts/ci.sh's second pass asserts a fresh process actually
           warm-starts from the first pass's cache.

Wall-clock per row still includes compilation — that is the honest
end-to-end cost a cold benchmark suite pays; the compile_s/exec_s split
shows where it goes, and the compile-once cache is exactly what the
sweep row amortizes across the grid. Also reports the max absolute
accuracy-history deviation between engines as a correctness cross-check.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Row, SCALE, fmt, preset
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import clear_compile_cache, run_sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_SEEDS = {"quick": 2, "default": 4, "full": 8}
# Numeric grid: G points that share one structural signature, so the
# grouped sweep compiles ONCE while the naive loop re-traces per point.
LR_GRID = {"quick": [0.03, 0.04, 0.05, 0.06],
           "default": [0.03, 0.04, 0.05, 0.06],
           "full": [0.02, 0.03, 0.04, 0.05, 0.06]}


def run() -> list[Row]:
    p = preset()
    n_seeds = N_SEEDS[SCALE]
    rounds = p["rounds"]
    lrs = LR_GRID[SCALE]
    g = len(lrs)
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=rounds, top_k=p["topk"]
    )
    base_rounds = n_seeds * rounds  # single-config sim-rounds
    grid_rounds = g * base_rounds  # grid-workload sim-rounds

    # Persistent warm-start cache: honor the caller's directory (the CI
    # cold→warm double pass shares one), else a private temp dir so the
    # warm rows below still measure the disk round trip. A self-created
    # temp dir is torn back down afterwards — env var, the global jax
    # compilation-cache config and the directory itself — so suites
    # running after this one in the same harness process are untouched.
    own_tmp = None
    if not os.environ.get("REPRO_COMPILE_CACHE_DIR"):
        own_tmp = tempfile.mkdtemp(prefix="repro-compile-cache-")
        os.environ["REPRO_COMPILE_CACHE_DIR"] = own_tmp
    try:
        if os.environ.get("REPRO_BENCH_WARM", "0") == "1":
            return _warm_rows(base, lrs, n_seeds, rounds, p, grid_rounds)
        return _cold_and_warm_rows(base, lrs, n_seeds, rounds, p,
                                   grid_rounds, g)
    finally:
        if own_tmp is not None:
            import shutil

            from repro.sim.sweep import disable_xla_cache

            os.environ.pop("REPRO_COMPILE_CACHE_DIR", None)
            disable_xla_cache()
            shutil.rmtree(own_tmp, ignore_errors=True)


def _cold_and_warm_rows(
    base, lrs, n_seeds, rounds, p, grid_rounds, g
) -> list[Row]:
    import dataclasses

    base_rounds = n_seeds * rounds  # single-config sim-rounds

    # --- seed-style Python loop over the grid (fresh sim per run) ------ #
    t0 = time.time()
    looped = [
        [
            FedFogSimulator(
                dataclasses.replace(base, lr=lr, seed=s)
            ).run(rounds)
            for s in range(n_seeds)
        ]
        for lr in lrs
    ]
    t_loop = time.time() - t0

    # --- scan-compiled engine, one sim per seed (base config only) ----- #
    # AOT-compile the scan program ONCE and execute it per seed: the jit
    # dispatch caches are per-instance, so the old per-seed run_scanned()
    # loop recompiled for every simulator and the row's "speedup" mixed a
    # one-off compile into every per-round number (the recorded
    # scanned_speedup_vs_loop=0.82 artifact). compile_s / exec_s are now
    # attributed separately and the summary compares execute-to-execute.
    t0 = time.time()
    scan_exe = FedFogSimulator(
        dataclasses.replace(base, seed=0)
    ).aot_scanned(rounds)
    t_scan_compile = time.time() - t0
    t0 = time.time()
    scanned = [
        FedFogSimulator(dataclasses.replace(base, seed=s)).run_scanned_with(
            scan_exe, rounds
        )
        for s in range(n_seeds)
    ]
    t_scan_exec = time.time() - t0
    t_scan = t_scan_compile + t_scan_exec

    # --- grouped sweep: the whole grid × seed batch as ONE program ----- #
    tm: dict = {}
    t0 = time.time()
    res = run_sweep(
        base, seeds=range(n_seeds), axes={"lr": lrs}, rounds=rounds,
        timings=tm,
    )
    t_sweep = time.time() - t0

    # --- fault tax: the same grid through the ACTIVE fault gate -------- #
    # Rates are lifted numerics, so the faulted grid still compiles ONCE;
    # the row prices what the gated program (retry chains, counters,
    # quorum select) adds per sim-round over the fault-free sweep row.
    from repro.sim.faults import FaultConfig

    tm_f: dict = {}
    t0 = time.time()
    res_fault = run_sweep(
        base, seeds=range(n_seeds),
        cases=[
            {"lr": lr, "faults": FaultConfig(crash_rate=0.25, max_retries=1)}
            for lr in lrs
        ],
        rounds=rounds, timings=tm_f,
    )
    t_fault = time.time() - t0
    fault_retries = int(np.asarray(res_fault.history["fault_retries"]).sum())

    # --- event-driven engine, sync-equivalent cohort config ------------ #
    from repro.sim.events import AsyncConfig

    tm_a: dict = {}
    t0 = time.time()
    res_async = run_sweep(
        base, seeds=range(n_seeds), rounds=rounds,
        engine="async", async_cfg=AsyncConfig(staleness_exponent=0.0),
        timings=tm_a,
    )
    t_async = time.time() - t0
    # one dispatch + its completions + the flush ≈ (topk+2) events/round
    sim_events = int((res_async.metric("valid") > 0).sum()) + n_seeds * rounds * (
        p["topk"] + 1
    )
    ev_exec = sim_events / max(tm_a.get("exec_s", 0.0), 1e-9)
    ev_wall = sim_events / max(t_async, 1e-9)

    # correctness cross-check: all four engines tell the same story.
    # scanned/async run the BASE config, so its lr must be a grid point
    # or the deviation columns would compare different learning rates.
    assert base.lr in lrs, f"LR_GRID[{SCALE}] must contain base lr {base.lr}"
    acc_loop = np.asarray([[h["accuracy"] for h in seeds] for seeds in looped])
    base_g = lrs.index(base.lr)
    acc_scan = np.asarray([h["accuracy"] for h in scanned])
    acc_sweep = np.asarray(res.metric("accuracy"))
    acc_async = np.asarray(res_async.metric("accuracy")[0])[:, :rounds]
    dev_scan = float(np.abs(acc_loop[base_g] - acc_scan).max())
    dev_sweep = float(np.abs(acc_loop - acc_sweep).max())
    dev_async = float(np.abs(acc_loop[base_g] - acc_async).max())

    warm_rows = _warm_rows(
        base, lrs, n_seeds, rounds, p, grid_rounds,
        cold_acc=acc_sweep, cold_acc_async=np.asarray(
            res_async.metric("accuracy")
        ),
    ) + [
        _tracked_row(base, rounds, p, t_scan_exec / base_rounds * 1e6,
                     acc_scan),
        _sharded_row(lrs, rounds, p), _population_row(p),
    ]

    shape = fmt(grid=g, seeds=n_seeds, rounds=rounds, clients=p["clients"])
    return [
        Row(
            "simulator_engine/looped",
            t_loop / grid_rounds * 1e6,
            f"wall_s={t_loop:.2f};{shape}",
        ),
        Row(
            "simulator_engine/scanned",
            t_scan / base_rounds * 1e6,
            f"wall_s={t_scan:.2f};"
            f"compile_s={t_scan_compile:.2f};"
            f"exec_s={t_scan_exec:.2f};"
            f"max_acc_dev={dev_scan:.2g};"
            + fmt(seeds=n_seeds, rounds=rounds, clients=p["clients"]),
        ),
        Row(
            "simulator_engine/sweep",
            t_sweep / grid_rounds * 1e6,
            f"wall_s={t_sweep:.2f};"
            f"trace_s={tm.get('trace_s', 0.0):.2f};"
            f"compile_s={tm.get('compile_s', 0.0):.2f};"
            f"exec_s={tm.get('exec_s', 0.0):.2f};"
            f"n_compiles={tm.get('n_compiles', 0)};"
            f"cache_hits={tm.get('cache_hits', 0)};"
            f"max_acc_dev={dev_sweep:.2g};{shape}",
        ),
        Row(
            "simulator_engine/sweep_faulted",
            t_fault / grid_rounds * 1e6,
            f"wall_s={t_fault:.2f};"
            f"compile_s={tm_f.get('compile_s', 0.0):.2f};"
            f"exec_s={tm_f.get('exec_s', 0.0):.2f};"
            f"n_compiles={tm_f.get('n_compiles', 0)};"
            f"fault_tax={t_fault / max(t_sweep, 1e-9):.3f};"
            f"total_retries={fault_retries};{shape}",
        ),
        Row(
            "simulator_engine/async_events",
            t_async / base_rounds * 1e6,
            f"wall_s={t_async:.2f};"
            f"trace_s={tm_a.get('trace_s', 0.0):.2f};"
            f"compile_s={tm_a.get('compile_s', 0.0):.2f};"
            f"exec_s={tm_a.get('exec_s', 0.0):.2f};"
            f"max_acc_dev={dev_async:.2g};"
            f"events_per_sec_exec={ev_exec:.0f};"
            f"events_per_sec_wall={ev_wall:.1f};"
            + fmt(seeds=n_seeds, rounds=rounds, clients=p["clients"]),
        ),
        Row(
            "simulator_engine/summary",
            0.0,
            fmt(
                # per-sim-round ratios: the rows cover different workloads
                # (loop+sweep run the G-point grid, scanned+async the base
                # config), so raw wall ratios would not be like-for-like.
                # scanned speedup is EXECUTE-to-execute (the scan program
                # compiles once; folding that one-off into every per-round
                # number was the 0.82 artifact); _wall keeps the old
                # cold-wall definition for trend continuity.
                scanned_speedup_vs_loop=(t_loop / grid_rounds)
                / max(t_scan_exec / base_rounds, 1e-9),
                scanned_speedup_vs_loop_wall=(t_loop / grid_rounds)
                / max(t_scan / base_rounds, 1e-9),
                sweep_speedup_vs_loop=t_loop / max(t_sweep, 1e-9),
                async_overhead_vs_sweep=(t_async / base_rounds)
                / max(t_sweep / grid_rounds, 1e-9),
                # _exec = steady-state event throughput (compile is
                # attributed separately); _wall keeps the historical
                # cold-wall definition (the pre-coalescing baselines'
                # `events_per_sec` was wall-based) — never compare one
                # against the other.
                events_per_sec_exec=ev_exec,
                events_per_sec_wall=ev_wall,
            ),
        ),
    ] + warm_rows


def _tracked_row(base, rounds, p, scanned_exec_us, acc_scan) -> Row:
    """``scanned_tracked``: the scan engine with a live metric tap
    (JsonlTracker sink, decimation 10) — the observability tax. The tap
    is an ordered io_callback under a ``step % 10 == 0`` cond inside the
    compiled scan, so the WARM per-round cost must stay within a few
    percent of the untapped scanned row's execute time
    (``tracked_over_scanned_exec``; the <10% acceptance gate). The first
    call's compile is attributed separately, and the tapped history must
    match the untapped engine bitwise (``max_acc_dev``)."""
    import dataclasses

    from repro.obs import JsonlTracker, MetricTap

    import tempfile as _tf

    every = 10
    path = os.path.join(_tf.mkdtemp(prefix="repro-bench-track-"),
                        "rows.jsonl")
    with JsonlTracker(path) as tracker:
        tap = MetricTap(tracker, every=every, channel="round")
        sim = FedFogSimulator(
            dataclasses.replace(base, seed=0), tap=tap
        )
        t0 = time.time()
        h = sim.run_scanned(rounds)  # cold: traces + compiles the tap
        t_cold = time.time() - t0
        t0 = time.time()
        sim.run_scanned(rounds)  # warm: jit cache hit, exec + taps only
        t_warm = time.time() - t0
    dev = float(np.abs(np.asarray(h["accuracy"])
                       - np.asarray(acc_scan[0])).max())
    rows_streamed = sum(1 for _ in open(path))
    warm_us = t_warm / rounds * 1e6
    return Row(
        "simulator_engine/scanned_tracked",
        warm_us,
        f"wall_cold_s={t_cold:.2f};"
        f"tracked_over_scanned_exec="
        f"{warm_us / max(scanned_exec_us, 1e-9):.3f};"
        f"max_acc_dev={dev:.2g};"
        f"rows_streamed={rows_streamed};"
        + fmt(every=every, rounds=rounds, clients=p["clients"]),
    )


def _sharded_row(lrs, rounds, p) -> Row:
    """``sweep_sharded``: the grouped lr-grid sweep with its seed batch
    sharded across 8 fake CPU devices (``run_sweep(devices=8)``), via a
    subprocess worker (the fake-device flag must precede jax init). One
    seed per device, so the executable's seed axis is fully parallel."""
    n_seeds = 8
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    # the worker measures its own cold compile — don't warm-start it from
    # this process's persistent cache dir
    env.pop("REPRO_COMPILE_CACHE_DIR", None)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_sharded_worker",
         "--devices", "8", "--seeds", str(n_seeds),
         "--clients", str(p["clients"]), "--rounds", str(rounds),
         "--topk", str(p["topk"]), "--lrs", ",".join(map(str, lrs))],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sweep_sharded worker rc={proc.returncode}: {proc.stderr[-500:]}"
        )
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    return Row(
        "simulator_engine/sweep_sharded",
        res["wall_s"] / res["sim_rounds"] * 1e6,
        f"wall_s={res['wall_s']:.2f};"
        f"compile_s={res['compile_s']:.2f};"
        f"exec_s={res['exec_s']:.2f};"
        f"acc_mean={res['acc_mean']:.4g};"
        + fmt(devices=res["devices"], grid=len(lrs), seeds=n_seeds,
              rounds=rounds, clients=p["clients"]),
    )


def _peak_mem_mb(compiled) -> float | None:
    """Best-effort peak-HBM estimate from the AOT executable's
    ``memory_analysis()`` (argument + output + temp + generated code);
    None when the backend doesn't implement it."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    total = 0.0
    found = False
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if isinstance(v, (int, float)):
            total += float(v)
            found = True
    return round(total / 2**20, 1) if found else None


def _population_row(p) -> Row:
    """``simulator_engine/population``: the ISSUE 7 acceptance row — a
    1M-virtual-client population sampled down to a 64-client cohort per
    round must cost ~what today's dense 64-client run costs (the per-
    round work is cohort-sized; only O(M) telemetry/scheduler gathers
    and scatters see the population). Cohort/population are FIXED at
    64/1M across bench scales so the ratio is comparable everywhere;
    rounds follow the preset (capped) to bound wall time. Columns carry
    both us/round numbers, their ratio, the AOT executables' peak-memory
    estimates, and — attributed separately, like compile — the one-time
    state-build cost a fresh same-config instance pays (``init_ms``: the
    (M,) registries through the shared jitted init)."""
    import dataclasses

    cohort, population = 64, 1_000_000
    rounds = min(p["rounds"], 8)
    dense = SimulatorConfig(
        task="emnist", num_clients=cohort, rounds=rounds, top_k=p["topk"]
    )
    pop = dataclasses.replace(dense, population=population)

    def prepare(cfg):
        sim = FedFogSimulator(cfg)
        t0 = time.time()
        exe = sim.aot_scanned(rounds)
        compile_s = time.time() - t0
        h = sim.run_scanned_with(exe, rounds)  # warm (first dispatch);
        # also the accuracy sample — later reps advance the carried state.
        # One-time state build (the (M,) registries in population mode)
        # is attributed separately, like compile: a fresh same-config
        # instance reuses the shared jitted init executable.
        t0 = time.time()
        fresh = FedFogSimulator(cfg)
        jax.block_until_ready((fresh.env, fresh.telemetry))
        init_ms = (time.time() - t0) * 1e3
        return sim, exe, compile_s, init_ms, _peak_mem_mb(exe), h

    def timed(sim, exe):
        t0 = time.time()
        sim.run_scanned_with(exe, rounds)
        return (time.time() - t0) / rounds * 1e6

    d_sim, d_exe, dense_compile, dense_init, dense_mem, _ = prepare(dense)
    p_sim, p_exe, pop_compile, pop_init, pop_mem, h_pop = prepare(pop)
    # The ratio below is an acceptance gate; single runs on a shared
    # host jitter ±20% and conditions drift over the suite. Interleave
    # the reps so both configs see the same machine state, take best-of.
    dense_us = pop_us = float("inf")
    for _ in range(3):
        dense_us = min(dense_us, timed(d_sim, d_exe))
        pop_us = min(pop_us, timed(p_sim, p_exe))
    return Row(
        "simulator_engine/population",
        pop_us,
        fmt(
            dense_us_per_round=dense_us,
            pop_over_dense=pop_us / max(dense_us, 1e-9),
            peak_mem_mb=pop_mem if pop_mem is not None else "na",
            dense_peak_mem_mb=dense_mem if dense_mem is not None else "na",
            compile_s=pop_compile,
            dense_compile_s=dense_compile,
            init_ms=pop_init,
            dense_init_ms=dense_init,
            final_acc=float(h_pop["accuracy"][-1]),
            population=population,
            cohort=cohort,
            rounds=rounds,
        ),
    )


def _warm_rows(
    base, lrs, n_seeds, rounds, p, grid_rounds,
    cold_acc=None, cold_acc_async=None,
) -> list[Row]:
    """Warm-start rows: replay the sweep + async workloads through the
    persistent compile cache (in-process cache cleared first, so every
    executable deserializes from REPRO_COMPILE_CACHE_DIR — the cost a
    SECOND process running the same grid pays)."""
    from repro.sim.events import AsyncConfig

    base_rounds = n_seeds * rounds

    clear_compile_cache()
    tm: dict = {}
    t0 = time.time()
    res = run_sweep(
        base, seeds=range(n_seeds), axes={"lr": lrs}, rounds=rounds,
        timings=tm,
    )
    t_sweep = time.time() - t0

    clear_compile_cache()
    tm_a: dict = {}
    t0 = time.time()
    res_a = run_sweep(
        base, seeds=range(n_seeds), rounds=rounds,
        engine="async", async_cfg=AsyncConfig(staleness_exponent=0.0),
        timings=tm_a,
    )
    t_async = time.time() - t0
    sim_events = int((res_a.metric("valid") > 0).sum()) + n_seeds * rounds * (
        p["topk"] + 1
    )
    ev_exec = sim_events / max(tm_a.get("exec_s", 0.0), 1e-9)
    ev_wall = sim_events / max(t_async, 1e-9)

    # replaying a serialized executable is exact — flag any drift
    dev = dev_a = ""
    if cold_acc is not None:
        d = float(np.abs(np.asarray(res.metric("accuracy")) - cold_acc).max())
        dev = f"max_acc_dev={d:.2g};"
    if cold_acc_async is not None:
        d = float(
            np.abs(np.asarray(res_a.metric("accuracy")) - cold_acc_async).max()
        )
        dev_a = f"max_acc_dev={d:.2g};"

    return [
        Row(
            "simulator_engine/sweep_warm",
            t_sweep / grid_rounds * 1e6,
            f"wall_s={t_sweep:.2f};"
            f"load_s={tm.get('load_s', 0.0):.2f};"
            f"exec_s={tm.get('exec_s', 0.0):.2f};"
            f"n_compiles={tm.get('n_compiles', 0)};"
            f"disk_hits={tm.get('disk_hits', 0)};{dev}"
            + fmt(grid=len(lrs), seeds=n_seeds, rounds=rounds,
                  clients=p["clients"]),
        ),
        Row(
            "simulator_engine/async_events_warm",
            t_async / base_rounds * 1e6,
            f"wall_s={t_async:.2f};"
            f"load_s={tm_a.get('load_s', 0.0):.2f};"
            f"exec_s={tm_a.get('exec_s', 0.0):.2f};"
            f"n_compiles={tm_a.get('n_compiles', 0)};"
            f"disk_hits={tm_a.get('disk_hits', 0)};{dev_a}"
            f"events_per_sec_exec={ev_exec:.0f};"
            f"events_per_sec_wall={ev_wall:.1f};"
            + fmt(seeds=n_seeds, rounds=rounds, clients=p["clients"]),
        ),
    ]
