"""Paper Table IX / Fig. 12: orchestration complexity.

Measures the REAL wall-time of FedFog's jitted scheduling decision
(Eqs. 1/2/3/7 + priority ranking) across client-pool sizes, against the
modeled FogFaaS redeploy/poll loop. Fits scaling exponents: FedFog should
be ~O(N log N) (near-linear), FogFaaS ~O(N²).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, SCALE, fmt
from repro.core.scheduler import SchedulerConfig, schedule_round
from repro.core.types import ClientTelemetry, init_scheduler_state
from repro.sim.des import FaasSimConfig, RoundCostModel
from repro.data.telemetry import TelemetryConfig, make_profiles

SIZES = {"quick": (64, 256, 1024), "default": (64, 256, 1024, 4096),
         "full": (64, 256, 1024, 4096, 16384)}


def _time_scheduler(n: int, iters: int = 20) -> float:
    cfg = SchedulerConfig(top_k=max(8, n // 4))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    tel = ClientTelemetry(
        cpu=jax.random.uniform(ks[0], (n,)),
        mem=jax.random.uniform(ks[1], (n,)),
        batt=jax.random.uniform(ks[2], (n,)),
        energy=jax.random.uniform(ks[3], (n,)),
    )
    hist = jnp.abs(jax.random.normal(ks[4], (n, 32))) + 0.5
    state = init_scheduler_state(n, 32)
    fn = jax.jit(lambda s, t, h: schedule_round(s, t, h, cfg))
    out = fn(state, tel, hist)  # compile
    jax.block_until_ready(out.selection.mask)
    t0 = time.time()
    for _ in range(iters):
        out = fn(state, tel, hist)
    jax.block_until_ready(out.selection.mask)
    return (time.time() - t0) / iters * 1e6  # us


def run() -> list[Row]:
    sizes = SIZES[SCALE]
    rows, fed_us, fog_ms = [], [], []
    cost_model = RoundCostModel(FaasSimConfig())
    for n in sizes:
        us = _time_scheduler(n)
        fed_us.append(us)
        prof = make_profiles(TelemetryConfig(num_clients=n))
        _, _, orch = cost_model.times_ms(
            prof, jnp.ones(n, bool), jnp.zeros(n, bool), 1e9, 1e6, 1e6,
            policy="fogfaas",
        )
        fog_ms.append(float(orch))
        rows.append(
            Row(
                f"tableIX/N{n}",
                us,
                fmt(fedfog_sched_us=us, fogfaas_orch_ms=float(orch)),
            )
        )
    ns = np.asarray(sizes, float)
    fed_alpha = float(np.polyfit(np.log(ns), np.log(np.asarray(fed_us)), 1)[0])
    fog_alpha = float(np.polyfit(np.log(ns), np.log(np.asarray(fog_ms)), 1)[0])
    rows.append(
        Row(
            "tableIX/summary",
            0.0,
            fmt(
                fedfog_alpha=fed_alpha,
                fogfaas_alpha=fog_alpha,
                paper_claim="fedfog~NlogN(fogfaas~N^2)",
                claim_met=int(fed_alpha < 1.5 and fog_alpha > 1.7),
            ),
        )
    )
    return rows
