"""Benchmark trajectory across PRs: append-only history of bench rows.

``BENCH_simulator.json`` is a single-snapshot baseline — the perf ratchet
compares against it, then REPRO_BENCH_RECORD=1 overwrites it, and the
previous numbers are gone (recoverable only by archaeology through git).
This module keeps the longitudinal view: every ``--json`` run of
``benchmarks.run`` ALSO appends one timestamped record (git sha, scale,
per-row ``us_per_call``) to ``BENCH_history.jsonl``, and

    python -m benchmarks.history --table

prints the per-row trajectory — one line per benchmark row, one column
per recorded run — so "did the async engine actually get faster over the
last four PRs, or did we just keep re-recording the baseline?" is a
one-command question.

``--backfill-git`` seeds the history from the git log of
``BENCH_simulator.json`` (one synthetic record per commit that touched
it), so the trajectory extends back before this file existed.

The file lives next to the baseline (``BENCH_history.jsonl`` at the repo
root) unless ``REPRO_BENCH_HISTORY`` points elsewhere — CI smoke tests
point it at a temp file so they never pollute the real trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_HISTORY_ENV = "REPRO_BENCH_HISTORY"
_DEFAULT_PATH = os.path.join(REPO, "BENCH_history.jsonl")
_BASELINE = "BENCH_simulator.json"


def history_path(path: str | None = None) -> str:
    return path or os.environ.get(_HISTORY_ENV) or _DEFAULT_PATH


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=REPO, timeout=10,
        )
        return out.stdout.strip() if out.returncode == 0 else ""
    except Exception:
        return ""


def append_record(payload: dict, path: str | None = None) -> str:
    """Append one history entry distilled from a ``benchmarks.run --json``
    payload; returns the path written. Row entries keep only the fields
    the trajectory table needs (name, us_per_call) — ``derived`` strings
    are bulky and stay in the snapshot baseline."""
    entry = {
        "ts": round(time.time(), 3),
        "git": _git_sha(),
        "scale": payload.get("scale", "default"),
        "suites": payload.get("suites", []),
        "rows": [
            {"name": r["name"], "us_per_call": r["us_per_call"]}
            for r in payload.get("rows", [])
            if "us_per_call" in r
        ],
    }
    path = history_path(path)
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
        f.flush()
    return path


def load(path: str | None = None) -> list[dict]:
    path = history_path(path)
    if not os.path.exists(path):
        return []
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # tolerate a torn final line
    return entries


def backfill_from_git(path: str | None = None) -> int:
    """Seed the history from every commit that touched the snapshot
    baseline: one synthetic record per ``BENCH_simulator.json`` version,
    stamped with the commit time and sha. Returns the number of records
    appended (0 if the baseline has no git history)."""
    try:
        log = subprocess.run(
            ["git", "log", "--reverse", "--format=%H %ct", "--", _BASELINE],
            capture_output=True, text=True, cwd=REPO, timeout=30,
        )
    except Exception:
        return 0
    if log.returncode != 0:
        return 0
    n = 0
    path = history_path(path)
    with open(path, "a") as f:
        for line in log.stdout.strip().splitlines():
            sha, _, ct = line.partition(" ")
            show = subprocess.run(
                ["git", "show", f"{sha}:{_BASELINE}"],
                capture_output=True, text=True, cwd=REPO, timeout=30,
            )
            if show.returncode != 0:
                continue
            try:
                payload = json.loads(show.stdout)
            except json.JSONDecodeError:
                continue
            entry = {
                "ts": float(ct),
                "git": sha[:7],
                "scale": payload.get("scale", "default"),
                "suites": payload.get("suites", []),
                "backfilled": True,
                "rows": [
                    {"name": r["name"], "us_per_call": r["us_per_call"]}
                    for r in payload.get("rows", [])
                    if "us_per_call" in r
                ],
            }
            f.write(json.dumps(entry) + "\n")
            n += 1
    return n


def format_table(entries: list[dict], last: int = 8) -> str:
    """Per-row trajectory: one line per bench row, one column per
    recorded run (oldest → newest of the final ``last`` entries), with
    the net change over the window. Summary rows (us_per_call == 0)
    carry their data in ``derived`` and are skipped."""
    entries = sorted(entries, key=lambda e: e.get("ts", 0.0))[-last:]
    if not entries:
        return "(no history recorded — run benchmarks.run --json first)"
    cols = [
        (e.get("git") or time.strftime("%m-%d", time.localtime(e["ts"])))
        + ("*" if e.get("backfilled") else "")
        for e in entries
    ]
    names: list[str] = []
    for e in entries:
        for r in e["rows"]:
            if r["us_per_call"] > 0 and r["name"] not in names:
                names.append(r["name"])
    by_entry = [
        {r["name"]: r["us_per_call"] for r in e["rows"]} for e in entries
    ]
    name_w = max([len(n) for n in names] or [4])
    col_w = max([len(c) for c in cols] + [9])
    lines = [
        f"# us/call trajectory, {len(entries)} run(s)"
        + (" (*=git backfill)" if any(e.get("backfilled") for e in entries)
           else ""),
        " ".join([" " * name_w] + [c.rjust(col_w) for c in cols]
                 + ["    net"]),
    ]
    for name in names:
        vals = [be.get(name) for be in by_entry]
        cells = [
            (f"{v:.0f}" if v is not None else "-").rjust(col_w)
            for v in vals
        ]
        present = [v for v in vals if v]
        net = (
            f"{(present[-1] - present[0]) / present[0] * 100:+.0f}%"
            if len(present) >= 2 else "  -"
        )
        lines.append(" ".join([name.ljust(name_w)] + cells
                              + [net.rjust(6)]))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table", action="store_true",
                    help="print the per-row trajectory table")
    ap.add_argument("--last", type=int, default=8,
                    help="show at most the final N history entries")
    ap.add_argument("--path", default=None,
                    help=f"history file (default {_DEFAULT_PATH}, "
                         f"env override {_HISTORY_ENV})")
    ap.add_argument("--backfill-git", action="store_true",
                    help=f"seed history from the git log of {_BASELINE}")
    args = ap.parse_args(argv)
    if args.backfill_git:
        n = backfill_from_git(args.path)
        print(f"# backfilled {n} record(s) from git history of {_BASELINE}")
    if args.table or not args.backfill_git:
        print(format_table(load(args.path), last=args.last))
    return 0


if __name__ == "__main__":
    sys.exit(main())
