"""Worker for the ``sweep_sharded`` row (run as a SUBPROCESS).

Backs N fake CPU devices and runs the grouped lr-grid sweep with
``run_sweep(devices=N)`` — the vmapped seed batch sharded across the
device mesh, every device executing |seeds|/N simulations of each grid
point in parallel. Must run in its own process because the fake-device
flag has to be set before jax initializes its backend.

Prints one JSON line: wall/compile/exec attribution plus an accuracy
checksum (per-seed results are device-count invariant — verified by
test_sweep_devices_sharding_bit_identical).
"""
import os
import sys

if __name__ == "__main__":  # set BEFORE any jax import in this process
    _n = "8"
    for _i, _a in enumerate(sys.argv):
        if _a == "--devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

# ruff: noqa: E402
import argparse
import json
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--topk", type=int, default=12)
    ap.add_argument("--lrs", default="0.03,0.04,0.05,0.06")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.fl.simulator import SimulatorConfig
    from repro.sim import run_sweep

    base = SimulatorConfig(
        task="emnist", num_clients=args.clients, rounds=args.rounds,
        top_k=args.topk,
    )
    lrs = [float(x) for x in args.lrs.split(",")]
    tm: dict = {}
    t0 = time.time()
    res = run_sweep(
        base, seeds=range(args.seeds), axes={"lr": lrs},
        rounds=args.rounds, devices=args.devices, timings=tm,
    )
    wall = time.time() - t0
    print(json.dumps({
        "wall_s": wall,
        "trace_s": tm.get("trace_s", 0.0),
        "compile_s": tm.get("compile_s", 0.0),
        "exec_s": tm.get("exec_s", 0.0),
        "sim_rounds": len(res.configs) * args.seeds * args.rounds,
        "devices": args.devices,
        "acc_mean": float(np.asarray(res.metric("accuracy")).mean()),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
