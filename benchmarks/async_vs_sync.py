"""Async server rules vs the synchronous round barrier, under churn.

The scenario the paper's synchronous DES cannot express: a straggler tail
(lognormal per-client latency) plus client churn. All three engines run
the SAME event-driven machinery (repro.sim.events) with the same dispatch
budget, churn process, and cost model — only the server rule differs:

    sync    : round barrier — dispatch the next cohort only when every
              admitted update has arrived (on_flush cohort mode).
    fedasync: apply every update on arrival, staleness-discounted
              (buffer_k=1, fixed dispatch cadence).
    fedbuff : buffered aggregation — flush every K arrivals
              (buffer_k=K, fixed dispatch cadence).

Reported per rule (multi-seed): time-to-target-accuracy on the virtual
clock, energy spent up to the target, final accuracy, mean staleness.
The async rules should reach the target in less virtual time because the
barrier pays the straggler tail every round.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, fmt, preset
from repro.fl.simulator import SimulatorConfig
from repro.sim import run_sweep
from repro.sim.events import AsyncConfig, ChurnConfig

MODES = {
    "sync": {"dispatch_mode": "on_flush", "buffer_k": None,
             "staleness_exponent": 0.0},
    "fedasync": {"dispatch_mode": "interval", "buffer_k": 1},
    "fedbuff": {"dispatch_mode": "interval"},  # buffer_k set from preset
}


def _time_and_energy_to_target(res, g, target):
    """(mean time-to-target ms, mean energy-to-target J, hit rate) over
    the seeds of grid point ``g`` that reach ``target`` accuracy."""
    acc = res.metric("accuracy")[g]
    t = res.metric("t_ms")[g]
    e = np.cumsum(res.metric("energy_j")[g], axis=-1)
    valid = res.metric("valid")[g] > 0
    tts, ets = [], []
    for s in range(acc.shape[0]):
        hit = np.flatnonzero((acc[s] >= target) & valid[s])
        if hit.size:
            tts.append(t[s, hit[0]])
            ets.append(e[s, hit[0]])
    n = acc.shape[0]
    if not tts:
        return float("inf"), float("inf"), 0.0
    return float(np.mean(tts)), float(np.mean(ets)), len(tts) / n


def run() -> list[Row]:
    p = preset()
    cfg = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"], seed=0,
    )
    base = AsyncConfig(
        dispatch_interval_ms=400.0,
        straggler_sigma=0.5,
        churn=ChurnConfig(arrival_rate=0.05, departure_rate=0.1),
    )
    cases = [dict(v) for v in MODES.values()]
    cases[-1]["buffer_k"] = max(2, p["topk"] // 3)  # fedbuff K

    # Every rule gets the same generous dispatch budget; time-to-target is
    # judged on the *virtual* clock, so extra dispatches cannot flatter a
    # rule — the barrier still pays the straggler tail per round.
    dispatches = p["rounds"] * 3

    t0 = time.time()
    res = run_sweep(
        cfg, seeds=range(p["seeds"]), cases=cases,
        rounds=dispatches, engine="async", async_cfg=base,
    )
    wall = time.time() - t0
    # processed events = dispatches + completions (Σ aggregated) + flushes
    sim_events = int(
        (res.metric("valid") > 0).sum()
        + res.metric("num_aggregated").sum()
        + len(cases) * p["seeds"] * dispatches
    )

    # target: 90% of the WEAKEST rule's mean final accuracy, so every rule
    # can reach it and time-to-target compares speed at a common bar.
    # (FedBuff's normalized buffer average takes ~K completions per
    # effective server step, so a sync-anchored bar would be unreachable
    # for it at small dispatch budgets.)
    finals = res.final("accuracy").mean(axis=1)  # valid-aware (G,)
    target = 0.9 * float(finals.min())

    rows, tt = [], {}
    for g, name in enumerate(MODES):
        t_ms, e_j, hit = _time_and_energy_to_target(res, g, target)
        final = float(finals[g])
        valid = res.metric("valid")[g] > 0
        stal = res.metric("mean_staleness")[g]
        rows.append(
            Row(
                f"async_vs_sync/{name}",
                wall / max(sim_events, 1) * 1e6,
                fmt(
                    target_acc=target,
                    time_to_target_ms=t_ms,
                    energy_to_target_j=e_j,
                    hit_rate=hit,
                    final_acc=final,
                    mean_staleness=float(stal[valid].mean()),
                ),
            )
        )
        tt[name] = t_ms
    rows.append(
        Row(
            "async_vs_sync/summary",
            0.0,
            fmt(
                fedbuff_speedup_vs_sync=tt["sync"] / max(tt["fedbuff"], 1e-9),
                fedasync_speedup_vs_sync=tt["sync"] / max(tt["fedasync"], 1e-9),
                claim="async rules avoid paying the straggler tail per round",
            ),
        )
    )
    return rows
