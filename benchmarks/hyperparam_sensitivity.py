"""Paper Fig. 10: batch-size and learning-rate sensitivity.

Paper claims: best trade-off near batch 32; lr 0.01-ish best, with 0.001
too slow and 0.1 unstable.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_rounds
from repro.fl.simulator import FedFogSimulator, SimulatorConfig


def run() -> list[Row]:
    p = preset()
    rows = []
    accs_b, accs_lr = {}, {}
    for bs in (16, 32, 64, 128):
        sim = FedFogSimulator(
            SimulatorConfig(
                task="emnist", num_clients=p["clients"], rounds=p["rounds"],
                top_k=p["topk"], local_batch=bs, seed=0,
            )
        )
        h, uspc = timed_rounds(sim, p["rounds"])
        accs_b[bs] = h["final_accuracy"]
        rows.append(
            Row(
                f"fig10/batch{bs}", uspc,
                fmt(acc=h["final_accuracy"], latency_ms=h["mean_latency_ms"]),
            )
        )
    for lr in (0.005, 0.05, 0.5):
        sim = FedFogSimulator(
            SimulatorConfig(
                task="emnist", num_clients=p["clients"], rounds=p["rounds"],
                top_k=p["topk"], lr=lr, seed=0,
            )
        )
        h, uspc = timed_rounds(sim, p["rounds"])
        accs_lr[lr] = h["final_accuracy"]
        rows.append(Row(f"fig10/lr{lr}", uspc, fmt(acc=h["final_accuracy"])))
    rows.append(
        Row(
            "fig10/summary",
            0.0,
            fmt(
                best_batch=max(accs_b, key=accs_b.get),
                best_lr=max(accs_lr, key=accs_lr.get),
                mid_lr_best=int(
                    accs_lr[0.05] >= max(accs_lr[0.005], accs_lr[0.5])
                ),
            ),
        )
    )
    return rows
