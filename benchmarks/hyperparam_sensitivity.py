"""Paper Fig. 10: batch-size and learning-rate sensitivity.

Paper claims: best trade-off near batch 32; lr 0.01-ish best, with 0.001
too slow and 0.1 unstable.

Runs on the sweep API: one sweep over batch sizes, one over learning
rates (each grid point scan-compiled).
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig

BATCHES = (16, 32, 64, 128)
LRS = (0.005, 0.05, 0.5)


def run() -> list[Row]:
    p = preset()
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"],
    )
    rows = []
    res_b, uspc_b = timed_sweep(
        base, seeds=[0], axes={"local_batch": list(BATCHES)}
    )
    accs_b = {
        bs: float(res_b.final("accuracy")[i, 0]) for i, bs in enumerate(BATCHES)
    }
    for i, bs in enumerate(BATCHES):
        s = res_b.stats(i)
        rows.append(
            Row(
                f"fig10/batch{bs}", uspc_b,
                fmt(acc=accs_b[bs], latency_ms=float(s["mean_latency_ms"][0])),
            )
        )
    res_lr, uspc_lr = timed_sweep(base, seeds=[0], axes={"lr": list(LRS)})
    accs_lr = {
        lr: float(res_lr.final("accuracy")[i, 0]) for i, lr in enumerate(LRS)
    }
    for lr in LRS:
        rows.append(Row(f"fig10/lr{lr}", uspc_lr, fmt(acc=accs_lr[lr])))
    rows.append(
        Row(
            "fig10/summary",
            0.0,
            fmt(
                best_batch=max(accs_b, key=accs_b.get),
                best_lr=max(accs_lr, key=accs_lr.get),
                mid_lr_best=int(
                    accs_lr[0.05] >= max(accs_lr[0.005], accs_lr[0.5])
                ),
            ),
        )
    )
    return rows
