"""Roofline analysis (launch brief §Roofline): derive the three terms per
(arch × shape) cell from the dry-run's compiled artifact.

    compute    = HLO_FLOPs_per_device / peak_FLOPs            [s]
    memory     = HLO_bytes_per_device / HBM_bw                [s]
    collective = collective_bytes_per_device / ICI link bw    [s]

Sources: loop-scaled static HLO analysis (dist/hlo_analysis — XLA's own
cost_analysis under-counts while bodies; see module doc) from
results/dryrun/*.json. MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(serving) gives the useful-compute ratio.

Emits one row per cell + writes results/roofline.csv for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Row, fmt
from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import build_model

# CWD-relative, matching repro.launch.dryrun's RESULT_DIR (both halves of
# the pipeline are run from the repo root); fall back to the repo-root
# location when invoked from elsewhere.
DRYRUN_DIR = os.path.join("results", "dryrun")
if not os.path.isdir(DRYRUN_DIR):
    DRYRUN_DIR = os.path.join(
        os.path.dirname(__file__), "..", "results", "dryrun"
    )
OUT_CSV = os.path.join(os.path.dirname(DRYRUN_DIR), "roofline.csv")

# bf16 HLO byte traffic is inflated ~2x by the CPU backend's f32
# legalization of bf16 arithmetic; we report raw parsed bytes (upper bound)
# — noted in EXPERIMENTS.md.


def model_flops_total(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return model.flops_per_token(train=True) * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return model.flops_per_token(train=False) * tokens
    # decode: one token per sequence
    return model.flops_per_token(train=False) * shape.global_batch


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    chips = 512 if rec["mesh"].startswith("multipod") else 256
    flops_dev = rec.get("dot_flops", 0.0)
    bytes_dev = rec.get("hbm_bytes", 0.0)
    coll_dev = rec.get("collective_total", 0.0)
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_total(rec["arch"], rec["shape"])
    useful = mf / max(flops_dev * chips, 1e-9)
    bound = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS_BF16) / max(bound, 1e-12)
    return dict(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_coll,
        dominant=dominant,
        model_flops=mf,
        useful_ratio=useful,
        roofline_fraction=min(frac, 1.0),
        temp_gb=rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    )


def load_cells(mesh: str = "single") -> list[dict]:
    out = []
    if not os.path.isdir(DRYRUN_DIR):
        return out
    for arch in ARCH_IDS:
        for shape in SHAPES:
            path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
            if os.path.exists(path):
                with open(path) as f:
                    out.append(json.load(f))
    return out


def run() -> list[Row]:
    rows = []
    cells = load_cells("single")
    analyzed = []
    n_ok = n_skip = n_fail = 0
    for rec in cells:
        if rec["status"] == "SKIP":
            n_skip += 1
            rows.append(
                Row(
                    f"roofline/{rec['arch']}/{rec['shape']}",
                    0.0,
                    fmt(status="SKIP", reason=rec.get("skip_reason", "")[:40]),
                )
            )
            continue
        if rec["status"] != "OK":
            n_fail += 1
            rows.append(
                Row(
                    f"roofline/{rec['arch']}/{rec['shape']}",
                    0.0,
                    fmt(status="FAIL"),
                )
            )
            continue
        n_ok += 1
        a = analyze_cell(rec)
        analyzed.append(a)
        rows.append(
            Row(
                f"roofline/{rec['arch']}/{rec['shape']}",
                0.0,
                fmt(
                    compute_s=a["t_compute"],
                    memory_s=a["t_memory"],
                    collective_s=a["t_collective"],
                    dominant=a["dominant"],
                    useful_ratio=a["useful_ratio"],
                    roofline_frac=a["roofline_fraction"],
                ),
            )
        )
    if analyzed:
        os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
        with open(OUT_CSV, "w") as f:
            cols = list(analyzed[0])
            f.write(",".join(cols) + "\n")
            for a in analyzed:
                f.write(",".join(str(a[c]) for c in cols) + "\n")
    rows.append(
        Row("roofline/summary", 0.0, fmt(ok=n_ok, skip=n_skip, fail=n_fail))
    )
    return rows
