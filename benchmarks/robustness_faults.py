"""Fault & recovery robustness: accuracy / §IV.F cost vs failure rate.

Three experiments on the sweep API (compile-once per structural group —
the fault gate is the ONLY structural bit, the rates are lifted
numerics, so the whole crash grid shares one compiled program):

  crash grid      : crash_rate ∈ {0, 0.2, 0.5} with a 2-retry backoff
                    budget — how much accuracy survives a serverless
                    crash storm, and what the retry chains cost in
                    wall latency and repaid invocation energy.
  deadline_vs_barrier : the same faulted cohort aggregated two ways —
                    full barrier (server waits out every retry chain)
                    vs a round deadline + quorum ≥ 25% (aggregate
                    whatever arrived, Eq. 6 reweighted). The paper's
                    straggler argument, restated for failures: the
                    deadline trades a sliver of per-round cohort mass
                    for a hard latency cap.
  failover        : fog-tier outage (fog_nodes=2) with and without
                    failover — recovered arrivals vs lost ones, and
                    the detour latency failover pays.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig
from repro.sim.faults import FaultConfig

CRASH_RATES = (0.0, 0.2, 0.5)


def _totals(res, g):
    """Per-grid-point fault/latency/energy totals summed over seeds+rounds."""
    lat = np.asarray(res.history["round_latency_ms"])[g].mean()
    energy = np.asarray(res.history["energy_j"])[g].sum()
    retries = np.asarray(res.history["fault_retries"])[g].sum()
    lost = np.asarray(res.history["fault_lost"])[g].sum()
    skipped = np.asarray(res.history["round_skipped"])[g].sum()
    return lat, energy, retries, lost, skipped


def run() -> list[Row]:
    p = preset()
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"],
    )
    rows: list[Row] = []

    # --- crash-rate grid (one compiled program: rates are lifted) ------ #
    cases = [
        {"faults": FaultConfig(crash_rate=r, max_retries=2)}
        for r in CRASH_RATES
    ]
    res, uspc = timed_sweep(base, seeds=[0, 1], cases=cases)
    finals = {}
    for g, r in enumerate(CRASH_RATES):
        acc = float(res.final("accuracy")[g].mean())
        lat, energy, retries, lost, skipped = _totals(res, g)
        finals[r] = (acc, lat, energy)
        rows.append(
            Row(
                f"robustness_faults/crash_{r:g}",
                uspc,
                fmt(
                    final_acc=acc, mean_latency_ms=lat, energy_j=energy,
                    retries=int(retries), lost=int(lost),
                    skipped=int(skipped),
                ),
            )
        )

    # --- deadline+quorum vs full barrier under the same crash storm --- #
    storm = dict(crash_rate=0.5, max_retries=2, backoff_base_ms=500.0)
    cases = [
        {"faults": FaultConfig(**storm)},  # barrier: wait out all retries
        {"faults": FaultConfig(**storm, deadline_ms=4000.0,
                               quorum_frac=0.25)},
    ]
    res_d, uspc_d = timed_sweep(base, seeds=[0, 1], cases=cases)
    lat_b, _, _, _, _ = _totals(res_d, 0)
    lat_d, _, _, lost_d, skip_d = _totals(res_d, 1)
    acc_b = float(res_d.final("accuracy")[0].mean())
    acc_d = float(res_d.final("accuracy")[1].mean())
    rows.append(
        Row(
            "robustness_faults/deadline_vs_barrier",
            uspc_d,
            fmt(
                barrier_latency_ms=lat_b, deadline_latency_ms=lat_d,
                latency_saved=1.0 - lat_d / max(lat_b, 1e-9),
                barrier_acc=acc_b, deadline_acc=acc_d,
                deadline_lost=int(lost_d), rounds_skipped=int(skip_d),
            ),
        )
    )

    # --- fog outage: failover reroutes, no-failover loses -------------- #
    outage = dict(fog_outage_rate=0.3)
    res_f, uspc_f = timed_sweep(
        base, seeds=[0, 1],
        cases=[
            {"fog_nodes": 2, "faults": FaultConfig(**outage)},
            {"fog_nodes": 2,
             "faults": FaultConfig(**outage, fog_failover=True)},
        ],
    )
    lost_no = float(np.asarray(res_f.history["fault_lost"])[0].sum())
    saved = float(np.asarray(res_f.history["fault_failed_over"])[1].sum())
    lat_no = float(np.asarray(res_f.history["round_latency_ms"])[0].mean())
    lat_fo = float(np.asarray(res_f.history["round_latency_ms"])[1].mean())
    rows.append(
        Row(
            "robustness_faults/failover",
            uspc_f,
            fmt(
                lost_without_failover=int(lost_no),
                rerouted_with_failover=int(saved),
                latency_ms_no_failover=lat_no,
                latency_ms_failover=lat_fo,
                acc_no_failover=float(res_f.final("accuracy")[0].mean()),
                acc_failover=float(res_f.final("accuracy")[1].mean()),
            ),
        )
    )

    # --- summary: the fault tax relative to the clean grid point ------- #
    acc0, lat0, e0 = finals[0.0]
    accw, latw, ew = finals[max(CRASH_RATES)]
    rows.append(
        Row(
            "robustness_faults/summary",
            0.0,
            fmt(
                acc_drop_at_worst=acc0 - accw,
                latency_tax=latw / max(lat0, 1e-9),
                energy_tax=ew / max(e0, 1e-9),
                deadline_latency_saved=1.0 - lat_d / max(lat_b, 1e-9),
            ),
        )
    )
    return rows
