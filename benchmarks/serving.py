"""Serving engines: continuous batching vs the sequential per-request oracle.

One Poisson trace (offered load > 1 request per decode window, so slots
stay saturated) served three ways on the same reduced model:

  sequential      : the per-request oracle — prefill + batch-1 decode to
                    completion, one request at a time (the static
                    baseline every serving stack is measured against).
  continuous      : the slot-scheduled engine, dense-gather attention —
                    the token-for-token-exact path. The derived columns
                    carry the acceptance gate: ``speedup`` (wall
                    tokens/sec over sequential, expected >= 2x at quick
                    scale) and ``exact`` (1 iff every request's tokens
                    match the oracle bitwise).
  continuous_paged: same engine through the Pallas paged flash-decode
                    kernel (interpret mode off-TPU) — prices the
                    kernel's dispatch overhead and checks greedy-token
                    agreement with the oracle.

``us_per_call`` is wall microseconds per generated token (lower is
better); latency percentiles / goodput / energy-per-token ride in
``derived`` (virtual-clock §IV.F accounting — see docs/EXPERIMENTS.md
§Serving). Both engines keep tokens device-resident with ONE terminal
sync, so the comparison measures scheduling, not host transfers.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row, SCALE
from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import (
    ContinuousBatchingEngine,
    EngineConfig,
    SequentialOracle,
    TraceConfig,
    make_trace,
)

ARCH = "llama3.2-1b"
SHAPES = {
    "quick": dict(requests=24, slots=8, prompt_len=16, page_size=8,
                  min_gen=6, max_gen=12, rate=200.0),
    "default": dict(requests=48, slots=8, prompt_len=16, page_size=8,
                    min_gen=8, max_gen=16, rate=200.0),
    "full": dict(requests=96, slots=16, prompt_len=32, page_size=16,
                 min_gen=8, max_gen=24, rate=400.0),
}


def _serve_timed(server, trace):
    """Median-of-3 wall time (the loop is host-driven; first call per
    engine warms numpy<->device conversion paths)."""
    reps, walls = [], []
    for _ in range(3):
        t0 = time.time()
        rep = server.serve(trace)
        walls.append(time.time() - t0)
        reps.append(rep)
    return reps[int(np.argsort(walls)[1])], float(np.median(walls))


def run() -> list[Row]:
    shape = SHAPES[SCALE]
    cfg = get_reduced(ARCH, loss_chunk=0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        slots=shape["slots"], page_size=shape["page_size"],
        prompt_len=shape["prompt_len"], max_gen=shape["max_gen"],
        max_requests=shape["requests"],
    )
    trace = make_trace(
        jax.random.PRNGKey(1),
        TraceConfig(
            n_requests=shape["requests"], rate_per_s=shape["rate"],
            prompt_len=shape["prompt_len"], min_gen=shape["min_gen"],
            max_gen=shape["max_gen"], slo_ms=8000.0,
        ),
        cfg,
    )

    rows = []
    oracle = SequentialOracle(model, params, ecfg)
    ref, wall = _serve_timed(oracle, trace)
    seq_tps = ref.tokens_generated / wall
    rows.append(Row(
        "serving/sequential",
        wall / ref.tokens_generated * 1e6,
        f"tok_per_s={seq_tps:.0f};p95_ms={ref.percentiles['p95']:.0f};"
        f"energy_per_token_j={ref.energy_per_token_j:.3e};"
        f"virtual_ms={ref.virtual_ms:.0f}",
    ))

    for attn in ("dense", "paged"):
        import dataclasses

        eng = ContinuousBatchingEngine(
            model, params, dataclasses.replace(ecfg, attn=attn)
        )
        rep, wall = _serve_timed(eng, trace)
        tps = rep.tokens_generated / wall
        match = sum(
            rep.tokens_for(r) == ref.tokens_for(r)
            for r in range(trace.n_requests)
        )
        pct = rep.percentiles
        name = "continuous" if attn == "dense" else "continuous_paged"
        # Dense must match the oracle bitwise (exact=1 is the acceptance
        # gate); the paged kernel recomputes the softmax online in fp32,
        # so near-tie greedy picks can flip — report its match fraction.
        exact = int(match == trace.n_requests)
        rows.append(Row(
            f"serving/{name}",
            wall / rep.tokens_generated * 1e6,
            f"speedup_vs_sequential={tps / seq_tps:.2f};exact={exact};"
            f"req_match={match}/{trace.n_requests};"
            f"tok_per_s={tps:.0f};p50_ms={pct['p50']:.0f};"
            f"p95_ms={pct['p95']:.0f};p99_ms={pct['p99']:.0f};"
            f"goodput_rps={rep.goodput_rps:.2f};"
            f"energy_per_token_j={rep.energy_per_token_j:.3e};"
            f"cold_starts={rep.cold_starts};"
            f"n_compiles={sum(rep.n_compiles.values())}",
        ))
    return rows
