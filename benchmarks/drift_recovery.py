"""Paper Table IV: convergence & drift impact.

Drift injected every ``drift_period`` rounds; report initial/peak/post-drift
trough/recovery accuracies and rounds-to-recovery. Paper claim: ≥95% of
peak accuracy recovered within 10 rounds post-drift.

Runs on the sweep API (single grid point, scan-compiled rounds).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig


def run() -> list[Row]:
    p = preset()
    rounds = max(p["rounds"], 24)
    drift_at = rounds // 2
    cfg = SimulatorConfig(
        task="emnist",
        num_clients=p["clients"],
        rounds=rounds,
        top_k=p["topk"],
        drift_period=drift_at,
    )
    res, uspc = timed_sweep(cfg, seeds=[0], rounds=rounds)
    acc = np.asarray(res.metric("accuracy")[0, 0])
    peak_pre = float(acc[:drift_at].max())
    # trough within 10 rounds of the shift; recovery measured FROM the trough
    window_end = min(drift_at + 10, rounds)
    trough_idx = drift_at + int(np.argmin(acc[drift_at:window_end]))
    trough_post = float(acc[trough_idx])
    recovery_target = 0.95 * peak_pre
    rec_rounds = next(
        (i for i in range(trough_idx, rounds) if acc[i] >= recovery_target),
        None,
    )
    rec_in = (rec_rounds - trough_idx) if rec_rounds is not None else -1
    return [
        Row(
            "tableIV/drift_impact",
            uspc,
            fmt(
                initial=float(acc[0]),
                peak_pre_drift=peak_pre,
                trough_post_drift=trough_post,
                final=float(acc[-1]),
                rounds_to_95pct_recovery=rec_in,
                paper_claim="recovery<=10",
                claim_met=int(0 <= rec_in <= 10),
            ),
        )
    ]
