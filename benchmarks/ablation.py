"""Paper Table VI: ablation of FedFog's components (EMNIST task).

Variants: full FedFog, w/o utility scheduler (random selection), w/o drift
manager (drift gate disabled), w/o energy model (adaptive budgeting off +
no energy gate). Reported: accuracy, mean latency, cold starts — the paper
claims every ablation hurts at least one of them.

Runs on the sweep API: one compiled program per variant (seed 0 vmapped).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.core.scheduler import SchedulerConfig
from repro.fl.simulator import SimulatorConfig


def run() -> list[Row]:
    p = preset()
    base_sched = SchedulerConfig()
    variants = {
        "full": dict(policy="fedfog", scheduler=base_sched),
        "wo_scheduler": dict(policy="rcs", scheduler=base_sched),
        "wo_drift_manager": dict(
            policy="fedfog",
            scheduler=dataclasses.replace(base_sched, drift_gating=False),
        ),
        "wo_energy_model": dict(
            policy="fedfog",
            scheduler=dataclasses.replace(
                base_sched, adaptive_energy=False, theta_e=0.0
            ),
        ),
    }
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"],
        drift_period=max(p["rounds"] // 2, 6),  # drift manager must matter
    )
    res, uspc = timed_sweep(base, seeds=[0], cases=list(variants.values()))
    rows, metrics = [], {}
    for i, name in enumerate(variants):
        s = res.stats(i)
        metrics[name] = h = {
            "final_accuracy": float(s["final_accuracy"][0]),
            "mean_latency_ms": float(s["mean_latency_ms"][0]),
            "total_cold_starts": float(s["total_cold_starts"][0]),
            "total_energy_j": float(s["total_energy_j"][0]),
        }
        rows.append(
            Row(
                f"tableVI/{name}",
                uspc,
                fmt(
                    acc=h["final_accuracy"],
                    latency_ms=h["mean_latency_ms"],
                    cold_starts=h["total_cold_starts"],
                    energy_j=h["total_energy_j"],
                ),
            )
        )
    full = metrics["full"]
    degraded = sum(
        1
        for k, h in metrics.items()
        if k != "full"
        and (
            h["final_accuracy"] < full["final_accuracy"]
            or h["mean_latency_ms"] > full["mean_latency_ms"]
            or h["total_cold_starts"] > full["total_cold_starts"]
            or h["total_energy_j"] > full["total_energy_j"]
        )
    )
    rows.append(
        Row("tableVI/summary", 0.0, fmt(ablations_degrading=degraded, of=3))
    )
    return rows
