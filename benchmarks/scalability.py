"""Paper Fig. 8/9: energy, cold starts, latency and accuracy vs client
count for FedFog vs FogFaaS. Paper claims FedFog's energy grows ~O(N log N)
vs FogFaaS ~O(N²), and cold-start overhead ~O(N) vs super-linear.

Runs on the sweep API: client counts change array shapes, so each
(N, policy) pair is its own compiled program (``cases``); seeds vmap
inside each.

A second, population-scaling axis holds the sampled cohort FIXED and
grows the virtual client registry (``population``): per-round cost must
stay ~flat because only O(M) telemetry/scheduler gather/scatter sees the
population — the training/aggregation work is cohort-sized.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, SCALE, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig

SIZES = {"quick": (8, 16, 32), "default": (16, 32, 64), "full": (16, 32, 64, 128)}
# fixed-cohort population axis (M virtual clients, structural per point)
POPULATIONS = {
    "quick": (1_000, 100_000),
    "default": (1_000, 100_000, 1_000_000),
    "full": (1_000, 100_000, 1_000_000),
}


def _fit_power(ns, ys):
    """Least-squares exponent of y ~ n^alpha."""
    ln_n, ln_y = np.log(ns), np.log(np.maximum(ys, 1e-9))
    a, _ = np.polyfit(ln_n, ln_y, 1)
    return float(a)


def run() -> list[Row]:
    p = preset()
    sizes = SIZES[SCALE]
    cases = [
        {
            "num_clients": n,
            "policy": policy,
            "top_k": max(4, n // 3) if policy == "fedfog" else None,
        }
        for n in sizes
        for policy in ("fedfog", "fogfaas")
    ]
    base = SimulatorConfig(task="emnist", rounds=p["rounds"])
    res, uspc = timed_sweep(base, seeds=[0], cases=cases)
    rows = []
    series = {("fedfog", "energy"): [], ("fogfaas", "energy"): [],
              ("fedfog", "cold"): [], ("fogfaas", "cold"): [],
              ("fedfog", "latency"): [], ("fogfaas", "latency"): []}
    for g, ov in enumerate(res.configs):
        s = res.stats(g)
        policy, n = ov["policy"], ov["num_clients"]
        series[(policy, "energy")].append(float(s["total_energy_j"][0]))
        series[(policy, "cold")].append(float(s["total_cold_starts"][0]) + 1)
        series[(policy, "latency")].append(float(s["mean_latency_ms"][0]))
        rows.append(
            Row(
                f"fig8/{policy}/N{n}",
                uspc,
                fmt(
                    energy_j=float(s["total_energy_j"][0]),
                    cold=float(s["total_cold_starts"][0]),
                    latency_ms=float(s["mean_latency_ms"][0]),
                    acc=float(s["final_accuracy"][0]),
                ),
            )
        )
    ns = np.asarray(sizes, float)
    rows.append(
        Row(
            "fig8/scaling_exponents",
            0.0,
            fmt(
                fedfog_energy_alpha=_fit_power(ns, series[("fedfog", "energy")]),
                fogfaas_energy_alpha=_fit_power(ns, series[("fogfaas", "energy")]),
                fedfog_cold_alpha=_fit_power(ns, series[("fedfog", "cold")]),
                fogfaas_cold_alpha=_fit_power(ns, series[("fogfaas", "cold")]),
            ),
        )
    )
    rows.extend(_population_axis(p))
    return rows


def _population_axis(p) -> list[Row]:
    """Fixed cohort, growing population: per-round us must stay ~flat
    (the cohort gather/scatter is the only O(M) work). Each population is
    structural — its own compiled program via ``cases``."""
    cohort = min(p["clients"], 16)
    pops = POPULATIONS[SCALE]
    cases = [{"population": m} for m in pops]
    base = SimulatorConfig(
        task="emnist", num_clients=cohort, rounds=p["rounds"],
        top_k=max(4, cohort // 2),
    )
    res, _ = timed_sweep(base, seeds=[0], cases=cases)
    rows = []
    us = []
    for g, ov in enumerate(res.configs):
        s = res.stats(g)
        # per-group us/round: re-time isn't available per group from one
        # sweep call, so run each point standalone for the us column.
        import dataclasses
        import time

        cfg_g = dataclasses.replace(base, **ov)
        from repro.fl.simulator import FedFogSimulator

        sim = FedFogSimulator(cfg_g)
        exe = sim.aot_scanned(p["rounds"])
        sim.run_scanned_with(exe, p["rounds"])  # warm
        t0 = time.time()
        FedFogSimulator(cfg_g).run_scanned_with(exe, p["rounds"])
        us_round = (time.time() - t0) / p["rounds"] * 1e6
        us.append(us_round)
        rows.append(
            Row(
                f"population/M{ov['population']}",
                us_round,
                fmt(
                    cohort=cohort,
                    acc=float(s["final_accuracy"][0]),
                    energy_j=float(s["total_energy_j"][0]),
                ),
            )
        )
    rows.append(
        Row(
            "population/flatness",
            0.0,
            fmt(
                cohort=cohort,
                max_over_min=max(us) / max(min(us), 1e-9),
                pops=":".join(str(m) for m in pops),
            ),
        )
    )
    return rows
