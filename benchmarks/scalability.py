"""Paper Fig. 8/9: energy, cold starts, latency and accuracy vs client
count for FedFog vs FogFaaS. Paper claims FedFog's energy grows ~O(N log N)
vs FogFaaS ~O(N²), and cold-start overhead ~O(N) vs super-linear.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, SCALE, fmt, preset, timed_rounds
from repro.fl.simulator import FedFogSimulator, SimulatorConfig

SIZES = {"quick": (8, 16, 32), "default": (16, 32, 64), "full": (16, 32, 64, 128)}


def _fit_power(ns, ys):
    """Least-squares exponent of y ~ n^alpha."""
    ln_n, ln_y = np.log(ns), np.log(np.maximum(ys, 1e-9))
    a, _ = np.polyfit(ln_n, ln_y, 1)
    return float(a)


def run() -> list[Row]:
    p = preset()
    sizes = SIZES[SCALE]
    rows = []
    series = {("fedfog", "energy"): [], ("fogfaas", "energy"): [],
              ("fedfog", "cold"): [], ("fogfaas", "cold"): [],
              ("fedfog", "latency"): [], ("fogfaas", "latency"): []}
    for n in sizes:
        for policy in ("fedfog", "fogfaas"):
            sim = FedFogSimulator(
                SimulatorConfig(
                    task="emnist", num_clients=n, rounds=p["rounds"],
                    top_k=max(4, n // 3) if policy == "fedfog" else None,
                    policy=policy, seed=0,
                )
            )
            h, uspc = timed_rounds(sim, p["rounds"])
            series[(policy, "energy")].append(h["total_energy_j"])
            series[(policy, "cold")].append(h["total_cold_starts"] + 1)
            series[(policy, "latency")].append(h["mean_latency_ms"])
            rows.append(
                Row(
                    f"fig8/{policy}/N{n}",
                    uspc,
                    fmt(
                        energy_j=h["total_energy_j"],
                        cold=h["total_cold_starts"],
                        latency_ms=h["mean_latency_ms"],
                        acc=h["final_accuracy"],
                    ),
                )
            )
    ns = np.asarray(sizes, float)
    rows.append(
        Row(
            "fig8/scaling_exponents",
            0.0,
            fmt(
                fedfog_energy_alpha=_fit_power(ns, series[("fedfog", "energy")]),
                fogfaas_energy_alpha=_fit_power(ns, series[("fogfaas", "energy")]),
                fedfog_cold_alpha=_fit_power(ns, series[("fedfog", "cold")]),
                fogfaas_cold_alpha=_fit_power(ns, series[("fogfaas", "cold")]),
            ),
        )
    )
    return rows
