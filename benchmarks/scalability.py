"""Paper Fig. 8/9: energy, cold starts, latency and accuracy vs client
count for FedFog vs FogFaaS. Paper claims FedFog's energy grows ~O(N log N)
vs FogFaaS ~O(N²), and cold-start overhead ~O(N) vs super-linear.

Runs on the sweep API: client counts change array shapes, so each
(N, policy) pair is its own compiled program (``cases``); seeds vmap
inside each.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, SCALE, fmt, preset, timed_sweep
from repro.fl.simulator import SimulatorConfig

SIZES = {"quick": (8, 16, 32), "default": (16, 32, 64), "full": (16, 32, 64, 128)}


def _fit_power(ns, ys):
    """Least-squares exponent of y ~ n^alpha."""
    ln_n, ln_y = np.log(ns), np.log(np.maximum(ys, 1e-9))
    a, _ = np.polyfit(ln_n, ln_y, 1)
    return float(a)


def run() -> list[Row]:
    p = preset()
    sizes = SIZES[SCALE]
    cases = [
        {
            "num_clients": n,
            "policy": policy,
            "top_k": max(4, n // 3) if policy == "fedfog" else None,
        }
        for n in sizes
        for policy in ("fedfog", "fogfaas")
    ]
    base = SimulatorConfig(task="emnist", rounds=p["rounds"])
    res, uspc = timed_sweep(base, seeds=[0], cases=cases)
    rows = []
    series = {("fedfog", "energy"): [], ("fogfaas", "energy"): [],
              ("fedfog", "cold"): [], ("fogfaas", "cold"): [],
              ("fedfog", "latency"): [], ("fogfaas", "latency"): []}
    for g, ov in enumerate(res.configs):
        s = res.stats(g)
        policy, n = ov["policy"], ov["num_clients"]
        series[(policy, "energy")].append(float(s["total_energy_j"][0]))
        series[(policy, "cold")].append(float(s["total_cold_starts"][0]) + 1)
        series[(policy, "latency")].append(float(s["mean_latency_ms"][0]))
        rows.append(
            Row(
                f"fig8/{policy}/N{n}",
                uspc,
                fmt(
                    energy_j=float(s["total_energy_j"][0]),
                    cold=float(s["total_cold_starts"][0]),
                    latency_ms=float(s["mean_latency_ms"][0]),
                    acc=float(s["final_accuracy"][0]),
                ),
            )
        )
    ns = np.asarray(sizes, float)
    rows.append(
        Row(
            "fig8/scaling_exponents",
            0.0,
            fmt(
                fedfog_energy_alpha=_fit_power(ns, series[("fedfog", "energy")]),
                fogfaas_energy_alpha=_fit_power(ns, series[("fogfaas", "energy")]),
                fedfog_cold_alpha=_fit_power(ns, series[("fedfog", "cold")]),
                fogfaas_cold_alpha=_fit_power(ns, series[("fogfaas", "cold")]),
            ),
        )
    )
    return rows
