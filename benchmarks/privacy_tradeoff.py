"""Paper Fig. 3 + §III.K: accuracy vs differential-privacy level.

Sweeps the Gaussian-mechanism noise scale σ, reporting (ε per Eq. 12,
final accuracy mean ± 95% CI over seeds). Also prints the Eq. 12 worked
example (with the paper's arithmetic discrepancy noted — see
docs/EXPERIMENTS.md).

Sweep-native since PR 3: one vmapped/scanned program per σ instead of a
per-round Python loop — multi-seed at the same wall cost.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.core.privacy import epsilon
from repro.fl.simulator import SimulatorConfig

SIGMAS = (0.0, 0.05, 0.1, 0.3)


def run() -> list[Row]:
    p = preset()
    cfg = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"], clip_norm=1.1, seed=0,
    )
    res, uspc = timed_sweep(
        cfg, seeds=range(p["seeds"]),
        cases=[{"dp_sigma": s} for s in SIGMAS],
    )
    mean, ci = res.mean_ci("accuracy")
    rows = []
    finals = {}
    for g, sigma in enumerate(SIGMAS):
        eps = (
            float("inf")
            if sigma == 0
            else epsilon(sigma, 1.1, p["topk"], 1e-5)
        )
        finals[sigma] = float(mean[g, -1])
        rows.append(
            Row(
                f"fig3/sigma{sigma}",
                uspc,
                fmt(
                    eps_per_round=eps,
                    final_acc=finals[sigma],
                    ci95=float(ci[g, -1]),
                    seeds=p["seeds"],
                ),
            )
        )
    rows.append(
        Row(
            "fig3/eq12_worked_example",
            0.0,
            fmt(
                eps_at_paper_params=epsilon(0.3, 1.1, 30, 1e-5),
                paper_quoted=1.8,
                eps_at_Ct10=epsilon(0.3, 1.1, 10, 1e-5),
                note="paper arithmetic matches |Ct|=10 not 30",
            ),
        )
    )
    rows.append(
        Row(
            "fig3/summary",
            0.0,
            fmt(
                acc_retention_at_strongest_dp=finals[SIGMAS[-1]]
                / max(finals[0.0], 1e-9),
                paper_claim=">0.8 retention",
            ),
        )
    )
    return rows
