"""Paper Table II: threshold sensitivity grid on the EMNIST-like task.

Three (θ_h, θ_e, θ_d) combinations × multiple seeds; reports mean ± std
final accuracy. Paper claim to validate: the middle setting (0.6, 0.5, 0.1)
gives the best accuracy of the three.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, fmt, preset, timed_rounds
from repro.core.scheduler import SchedulerConfig
from repro.fl.simulator import FedFogSimulator, SimulatorConfig

GRID = [
    (0.5, 0.4, 0.10),
    (0.6, 0.5, 0.10),  # paper's adopted default
    (0.7, 0.6, 0.05),
]


def run() -> list[Row]:
    p = preset()
    rows = []
    results = {}
    for th, te, td in GRID:
        accs, uspc = [], 0.0
        for seed in range(p["seeds"]):
            sim = FedFogSimulator(
                SimulatorConfig(
                    task="emnist",
                    num_clients=p["clients"],
                    rounds=p["rounds"],
                    top_k=p["topk"],
                    seed=seed,
                    scheduler=SchedulerConfig(theta_h=th, theta_e=te, theta_d=td),
                )
            )
            h, uspc = timed_rounds(sim, p["rounds"])
            accs.append(h["final_accuracy"])
        results[(th, te, td)] = (float(np.mean(accs)), float(np.std(accs)))
        rows.append(
            Row(
                f"tableII/theta_{th}_{te}_{td}",
                uspc,
                fmt(acc_mean=results[(th, te, td)][0], acc_std=results[(th, te, td)][1]),
            )
        )
    best = max(results, key=lambda k: results[k][0])
    rows.append(
        Row(
            "tableII/summary",
            0.0,
            fmt(
                best=f"{best}",
                paper_best="(0.6, 0.5, 0.1)",
                matches_paper=int(best == (0.6, 0.5, 0.10)),
            ),
        )
    )
    return rows
