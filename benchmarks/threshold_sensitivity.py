"""Paper Table II: threshold sensitivity grid on the EMNIST-like task.

Three (θ_h, θ_e, θ_d) combinations × multiple seeds; reports mean ± std
final accuracy. Paper claim to validate: the middle setting (0.6, 0.5, 0.1)
gives the best accuracy of the three.

Runs on the sweep API: one compiled program per grid point, all seeds
vmapped inside it.
"""
from __future__ import annotations

from benchmarks.common import Row, fmt, preset, timed_sweep
from repro.core.scheduler import SchedulerConfig
from repro.fl.simulator import SimulatorConfig

GRID = [
    (0.5, 0.4, 0.10),
    (0.6, 0.5, 0.10),  # paper's adopted default
    (0.7, 0.6, 0.05),
]


def run() -> list[Row]:
    p = preset()
    base = SimulatorConfig(
        task="emnist", num_clients=p["clients"], rounds=p["rounds"],
        top_k=p["topk"],
    )
    res, uspc = timed_sweep(
        base,
        seeds=range(p["seeds"]),
        cases=[
            {"scheduler": SchedulerConfig(theta_h=th, theta_e=te, theta_d=td)}
            for th, te, td in GRID
        ],
    )
    acc_mean, acc_std = res.mean_std("accuracy", reduce="final")
    rows = [
        Row(
            f"tableII/theta_{th}_{te}_{td}",
            uspc,
            fmt(acc_mean=float(acc_mean[i]), acc_std=float(acc_std[i])),
        )
        for i, (th, te, td) in enumerate(GRID)
    ]
    best = GRID[int(acc_mean.argmax())]
    rows.append(
        Row(
            "tableII/summary",
            0.0,
            fmt(
                best=f"{best}",
                paper_best="(0.6, 0.5, 0.1)",
                matches_paper=int(best == (0.6, 0.5, 0.10)),
            ),
        )
    )
    return rows
