"""Kernel microbenchmarks: Pallas (interpret) correctness-path timings and
the XLA-path (jnp oracle) timings that actually execute on this CPU host.

On-TPU wall-times cannot be measured here; us_per_call is the CPU oracle
timing (the kernels' interpret mode is a correctness tool, not a perf
path). Roofline-relevant figures come from benchmarks/roofline.py instead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, fmt
from repro.kernels.delta_pipeline import delta_pipeline_ref
from repro.kernels.fedavg import fedavg_apply, fedavg_apply_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.layers import attention_xla_chunked


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run() -> list[Row]:
    key = jax.random.PRNGKey(0)
    rows = []

    # attention: oracle vs chunked-xla (the dry-run path)
    b, h, s, hd = 1, 4, 1024, 64
    q = jax.random.normal(key, (b, h, s, hd))
    k = jax.random.normal(key, (b, h, s, hd))
    v = jax.random.normal(key, (b, h, s, hd))
    t_ref = _time(jax.jit(lambda q, k, v: flash_attention_ref(q, k, v)), q, k, v)
    qs, ks, vs = (z.swapaxes(1, 2) for z in (q, k, v))
    pos = jnp.arange(s, dtype=jnp.int32)
    t_chunk = _time(
        jax.jit(
            lambda q, k, v: attention_xla_chunked(q, k, v, pos, pos, -1)
        ),
        qs, ks, vs,
    )
    rows.append(
        Row(
            "kernels/attention_1k",
            t_chunk,
            fmt(ref_us=t_ref, chunked_us=t_chunk),
        )
    )

    # wkv6 oracle
    r = jax.random.normal(key, (1, 256, 4, 64, ))
    kk = jax.random.normal(key, (1, 256, 4, 64)) * 0.5
    vv = jax.random.normal(key, (1, 256, 4, 64))
    w = jnp.exp(-jnp.exp(jax.random.uniform(key, (1, 256, 4, 64), minval=-3, maxval=0)))
    u = jax.random.normal(key, (4, 64)) * 0.3
    t_wkv = _time(jax.jit(lambda *a: wkv6_ref(*a)[0]), r, kk, vv, w, u)
    rows.append(Row("kernels/wkv6_256", t_wkv, fmt(ref_us=t_wkv)))

    # fedavg fused kernel (interpret) vs jnp oracle
    upd = jax.random.normal(key, (32, 1 << 16))
    base = jax.random.normal(key, (1 << 16,))
    mask = jnp.ones((32,), bool)
    wts = jnp.ones((32,))
    t_ref = _time(
        jax.jit(lambda *a: fedavg_apply_ref(*a)), upd, base, mask, wts
    )
    rows.append(Row("kernels/fedavg_32x64k", t_ref, fmt(oracle_us=t_ref)))

    # delta pipeline: fused single-buffer pass vs the unfused per-stage
    # per-leaf chain (per-client clip → staleness-discounted Eq. 6
    # aggregate → DP → momentum apply over a 5-leaf tree). Both are the
    # CPU (XLA) oracle implementations — the Pallas kernel itself is a
    # TPU path; its interpret mode is a correctness tool, not perf.
    from repro.core.aggregation import fedavg_stacked
    from repro.optim import clip_by_global_norm

    seg_sizes = (1 << 15, 1 << 14, 1 << 14, 1 << 13, 1 << 13)
    p_total = sum(seg_sizes)
    c = 32
    upd = jax.random.normal(key, (c, p_total))
    base = jax.random.normal(key, (p_total,))
    mu = jnp.zeros((p_total,))
    noise = 0.1 * jax.random.normal(key, (p_total,))
    mask = jnp.ones((c,), bool)
    wts = jnp.ones((c,))
    stal = jnp.arange(c, dtype=jnp.float32) % 4
    kw = dict(
        lr=0.9, dp_noise=noise, momentum=mu, clip_norm=1.0,
        staleness=stal, staleness_exponent=0.5,
        server_optimizer="fedavgm",
    )
    t_fused = _time(
        jax.jit(
            lambda u, b, m, w: delta_pipeline_ref(u, b, m, w, **kw)[0]
        ),
        upd, base, mask, wts,
    )
    offs = [0]
    for s in seg_sizes:
        offs.append(offs[-1] + s)

    def unfused(u, b, m, w):
        tree = {
            f"l{i}": u[:, offs[i]:offs[i + 1]]
            for i in range(len(seg_sizes))
        }
        tree = jax.vmap(lambda d: clip_by_global_norm(d, 1.0)[0])(tree)
        disc = (1.0 + stal) ** -0.5
        agg = fedavg_stacked(tree, m, w * disc)
        sized = m * w
        scale = (jnp.sum(sized * disc) + 1e-12) / (jnp.sum(sized) + 1e-12)
        cat = jnp.concatenate(
            [agg[f"l{i}"] for i in range(len(seg_sizes))]
        ) * scale
        mu2 = 0.9 * mu + (cat + noise)
        return b + 0.9 * mu2

    t_unfused = _time(jax.jit(unfused), upd, base, mask, wts)
    rows.append(
        Row(
            "kernels/delta_pipeline_32x96k",
            t_fused,
            fmt(fused_us=t_fused, unfused_us=t_unfused,
                speedup=t_unfused / max(t_fused, 1e-9)),
        )
    )

    # sharded delta pipeline: shard_map + per-shard partial kernel + one
    # psum on an 8-fake-device mesh, vs the single-device fused kernel on
    # the same (32, 32k) buffer. Subprocess: the fake-device flag must be
    # set before jax initializes (this process is already single-device).
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-m",
         "repro.kernels.delta_pipeline.sharded_selftest",
         "--json", "--bench", "--devices", "8"],
        capture_output=True, text=True, env=env, cwd=repo, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded selftest rc={proc.returncode}: {proc.stderr[-500:]}"
        )
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    b = res["bench"]
    rows.append(
        Row(
            "kernels/delta_pipeline_sharded",
            b["sharded_us"],
            fmt(sharded_us=b["sharded_us"], unsharded_us=b["unsharded_us"],
                c=b["c"], p=b["p"], devices=res["devices"],
                gate_matrix_ok=res["ok"]),
        )
    )
    return rows
