"""Shared benchmark plumbing.

Every benchmark module exposes ``run() -> list[Row]``; benchmarks/run.py
aggregates and prints the ``name,us_per_call,derived`` CSV. Scale with
REPRO_BENCH_SCALE=quick|default|full (clients/rounds grow accordingly).
"""
from __future__ import annotations

import dataclasses
import os
import time

SCALE = os.environ.get("REPRO_BENCH_SCALE", "default")

PRESETS = {
    "quick": dict(clients=16, rounds=8, seeds=1, topk=8),
    "default": dict(clients=32, rounds=20, seeds=2, topk=12),
    "full": dict(clients=64, rounds=50, seeds=5, topk=24),
}


def preset() -> dict:
    return dict(PRESETS[SCALE])


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed_rounds(sim, rounds: int):
    """Run a simulator (per-round loop engine), returning (history, us_per_round)."""
    t0 = time.time()
    h = sim.run(rounds)
    dt = time.time() - t0
    return h, dt / rounds * 1e6


def timed_sweep(cfg, seeds, *, axes=None, cases=None, rounds=None,
                devices=None):
    """Run a vmapped/scanned sweep, returning (SweepResult, us_per_sim_round).

    us_per_sim_round amortizes wall-clock over every simulated round
    (grid points × seeds × rounds) — directly comparable to the
    ``timed_rounds`` number of the per-round loop engine.

    ``devices`` is forwarded to ``run_sweep(devices=...)``: pass an int N
    (or a device list) to shard the vmapped seed batch across N local
    devices, so each runs |seeds|/N simulations in parallel — per-seed
    results are unchanged (verified bit-identical by
    test_sweep_devices_sharding_bit_identical in
    tests/test_simulator_engine.py). Default None keeps one device.
    """
    from repro.sim import run_sweep

    t0 = time.time()
    res = run_sweep(cfg, seeds, axes=axes, cases=cases, rounds=rounds,
                    devices=devices)
    dt = time.time() - t0
    sim_rounds = len(res.configs) * len(res.seeds) * res.rounds
    return res, dt / max(sim_rounds, 1) * 1e6


def fmt(**kv) -> str:
    parts = []
    for k, v in kv.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)
