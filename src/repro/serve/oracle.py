"""Sequential per-request serving oracle.

The slow reference for the continuous-batching engine (oracle
discipline): serve the trace one request at a time — prefill, then
single-slot greedy decode to the request's length — with the SAME
§IV.F cost accounting. Two contracts hang off it:

  * correctness: the engine's ``attn="dense"`` path must reproduce this
    oracle's tokens exactly. The oracle's contiguous ``cache_len`` is
    deliberately ``PagePlan.cache_len`` (= page-table width x page size),
    so the engine's gathered attention reduces over identically-shaped
    operands and the match is bitwise, not approximate;
  * performance: ``benchmarks/serving.py`` measures the continuous
    engine's wall-clock tokens/sec against this baseline (the >= 2x
    acceptance gate) — the oracle keeps its tokens device-resident in
    the same ``(R+1, max_gen)`` buffer with one terminal sync, so the
    comparison isn't rigged by host transfers.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.config import Family
from repro.models.transformer import Runtime
from repro.serve.arrivals import RequestTrace
from repro.serve.costs import ServeCostModel
from repro.serve.engine import EngineConfig, ServeReport
from repro.serve.paged import PagePlan, check_family


class SequentialOracle:
    """One-request-at-a-time reference server (batch = 1, no slots)."""

    def __init__(
        self,
        model: Model,
        params,
        cfg: EngineConfig = EngineConfig(),
        cost: ServeCostModel = ServeCostModel(),
        runtime: Runtime = Runtime(),
    ):
        check_family(model.cfg)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cost = cost
        self.plan = PagePlan.build(
            model.cfg, cfg.prompt_len, cfg.max_gen,
            page_size=cfg.page_size, n_patches=cfg.n_patches,
        )
        self.is_vlm = model.cfg.family is Family.VLM
        plan = self.plan

        def prefill(params, batch, out_buf, req):
            logits, cache = model.prefill(
                params, batch, cache_len=plan.cache_len, runtime=runtime
            )
            first = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            out_buf = out_buf.at[req, 0].set(first)
            return cache, first[None, None], out_buf

        def step(params, cache, tok, out_buf, req, idx):
            logits, cache = model.decode_step(params, cache, tok, runtime)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (1,)
            out_buf = out_buf.at[req, idx].set(nxt[0])
            return cache, nxt[:, None], out_buf

        buf_aval = jax.ShapeDtypeStruct(
            (cfg.max_requests + 1, cfg.max_gen), jnp.int32
        )
        i32 = jnp.int32
        batch_avals = {
            "tokens": jax.ShapeDtypeStruct((1, plan.prompt_len), i32)
        }
        if self.is_vlm:
            batch_avals["patch_embeds"] = jax.ShapeDtypeStruct(
                (1, plan.n_patches, model.cfg.d_model),
                jnp.dtype(model.cfg.compute_dtype),
            )
        self._prefill = (
            jax.jit(prefill, donate_argnums=(2,))
            .lower(params, batch_avals, buf_aval,
                   jax.ShapeDtypeStruct((), i32))
            .compile()
        )
        cache_avals = jax.eval_shape(
            lambda: model.init_cache(1, plan.cache_len)
        )
        self._step = (
            jax.jit(step, donate_argnums=(1, 3))
            .lower(params, cache_avals,
                   jax.ShapeDtypeStruct((1, 1), i32), buf_aval,
                   jax.ShapeDtypeStruct((), i32),
                   jax.ShapeDtypeStruct((), i32))
            .compile()
        )
        self.n_compiles = {"prefill": 1, "decode": 1}

    # ------------------------------------------------------------------ #
    def serve(self, trace: RequestTrace) -> ServeReport:
        cfg, plan, cost = self.cfg, self.plan, self.cost
        r = trace.n_requests
        if r > cfg.max_requests:
            raise ValueError(f"trace of {r} > max_requests={cfg.max_requests}")
        out_buf = jnp.zeros((cfg.max_requests + 1, cfg.max_gen), jnp.int32)
        vclock = 0.0
        last_busy = -math.inf
        latency = np.full((r,), np.nan)
        fpt = self.model.flops_per_token(train=False)
        prompt_flops = fpt * plan.prompt_eff
        energy = 0.0
        cold_starts = decode_steps = tokens_generated = 0
        slo_violations = 0

        wall0 = time.perf_counter()
        for req in range(r):  # trace arrival times are nondecreasing
            arrival = float(trace.arrival_ms[req])
            start = max(vclock, arrival)
            warm = (start - last_busy) <= cost.keep_alive_ms
            batch = {"tokens": trace.prompts[req][None]}
            if self.is_vlm:
                batch["patch_embeds"] = trace.patch_embeds[req][None]
            cache, tok, out_buf = self._prefill(
                self.params, batch, out_buf, np.int32(req)
            )
            vclock = start + cost.prefill_ms(prompt_flops, warm)
            energy += cost.prefill_energy_j(prompt_flops, warm)
            cold_starts += not warm
            tokens_generated += 1
            for i in range(1, int(trace.gen_len[req])):
                cache, tok, out_buf = self._step(
                    self.params, cache, tok, out_buf,
                    np.int32(req), np.int32(i),
                )
                decode_steps += 1
                tokens_generated += 1
                vclock += cost.decode_step_ms(fpt)
                energy += cost.step_energy_j(fpt, 1)
            latency[req] = vclock - arrival
            slo_violations += latency[req] > trace.slo_ms
            last_busy = vclock

        tokens_np = np.asarray(jax.block_until_ready(out_buf))[: r]
        wall = time.perf_counter() - wall0
        lat_done = latency[~np.isnan(latency)]
        pct = {
            f"p{p}": float(np.percentile(lat_done, p)) if lat_done.size else float("nan")
            for p in (50, 95, 99)
        }
        in_slo = int(np.sum(lat_done <= trace.slo_ms))
        vsec = max(vclock / 1e3, 1e-9)
        return ServeReport(
            n_requests=r,
            completed=r,
            rejected=0,
            slo_violations=slo_violations,
            tokens_generated=tokens_generated,
            decode_steps=decode_steps,
            prefills=r,
            cold_starts=cold_starts,
            virtual_ms=vclock,
            wall_s=wall,
            latency_ms=latency,
            percentiles=pct,
            goodput_rps=in_slo / vsec,
            tokens_per_s=tokens_generated / vsec,
            tokens_per_wall_s=tokens_generated / max(wall, 1e-9),
            energy_j=energy,
            energy_per_token_j=energy / max(tokens_generated, 1),
            n_compiles=dict(self.n_compiles),
            counters=dict(
                arrived=r, completed=r, rejected=0, in_flight=0, waiting=0
            ),
            tokens=tokens_np,
            gen_len=trace.gen_len.copy(),
        )
