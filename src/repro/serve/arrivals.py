"""Request arrival process for the serving engine, on the DES event queue.

Arrivals are a (possibly diurnally-modulated) Poisson process: the
inter-arrival gap after time ``t`` is Exp(rate(t)) with

    rate(t) = rate_per_s * (1 + diurnal_amp * sin(2π t / period))

— the same sinusoidal availability shape the population-scale cohort
sampler uses for client churn, now driving inference traffic. Each
request gets a prompt, a generation length and an SLO deadline, and is
pushed into the shared ``sim.events`` queue as a ``KIND_ARRIVE`` event
whose payload is the request id. The engine pops arrivals against its
virtual clock exactly like the async FL engine pops completions — one
queue implementation serves both training and serving traffic.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.events.queue import KIND_ARRIVE, EventQueue, make_queue, push_events


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 32
    rate_per_s: float = 20.0  # mean arrival rate (virtual seconds)
    diurnal_amp: float = 0.0  # 0..1 sinusoidal rate modulation
    diurnal_period_ms: float = 60_000.0
    slo_ms: float = 4_000.0  # per-request completion deadline
    prompt_len: int = 16
    min_gen: int = 4
    max_gen: int = 16  # inclusive; also sizes the slot span


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """One materialized arrival trace (host metadata + device prompts)."""

    arrival_ms: np.ndarray  # (R,) f64, nondecreasing
    gen_len: np.ndarray  # (R,) i64 in [min_gen, max_gen]
    slo_ms: float
    prompts: np.ndarray  # (R, prompt_len) i32, host-resident: per-request
    # rows feed compiled admit/prefill calls, so slicing must be a cheap
    # numpy view rather than an eager device gather in the serve loop
    patch_embeds: np.ndarray | None  # (R, n_patches, d) for VLM archs
    queue: EventQueue  # KIND_ARRIVE events, payload = request id

    @property
    def n_requests(self) -> int:
        return int(self.arrival_ms.shape[0])

    def deadline_ms(self, req: int) -> float:
        return float(self.arrival_ms[req]) + self.slo_ms


def _arrival_times(u: np.ndarray, cfg: TraceConfig) -> np.ndarray:
    """Inverse-CDF Poisson thinning with a time-varying rate."""
    t = 0.0
    out = np.empty(len(u), np.float64)
    for i, ui in enumerate(u):
        rate = cfg.rate_per_s * (
            1.0
            + cfg.diurnal_amp
            * math.sin(2.0 * math.pi * t / cfg.diurnal_period_ms * 1e3)
        )
        rate = max(rate, 1e-6)
        t += -math.log(max(1.0 - ui, 1e-12)) / rate * 1e3  # gap in ms
        out[i] = t
    return out


def make_trace(
    key: jax.Array, cfg: TraceConfig, model_cfg=None, n_patches: int = 8
) -> RequestTrace:
    """Sample a reproducible request trace for ``model_cfg`` (or a generic
    256-vocab one when no model config is given)."""
    k_arr, k_gen, k_tok, k_img = jax.random.split(key, 4)
    r = cfg.n_requests
    u = np.asarray(jax.random.uniform(k_arr, (r,)), np.float64)
    arrival = _arrival_times(u, cfg)
    gen = np.asarray(
        jax.random.randint(k_gen, (r,), cfg.min_gen, cfg.max_gen + 1)
    ).astype(np.int64)

    vocab = int(model_cfg.vocab_size) if model_cfg is not None else 256
    prompts = np.asarray(
        jax.random.randint(k_tok, (r, cfg.prompt_len), 0, vocab, dtype=jnp.int32)
    )
    patch_embeds = None
    if model_cfg is not None and getattr(model_cfg.family, "name", "") == "VLM":
        patch_embeds = np.asarray(
            jax.random.normal(k_img, (r, n_patches, model_cfg.d_model)).astype(
                model_cfg.compute_dtype
            )
        )

    q = make_queue(r)
    q = push_events(
        q,
        times=jnp.asarray(arrival, jnp.float32),
        clients=jnp.arange(r, dtype=jnp.int32),
        kinds=jnp.full((r,), KIND_ARRIVE, jnp.int32),
        payloads=jnp.arange(r, dtype=jnp.float32),
        mask=jnp.ones((r,), bool),
    )
    return RequestTrace(
        arrival_ms=arrival,
        gen_len=gen,
        slo_ms=float(cfg.slo_ms),
        prompts=prompts,
        patch_embeds=patch_embeds,
        queue=q,
    )
