"""Host-side control plane: slot scheduler + physical page allocator.

Pure-python bookkeeping (the MaxText offline-engine pattern): all device
state is fixed-shape, so admission / eviction decisions live here and
only ever *index* into the compiled programs. The scheduler maintains a
conservation invariant checked by tests and the CI smoke:

    arrived == completed + rejected + in_flight + waiting

Queue policies:

    fifo — admit in arrival order.
    edf  — earliest-deadline-first: the waiting request with the nearest
           SLO deadline fills the next free slot (deadline-aware
           counterpart of the FedFog priority-queue scheduler).
"""
from __future__ import annotations

import dataclasses


class PageAllocator:
    """Free-list over the physical page pool. Page 0 is reserved as the
    trash page (masked writes from inactive slots land there)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages, 0, -1))  # pop() yields 1,2,...

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 < p <= self.num_pages, p
            self._free.append(p)


@dataclasses.dataclass
class SlotState:
    """Host mirror of one device slot."""

    req: int = -1
    pages: list[int] = dataclasses.field(default_factory=list)
    remaining: int = 0  # decode tokens still to produce
    deadline_ms: float = 0.0


class SlotScheduler:
    """Admission + slot assignment with conservation counters."""

    def __init__(self, slots: int, max_queue: int = 0, policy: str = "fifo"):
        if policy not in ("fifo", "edf"):
            raise ValueError(f"unknown queue policy {policy!r}")
        self.slots = [SlotState() for _ in range(slots)]
        self.max_queue = max_queue  # 0 = unbounded
        self.policy = policy
        self.waiting: list[tuple[int, float]] = []  # (req, deadline_ms)
        self.free_slots = list(range(slots - 1, -1, -1))
        self.arrived = 0
        self.admitted = 0
        self.rejected = 0
        self.completed = 0

    # -- counters ------------------------------------------------------ #
    @property
    def in_flight(self) -> int:
        return len(self.slots) - len(self.free_slots)

    def conservation(self) -> dict[str, int]:
        c = dict(
            arrived=self.arrived,
            completed=self.completed,
            rejected=self.rejected,
            in_flight=self.in_flight,
            waiting=len(self.waiting),
        )
        assert c["arrived"] == (
            c["completed"] + c["rejected"] + c["in_flight"] + c["waiting"]
        ), f"slot conservation violated: {c}"
        return c

    # -- transitions --------------------------------------------------- #
    def on_arrival(self, req: int, deadline_ms: float) -> bool:
        """Returns False when the admission queue is full (rejected)."""
        self.arrived += 1
        if self.max_queue and len(self.waiting) >= self.max_queue:
            self.rejected += 1
            return False
        self.waiting.append((req, deadline_ms))
        if self.policy == "edf":
            self.waiting.sort(key=lambda rd: (rd[1], rd[0]))
        return True

    def next_fill(self) -> tuple[int, float] | None:
        """Peek the request that should fill the next free slot."""
        if not self.waiting or not self.free_slots:
            return None
        return self.waiting[0]

    def on_insert(self, req: int, pages: list[int], remaining: int,
                  deadline_ms: float) -> int:
        """Commit the peeked request into a slot; returns the slot id."""
        head, _ = self.waiting.pop(0)
        assert head == req, (head, req)
        slot = self.free_slots.pop()
        self.slots[slot] = SlotState(req, pages, remaining, deadline_ms)
        self.admitted += 1
        return slot

    def on_complete(self, slot: int) -> SlotState:
        """Evict a finished slot; caller frees ``state.pages``."""
        state = self.slots[slot]
        assert state.req >= 0, slot
        self.slots[slot] = SlotState()
        self.free_slots.append(slot)
        self.completed += 1
        return state
