"""Arrival-rate sweeps over the serving engine (compile-once discipline).

Mirrors ``sim/sweep.py``'s contract at the serving layer: the swept
quantity (offered load) is trace DATA, never program structure, so one
``ContinuousBatchingEngine`` — two AOT executables — serves the entire
grid. ``sweep_rates`` asserts ``n_compiles`` is unchanged afterwards,
which is the same "grid rides one executable" property the round-sweep
subsystem enforces for lifted scheduler/cost numerics.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.serve.arrivals import TraceConfig, make_trace
from repro.serve.engine import ContinuousBatchingEngine, ServeReport


@dataclasses.dataclass(frozen=True)
class SweepServeResult:
    rates_per_s: np.ndarray  # (G,)
    reports: list[ServeReport]

    def column(self, name: str) -> np.ndarray:
        """(G,) array of one scalar report field (e.g. 'goodput_rps')."""
        vals = []
        for rep in self.reports:
            v = getattr(rep, name)
            vals.append(v["p95"] if name == "percentiles" else v)
        return np.asarray(vals, np.float64)


def sweep_rates(
    engine: ContinuousBatchingEngine,
    trace_cfg: TraceConfig,
    rates_per_s,
    seed: int = 0,
) -> SweepServeResult:
    """Serve one trace per offered load; one compile for the whole grid."""
    before = dict(engine.n_compiles)
    reports = []
    for g, rate in enumerate(rates_per_s):
        cfg = dataclasses.replace(trace_cfg, rate_per_s=float(rate))
        trace = make_trace(
            jax.random.PRNGKey(seed + g), cfg, engine.model.cfg,
            n_patches=engine.plan.n_patches or 8,
        )
        reports.append(engine.serve(trace))
    assert engine.n_compiles == before, (
        f"arrival-rate sweep recompiled: {before} -> {engine.n_compiles}"
    )
    return SweepServeResult(
        rates_per_s=np.asarray(list(rates_per_s), np.float64),
        reports=reports,
    )
