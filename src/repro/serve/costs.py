"""Virtual-clock cost model for the serving engine (§IV.F constants).

The continuous-batching loop is host-driven, so unlike the round engines
these helpers return plain floats — but every §IV.F constant (cold/warm
container delay, energy-per-flop, energy-per-byte, cold-start energy)
comes from the SAME ``FaasSimConfig`` via ``RoundCostModel``, so the
serving numbers cannot drift from the FL round accounting.

Timing model (single accelerator, MaxText-offline style):

  * a prefill is one serverless *invocation*: it pays the Eq. 4
    container delay (cold when the engine sat idle past ``keep_alive_ms``,
    warm otherwise) plus prompt compute, and preempts decode — the
    engine serializes prefill between decode steps.
  * a decode step costs a fixed weight-streaming overhead (decode is
    memory-bound: the whole parameter set crosses HBM once per step
    regardless of batch) plus the active slots' marginal flops. This is
    what makes continuous batching pay off in *virtual* time as well as
    wall time: S slots share one weight stream.
"""
from __future__ import annotations

import dataclasses

from repro.sim.des import FaasSimConfig, RoundCostModel


@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Virtual latency/energy for serving, on top of ``RoundCostModel``."""

    cost: RoundCostModel = dataclasses.field(default_factory=RoundCostModel)
    flops_per_s: float = 1e12  # accelerator throughput (sim units)
    step_overhead_ms: float = 5.0  # per-decode-step weight streaming floor
    keep_alive_ms: float = 500.0  # container cache window (Eq. 4 gate)
    tx_bytes_per_token: float = 8.0  # tokens streamed back to the client

    @classmethod
    def from_faas(cls, cfg: FaasSimConfig, **kw) -> "ServeCostModel":
        return cls(cost=RoundCostModel(cfg), **kw)

    # -- latency ------------------------------------------------------- #
    def prefill_ms(self, prompt_flops: float, warm: bool) -> float:
        """One admission: container delay (Eq. 4) + prompt compute."""
        return self.cost.invocation_delay_ms(warm) + (
            prompt_flops / self.flops_per_s * 1e3
        )

    def decode_step_ms(self, active_flops: float) -> float:
        """One batched decode step over however many slots are live."""
        return self.step_overhead_ms + active_flops / self.flops_per_s * 1e3

    # -- energy -------------------------------------------------------- #
    def prefill_energy_j(self, prompt_flops: float, warm: bool) -> float:
        e = self.cost.token_energy_j(prompt_flops)
        return e if warm else e + self.cost.cold_start_energy_j()

    def step_energy_j(self, active_flops: float, n_tokens: int) -> float:
        """Compute + per-token egress for one decode step (§IV.F E_i)."""
        return self.cost.token_energy_j(
            active_flops, tx_bytes=self.tx_bytes_per_token * n_tokens
        )
