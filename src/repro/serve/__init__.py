"""Continuous-batching serving engine over the paged decode state.

Layering:

    arrivals.py  — Poisson/diurnal request traces as KIND_ARRIVE events
                   on the shared ``sim.events`` queue.
    scheduler.py — host control plane: slot scheduler (fifo/edf, slot
                   conservation counters) + physical page allocator.
    paged.py     — device state & compiled programs: paged KV pool,
                   admission (prefill -> page scatter), the ONE batched
                   decode step (dense gather or Pallas paged kernel).
    costs.py     — §IV.F virtual latency/energy on ``RoundCostModel``.
    engine.py    — ``ContinuousBatchingEngine``: the prefill -> insert ->
                   generate loop, two AOT executables per structure.
    oracle.py    — ``SequentialOracle``: per-request reference the engine
                   must reproduce token-for-token (dense path).
    sweep.py     — arrival-rate grids under the compile-once discipline.
"""
from repro.serve.arrivals import RequestTrace, TraceConfig, make_trace
from repro.serve.costs import ServeCostModel
from repro.serve.engine import ContinuousBatchingEngine, EngineConfig, ServeReport
from repro.serve.oracle import SequentialOracle
from repro.serve.paged import PagePlan
from repro.serve.scheduler import PageAllocator, SlotScheduler
from repro.serve.sweep import SweepServeResult, sweep_rates

__all__ = [
    "ContinuousBatchingEngine",
    "EngineConfig",
    "PageAllocator",
    "PagePlan",
    "RequestTrace",
    "SequentialOracle",
    "ServeCostModel",
    "ServeReport",
    "SlotScheduler",
    "SweepServeResult",
    "TraceConfig",
    "make_trace",
    "sweep_rates",
]
