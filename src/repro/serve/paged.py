"""Device-side paged slot state + the compiled serving programs.

Everything here is shape-static so the engine AOT-compiles exactly two
executables per (model, slot-count) structure:

  * ``make_admit_fn``  — prefill one request (batch=1), scatter its
    prompt KV into the physical page pool at host-chosen page ids, seed
    the slot's next-token and the request's output row. One program per
    admission, reused for every request (page ids / slot / request id
    are traced operands).
  * ``make_decode_fn`` — ONE batched decode step over all S slots:
    per-slot positions, per-slot RoPE, KV writes routed through the page
    table (inactive slots write to the reserved trash page 0), ragged
    attention over the paged pool, greedy argmax, and token scatter into
    the device-resident output buffer (inactive slots land in the trash
    row). The output buffer is only synced to host ONCE, after the whole
    trace — the decode loop never materializes tokens host-side.

Attention modes:

  * ``dense`` — gather each slot's pages into a contiguous cache and run
    ``models.layers.attention_decode``. Because the gathered width equals
    the sequential oracle's ``cache_len`` and masked rows contribute
    exact zeros, this path reproduces the per-request decode
    *token-for-token* (the serving correctness contract).
  * ``paged`` — the Pallas paged flash-decode kernel: the page gather
    rides the BlockSpec index_map in the HBM pass, no gathered cache is
    materialized. fp32-tolerance vs. dense (online softmax reassociates).

Family support: DENSE / MOE / VLM / HYBRID route through the paged KV
pool (HYBRID adds slot-indexed SSM/conv states); SSM (rwkv6) has O(1)
recurrent state, so its "pool" is just the slot-indexed state and both
attention modes are no-ops. ENCDEC is rejected (its cross-attention
source cache is per-request ragged in a second axis).

Token-exactness note: MoE routing is batch-coupled (capacity grouping
across the slot batch), so MOE family serves correctly but is excluded
from the token-for-token contract — documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import gather_pages
from repro.models import transformer as tf
from repro.models import rwkv6
from repro.models.api import Model
from repro.models.config import Family, ModelConfig
from repro.models.layers import attention_decode, rms_norm
from repro.models.transformer import Runtime, static_layer_meta

Array = jax.Array

ATTN_MODES = ("dense", "paged")


def check_family(cfg: ModelConfig) -> None:
    if cfg.family is Family.ENCDEC:
        raise NotImplementedError(
            "continuous batching does not cover ENCDEC: the cross-attention "
            "source cache is per-request ragged in a second axis"
        )


@dataclasses.dataclass(frozen=True)
class PagePlan:
    """Static paging geometry shared by engine, oracle and tests."""

    page_size: int
    prompt_len: int  # text tokens per request (static prefill shape)
    n_patches: int  # VLM frontend embeddings prepended at prefill
    max_gen: int  # per-request generation cap (sizes the slot span)

    @property
    def prompt_eff(self) -> int:
        """Cached positions after prefill (text + vision tokens)."""
        return self.prompt_len + self.n_patches

    @property
    def span(self) -> int:
        return self.prompt_eff + self.max_gen

    @property
    def pages_per_slot(self) -> int:
        """Page-table width; also fixes the oracle's cache_len (= width *
        page_size) so dense-path reductions match the oracle bitwise."""
        return -(-self.span // self.page_size)

    @property
    def prompt_pages(self) -> int:
        return -(-self.prompt_eff // self.page_size)

    @property
    def cache_len(self) -> int:
        return self.pages_per_slot * self.page_size

    def pages_for_gen(self, gen_len: int) -> int:
        """Physical pages a request with ``gen_len`` decode tokens needs."""
        return -(-(self.prompt_eff + int(gen_len)) // self.page_size)

    @classmethod
    def build(
        cls, cfg: ModelConfig, prompt_len: int, max_gen: int,
        page_size: int = 16, n_patches: int = 8,
    ) -> "PagePlan":
        check_family(cfg)
        return cls(
            page_size=page_size,
            prompt_len=prompt_len,
            n_patches=n_patches if cfg.family is Family.VLM else 0,
            max_gen=max_gen,
        )


# --------------------------------------------------------------------- #
# Pool construction
# --------------------------------------------------------------------- #
def init_pool(
    cfg: ModelConfig, plan: PagePlan, slots: int, num_pages: int, dtype=None
):
    """Fixed-shape device state. Physical page 0 is the trash page, so the
    k/v pools carry ``num_pages + 1`` physical rows."""
    check_family(cfg)
    if cfg.family is Family.SSM:
        pool = rwkv6.init_cache(cfg, slots, 0)
        pool.pop("pos")  # per-slot positions are host state in serving
        return pool
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    pool = {
        "k": jnp.zeros((L, num_pages + 1, plan.page_size, Hkv, hd), dtype),
        "v": jnp.zeros((L, num_pages + 1, plan.page_size, Hkv, hd), dtype),
    }
    if cfg.family is Family.HYBRID:
        pool["ssm_state"] = jnp.zeros(
            (L, slots, cfg.d_inner, cfg.ssm_state), jnp.float32
        )
        pool["conv_state"] = jnp.zeros(
            (L, slots, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32
        )
    return pool


# --------------------------------------------------------------------- #
# Admission program: prefill -> page scatter -> slot seed
# --------------------------------------------------------------------- #
def make_admit_fn(model: Model, plan: PagePlan, runtime: Runtime = Runtime()):
    """Returns ``admit(params, pool, tokens, out_buf, prompt, [embeds,]
    pages, slot, req) -> (pool, tokens, out_buf)``.

    ``prompt`` is (1, prompt_len) int32; ``pages`` is (prompt_pages,)
    int32 physical page ids; ``slot``/``req`` are scalars. VLM models
    take the extra ``embeds`` (1, n_patches, d) operand.
    """
    cfg = model.cfg
    check_family(cfg)
    is_vlm = cfg.family is Family.VLM
    # Prefill chunks the prompt KV into whole pages; padding beyond the
    # prompt is zeros, overwritten in place once decode reaches it.
    prefill_len = plan.prompt_pages * plan.page_size

    def admit(params, pool, tokens, out_buf, prompt, *rest):
        if is_vlm:
            embeds, pages, slot, req = rest
            batch = {"tokens": prompt, "patch_embeds": embeds}
        else:
            pages, slot, req = rest
            batch = {"tokens": prompt}
        logits, cache = model.prefill(
            params, batch, cache_len=prefill_len, runtime=runtime
        )
        first = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
        if cfg.family is Family.SSM:
            pool = dict(pool)
            for key in ("wkv", "tm_x", "cm_x"):
                pool[key] = pool[key].at[:, slot].set(cache[key][:, 0])
        else:
            L = cfg.num_layers
            shape = (L, plan.prompt_pages, plan.page_size) + cache["k"].shape[3:]
            pool = dict(pool)
            pool["k"] = pool["k"].at[:, pages].set(cache["k"][:, 0].reshape(shape))
            pool["v"] = pool["v"].at[:, pages].set(cache["v"][:, 0].reshape(shape))
            if cfg.family is Family.HYBRID:
                pool["ssm_state"] = (
                    pool["ssm_state"].at[:, slot].set(cache["ssm_state"][:, 0])
                )
                pool["conv_state"] = (
                    pool["conv_state"].at[:, slot].set(cache["conv_state"][:, 0])
                )
        tokens = tokens.at[slot, 0].set(first)
        out_buf = out_buf.at[req, 0].set(first)
        return pool, tokens, out_buf

    return admit


# --------------------------------------------------------------------- #
# The one batched decode step
# --------------------------------------------------------------------- #
def _paged_transformer_step(
    params, cfg: ModelConfig, plan: PagePlan, pool, tokens, page_table,
    positions, active, runtime: Runtime, attn: str, interpret,
):
    """Slot-batched analogue of ``transformer.decode_step``: scalar
    ``cache["pos"]`` becomes per-slot ``positions`` and the contiguous
    cache becomes the page pool. Row-independent ops otherwise identical,
    which is what makes the dense path bitwise-match the oracle."""
    s = tokens.shape[0]
    page = plan.page_size
    x = tf.embed_inputs(params, cfg, tokens=tokens)  # (S, 1, d)
    pos2 = positions[:, None]  # (S, 1) per-slot RoPE positions
    arange = jnp.arange(s)
    # New-token KV target: the slot's current page, or trash page 0.
    tgt = jnp.where(active, page_table[arange, positions // page], 0)
    off = positions % page
    k_pool, v_pool = pool["k"], pool["v"]
    ss_all = pool.get("ssm_state")
    cs_all = pool.get("conv_state")

    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.rms_eps)
            k = rms_norm(k, lp["k_norm"], cfg.rms_eps)
        w_i, th_i = static_layer_meta(cfg, i)
        q = tf.apply_rope(q, pos2, th_i)
        k = tf.apply_rope(k, pos2, th_i)
        k_pool = k_pool.at[i, tgt, off].set(k[:, 0])
        v_pool = v_pool.at[i, tgt, off].set(v[:, 0])
        if attn == "paged":
            lengths = jnp.where(active, positions + 1, 0)
            out = paged_attention(
                q[:, 0], k_pool[i], v_pool[i], page_table, lengths, w_i,
                interpret=interpret,
            )[:, None]
        else:
            kg = gather_pages(k_pool[i], page_table)  # (S, cache_len, ...)
            vg = gather_pages(v_pool[i], page_table)
            out = attention_decode(q, kg, vg, positions, w_i)
        attn_out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        if cfg.family is Family.HYBRID:
            hs = rms_norm(x, lp["ssm_norm"], cfg.rms_eps)
            ssm_out, ss_new, cs_new = tf._ssm_decode_step(
                lp, cfg, hs, ss_all[i], cs_all[i]
            )
            ss_all = ss_all.at[i].set(ss_new)
            cs_all = cs_all.at[i].set(cs_new)
            attn_out = 0.5 * (attn_out + ssm_out)
        x = x + attn_out
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        x = x + tf._ffn_block(lp, cfg, h, runtime)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = tf._head_logits(params, cfg, x)  # (S, 1, V)
    pool = dict(pool, k=k_pool, v=v_pool)
    if cfg.family is Family.HYBRID:
        pool["ssm_state"], pool["conv_state"] = ss_all, cs_all
    return logits, pool


def make_decode_fn(
    model: Model,
    plan: PagePlan,
    runtime: Runtime = Runtime(),
    attn: str = "dense",
    interpret: bool | None = None,
):
    """Returns ``step(params, pool, tokens, out_buf, page_table, positions,
    active, out_req, out_idx) -> (pool, tokens, out_buf)`` — the single
    executable that serves the whole trace.

    ``out_req``/``out_idx`` route each slot's new token into the device
    output buffer; the host passes the trash row for inactive slots.
    """
    cfg = model.cfg
    check_family(cfg)
    if attn not in ATTN_MODES:
        raise ValueError(f"attn must be one of {ATTN_MODES}, got {attn!r}")

    def step(params, pool, tokens, out_buf, page_table, positions, active,
             out_req, out_idx):
        if cfg.family is Family.SSM:
            cache = dict(pool, pos=jnp.zeros((), jnp.int32))
            logits, cache = rwkv6.decode_step(params, cfg, cache, tokens)
            cache.pop("pos")
            pool = cache
        else:
            logits, pool = _paged_transformer_step(
                params, cfg, plan, pool, tokens, page_table, positions,
                active, runtime, attn, interpret,
            )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (S,)
        tokens = nxt[:, None]
        out_buf = out_buf.at[out_req, out_idx].set(nxt)
        return pool, tokens, out_buf

    return step
