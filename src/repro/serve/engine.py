"""Continuous-batching serving engine (slot scheduler + paged decode).

The MaxText MLPerf-offline serving shape, grown onto this repo's stack:

  * fixed-capacity SLOTS hold in-flight requests; device state
    (page pool, per-slot next-token, output buffer) is shape-static;
  * a prefill -> insert -> generate loop: finished slots are evicted and
    refilled MID-FLIGHT from the waiting queue without recompiling —
    exactly TWO AOT executables (admit, decode) serve the entire trace,
    and ``n_compiles`` is exported so tests/CI can assert the
    one-executable contract as slots churn;
  * request arrivals come from the shared ``sim.events`` queue
    (``KIND_ARRIVE``; Poisson/diurnal — see ``serve.arrivals``), popped
    against the engine's virtual clock like the async FL engine pops
    completions;
  * the virtual clock + §IV.F accounting (Eq. 4 cold/warm container
    delay on each admission, energy-per-token, cold-start energy) ride
    ``serve.costs.ServeCostModel`` on the same ``FaasSimConfig`` as the
    FL round engines;
  * generated tokens land in a device-resident ``(max_requests+1,
    max_gen)`` buffer via per-slot routing vectors — the host never syncs
    tokens during the loop; ONE terminal device->host transfer yields
    every request's output (`ServeReport.tokens`).

Correctness contract (tests/test_serving.py): with ``attn="dense"`` the
engine reproduces the sequential per-request oracle token-for-token on
non-MoE families; ``attn="paged"`` swaps in the Pallas paged
flash-decode kernel (fp32-tolerance logits, same greedy tokens).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.config import Family
from repro.models.transformer import Runtime
from repro.serve.arrivals import RequestTrace
from repro.serve.costs import ServeCostModel
from repro.serve.paged import PagePlan, init_pool, make_admit_fn, make_decode_fn
from repro.serve.scheduler import PageAllocator, SlotScheduler
from repro.sim.events.queue import peek_time, pop_event

# The queue ops run between compiled steps; jitted once (per queue
# capacity) they cost one dispatch instead of ~10 eager primitive binds —
# the arrival process must not tax the decode loop it drives. No donation:
# the first pop's operand is the trace's own queue, which must survive so
# one trace can be served repeatedly (oracle vs engine, timing reps).
_peek = jax.jit(peek_time)
_pop = jax.jit(pop_event)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 8
    page_size: int = 16
    prompt_len: int = 16
    max_gen: int = 16  # per-request generation cap (sizes slot span)
    max_requests: int = 256  # output-buffer rows; traces must fit
    num_pages: int = 0  # physical pool size; 0 = slots * pages_per_slot
    attn: str = "dense"  # "dense" (oracle-exact) | "paged" (Pallas kernel)
    policy: str = "fifo"  # waiting-queue order: "fifo" | "edf"
    max_queue: int = 0  # admission cap (0 = unbounded); over -> rejected
    n_patches: int = 8  # VLM frontend tokens per request


@dataclasses.dataclass
class ServeReport:
    """Everything one trace produced (host-side; device synced once)."""

    n_requests: int
    completed: int
    rejected: int
    slo_violations: int
    tokens_generated: int
    decode_steps: int
    prefills: int
    cold_starts: int
    virtual_ms: float
    wall_s: float
    latency_ms: np.ndarray  # (R,) NaN for rejected
    percentiles: dict[str, float]  # p50/p95/p99 over completed requests
    goodput_rps: float  # SLO-met completions per virtual second
    tokens_per_s: float  # virtual-time throughput
    tokens_per_wall_s: float  # wall-clock throughput (the benchmark axis)
    energy_j: float
    energy_per_token_j: float
    n_compiles: dict[str, int]
    counters: dict[str, int]
    tokens: np.ndarray  # (R, max_gen) int32; row r valid to gen_len[r]
    gen_len: np.ndarray  # (R,)

    def tokens_for(self, req: int) -> list[int]:
        return self.tokens[req, : int(self.gen_len[req])].tolist()


def _aval(x):
    return jax.ShapeDtypeStruct(x.shape, jnp.asarray(x).dtype)


class ContinuousBatchingEngine:
    """Slot-based continuous batching over a fixed page pool."""

    def __init__(
        self,
        model: Model,
        params,
        cfg: EngineConfig = EngineConfig(),
        cost: ServeCostModel = ServeCostModel(),
        runtime: Runtime = Runtime(),
        tap=None,
        interpret: bool | None = None,
    ):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cost = cost
        self.tap = tap
        self.plan = PagePlan.build(
            model.cfg, cfg.prompt_len, cfg.max_gen,
            page_size=cfg.page_size, n_patches=cfg.n_patches,
        )
        self.num_pages = cfg.num_pages or cfg.slots * self.plan.pages_per_slot
        if self.plan.pages_per_slot > self.num_pages:
            raise ValueError(
                f"pool of {self.num_pages} pages cannot hold one request "
                f"({self.plan.pages_per_slot} pages)"
            )
        self.is_vlm = model.cfg.family is Family.VLM
        self.is_ssm = model.cfg.family is Family.SSM

        s, plan = cfg.slots, self.plan
        pool_avals = jax.eval_shape(
            lambda: init_pool(model.cfg, plan, s, self.num_pages)
        )
        tok_aval = jax.ShapeDtypeStruct((s, 1), jnp.int32)
        buf_aval = jax.ShapeDtypeStruct(
            (cfg.max_requests + 1, cfg.max_gen), jnp.int32
        )
        i32 = jnp.int32

        admit = make_admit_fn(model, plan, runtime)
        admit_avals = [_aval(np.zeros((1, plan.prompt_len), np.int32))]
        if self.is_vlm:
            admit_avals.append(
                jax.ShapeDtypeStruct(
                    (1, plan.n_patches, model.cfg.d_model),
                    jnp.dtype(model.cfg.compute_dtype),
                )
            )
        admit_avals += [
            jax.ShapeDtypeStruct((plan.prompt_pages,), i32),
            jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((), i32),
        ]
        self._admit = (
            jax.jit(admit, donate_argnums=(1, 2, 3))
            .lower(params, pool_avals, tok_aval, buf_aval, *admit_avals)
            .compile()
        )
        step = make_decode_fn(model, plan, runtime, cfg.attn, interpret)
        step_avals = [
            jax.ShapeDtypeStruct((s, plan.pages_per_slot), i32),  # page_table
            jax.ShapeDtypeStruct((s,), i32),  # positions
            jax.ShapeDtypeStruct((s,), jnp.bool_),  # active
            jax.ShapeDtypeStruct((s,), i32),  # out_req
            jax.ShapeDtypeStruct((s,), i32),  # out_idx
        ]
        self._decode = (
            jax.jit(step, donate_argnums=(1, 2, 3))
            .lower(params, pool_avals, tok_aval, buf_aval, *step_avals)
            .compile()
        )
        # The one-executable contract: these never change after __init__.
        self.n_compiles = {"admit": 1, "decode": 1}

    # ------------------------------------------------------------------ #
    def decode_hlo_text(self) -> str:
        """Compiled decode HLO — launch/serve.py runs its collective
        census over this, same as the static path."""
        return self._decode.as_text()

    # ------------------------------------------------------------------ #
    def serve(self, trace: RequestTrace, max_steps: int = 0) -> ServeReport:
        cfg, plan, cost = self.cfg, self.plan, self.cost
        r = trace.n_requests
        if r > cfg.max_requests:
            raise ValueError(f"trace of {r} > max_requests={cfg.max_requests}")
        if trace.prompts.shape[1] != plan.prompt_len:
            raise ValueError("trace prompt_len != engine prompt_len")
        if int(trace.gen_len.max()) > plan.max_gen or int(trace.gen_len.min()) < 1:
            raise ValueError("trace gen_len outside [1, max_gen]")
        if plan.pages_for_gen(int(trace.gen_len.max())) > self.num_pages:
            raise ValueError("a request needs more pages than the pool holds")

        sched = SlotScheduler(cfg.slots, cfg.max_queue, cfg.policy)
        alloc = PageAllocator(self.num_pages)
        pool = init_pool(self.model.cfg, plan, cfg.slots, self.num_pages)
        tokens = jnp.zeros((cfg.slots, 1), jnp.int32)
        out_buf = jnp.zeros((cfg.max_requests + 1, cfg.max_gen), jnp.int32)

        n_tab = plan.pages_per_slot
        page_table = np.zeros((cfg.slots, n_tab), np.int32)
        positions = np.zeros((cfg.slots,), np.int32)
        active = np.zeros((cfg.slots,), bool)
        out_req = np.full((cfg.slots,), cfg.max_requests, np.int32)  # trash row
        out_idx = np.zeros((cfg.slots,), np.int32)

        queue = trace.queue
        vclock = 0.0
        last_busy = -math.inf  # first admission is always a cold start
        latency = np.full((r,), np.nan)
        fpt = self.model.flops_per_token(train=False)
        prompt_flops = fpt * plan.prompt_eff
        energy = 0.0
        cold_starts = prefills = decode_steps = tokens_generated = 0
        slo_violations = 0

        def finish(slot: int) -> None:
            nonlocal slo_violations
            st = sched.on_complete(slot)
            alloc.free(st.pages)
            latency[st.req] = vclock - float(trace.arrival_ms[st.req])
            slo_violations += vclock > st.deadline_ms
            page_table[slot] = 0
            positions[slot] = 0
            active[slot] = False
            out_req[slot] = cfg.max_requests
            out_idx[slot] = 0

        wall0 = time.perf_counter()
        while sched.completed + sched.rejected < r:
            # 1. Drain arrivals that are due at the current virtual time.
            while True:
                t = float(_peek(queue))
                if not t <= vclock:
                    break
                ev, queue = _pop(queue)
                req = int(ev.payload)
                sched.on_arrival(req, t + trace.slo_ms)
            # 2. Refill free slots from the waiting queue (policy order).
            while True:
                nxt = sched.next_fill()
                if nxt is None:
                    break
                req, deadline = nxt
                gen = int(trace.gen_len[req])
                pages = alloc.alloc(plan.pages_for_gen(gen))
                if pages is None:
                    break  # pool exhausted; retry after evictions
                warm = (vclock - last_busy) <= cost.keep_alive_ms
                slot = sched.on_insert(req, pages, gen - 1, deadline)
                row = np.zeros((n_tab,), np.int32)
                row[: len(pages)] = pages
                admit_args = [trace.prompts[req][None]]
                if self.is_vlm:
                    admit_args.append(trace.patch_embeds[req][None])
                pool, tokens, out_buf = self._admit(
                    self.params, pool, tokens, out_buf, *admit_args,
                    row[: plan.prompt_pages], np.int32(slot), np.int32(req),
                )
                vclock += cost.prefill_ms(prompt_flops, warm)
                energy += cost.prefill_energy_j(prompt_flops, warm)
                cold_starts += not warm
                prefills += 1
                tokens_generated += 1  # prefill emits the first token
                last_busy = vclock
                if sched.slots[slot].remaining == 0:
                    finish(slot)  # gen_len == 1: done at prefill
                    continue
                page_table[slot] = row
                positions[slot] = plan.prompt_eff
                active[slot] = True
                out_req[slot] = req
                out_idx[slot] = 1
            # 3. Idle: jump the clock to the next arrival.
            if not active.any():
                t = float(_peek(queue))
                if math.isinf(t):
                    assert not sched.waiting, "stuck with waiting requests"
                    continue  # loop condition decides termination
                vclock = max(vclock, t)
                continue
            # 4. One batched decode step — THE compiled executable.
            pool, tokens, out_buf = self._decode(
                self.params, pool, tokens, out_buf,
                page_table, positions, active, out_req, out_idx,
            )
            n_active = int(active.sum())
            decode_steps += 1
            tokens_generated += n_active
            vclock += cost.decode_step_ms(fpt * n_active)
            energy += cost.step_energy_j(fpt * n_active, n_active)
            last_busy = vclock
            if self.tap is not None:
                self.tap.host_log(
                    {
                        "virtual_ms": vclock,
                        "active_slots": n_active,
                        "waiting": len(sched.waiting),
                        "completed": sched.completed,
                        "tokens_generated": tokens_generated,
                        "energy_j": energy,
                    },
                    step=decode_steps,
                )
            # 5. Advance live slots; evict the finished ones.
            for slot in np.nonzero(active)[0]:
                positions[slot] += 1
                out_idx[slot] += 1
                st = sched.slots[slot]
                st.remaining -= 1
                if st.remaining == 0:
                    finish(int(slot))
            if max_steps and decode_steps >= max_steps:
                break

        # ONE terminal device->host sync for every request's tokens.
        tokens_np = np.asarray(jax.block_until_ready(out_buf))[: r]
        wall = time.perf_counter() - wall0

        counters = sched.conservation()
        done = ~np.isnan(latency)
        lat_done = latency[done]
        pct = {
            f"p{p}": float(np.percentile(lat_done, p)) if lat_done.size else float("nan")
            for p in (50, 95, 99)
        }
        in_slo = int(np.sum(lat_done <= trace.slo_ms)) if lat_done.size else 0
        vsec = max(vclock / 1e3, 1e-9)
        return ServeReport(
            n_requests=r,
            completed=sched.completed,
            rejected=sched.rejected,
            slo_violations=slo_violations,
            tokens_generated=tokens_generated,
            decode_steps=decode_steps,
            prefills=prefills,
            cold_starts=cold_starts,
            virtual_ms=vclock,
            wall_s=wall,
            latency_ms=latency,
            percentiles=pct,
            goodput_rps=in_slo / vsec,
            tokens_per_s=tokens_generated / vsec,
            tokens_per_wall_s=tokens_generated / max(wall, 1e-9),
            energy_j=energy,
            energy_per_token_j=energy / max(tokens_generated, 1),
            n_compiles=dict(self.n_compiles),
            counters=counters,
            tokens=tokens_np,
            gen_len=trace.gen_len.copy(),
        )
