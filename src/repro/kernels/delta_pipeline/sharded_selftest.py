"""Fake-device selftest for the sharded delta pipeline (run as SUBPROCESS).

Backs an N-device host mesh (client × zero) with XLA fake CPU devices,
then sweeps a gate matrix comparing three implementations on identical
inputs:

    delta_pipeline_apply_sharded  (shard_map + per-shard Pallas + 1 psum)
    delta_pipeline_apply          (single-device fused kernel)
    delta_pipeline_ref            (pure-jnp oracle)

and asserts via ``dist.hlo_analysis`` that the compiled sharded call
contains exactly ONE all-reduce crossing the client axis with the delta
payload. ``--bench`` times sharded vs single-device on a larger buffer
(backs the ``delta_pipeline_sharded`` row in benchmarks/kernels_bench.py).

MUST run in its own process: the fake-device flag has to be set before
jax initializes its backend (tests/test_sharded_pipeline.py and the
kernel bench both invoke ``python -m
repro.kernels.delta_pipeline.sharded_selftest --json``).
"""
import os
import sys

if __name__ == "__main__":  # set BEFORE any jax import in this process
    _n = "8"
    for _i, _a in enumerate(sys.argv):
        if _a == "--devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

# ruff: noqa: E402
import argparse
import functools
import json
import time


def _gate_matrix():
    """(name, kwargs) cases — every kernel gate alone plus the full stack."""
    seg = (1024, 512, 512)  # sums to P=2048
    return [
        ("plain", {}),
        ("clip", dict(clip_norm=0.5)),
        ("int8", dict(compression="int8", seg_sizes=seg)),
        ("topk", dict(compression="topk", topk_fraction=0.1, seg_sizes=seg)),
        ("staleness", dict(staleness=True, staleness_exponent=0.5)),
        ("dp", dict(dp=True)),
        ("fedavgm", dict(momentum=True, server_optimizer="fedavgm")),
        ("fedadam", dict(momentum=True, server_optimizer="fedadam")),
        ("full", dict(clip_norm=0.5, compression="int8", seg_sizes=seg,
                      dp=True, momentum=True, server_optimizer="fedavgm")),
    ]


def run_selftest(devices: int = 8, *, zero: int = 2, c: int = 16,
                 p: int = 2048, bench: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.dist.hlo_analysis import analyze_hlo, count_axis_crossing
    from repro.kernels.delta_pipeline import (
        delta_pipeline_apply,
        delta_pipeline_apply_sharded,
        delta_pipeline_ref,
    )

    assert len(jax.devices()) >= devices, (
        f"need {devices} devices, have {len(jax.devices())} — run via "
        "python -m repro.kernels.delta_pipeline.sharded_selftest"
    )
    client_ways = devices // zero
    mesh = Mesh(
        np.asarray(jax.devices()[:devices]).reshape(client_ways, zero),
        ("client", "zero"),
    )

    rng = np.random.default_rng(0)
    upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    mask = jnp.asarray(rng.random(c) < 0.75)
    weights = jnp.asarray(rng.integers(10, 100, c), jnp.float32)
    stale = jnp.asarray(rng.integers(0, 4, c), jnp.float32)
    noise = jnp.asarray(rng.normal(size=(p,)) * 1e-3, jnp.float32)
    mu = jnp.asarray(rng.normal(size=(p,)) * 0.1, jnp.float32)

    result = {"devices": devices, "client_ways": client_ways, "zero": zero,
              "cases": {}, "ok": True}
    for name, case in _gate_matrix():
        case = dict(case)
        kw = dict(
            lr=0.7,
            staleness=stale if case.pop("staleness", False) else None,
            staleness_exponent=case.pop("staleness_exponent", 0.0),
            dp_noise=noise if case.pop("dp", False) else None,
            momentum=mu if case.pop("momentum", False) else None,
        )
        static = dict(case)

        sharded = functools.partial(
            delta_pipeline_apply_sharded,
            mesh=mesh, client_axes=("client",), **static,
        )
        args = (upd, base, mask, weights, kw["lr"], kw["staleness"],
                kw["staleness_exponent"], kw["dp_noise"], kw["momentum"])
        compiled = jax.jit(
            lambda u, b, m, w: sharded(
                u, b, m, w, kw["lr"], kw["staleness"],
                kw["staleness_exponent"], kw["dp_noise"], kw["momentum"],
            )
        ).lower(upd, base, mask, weights).compile()
        out_sh = compiled(upd, base, mask, weights)
        out_un = delta_pipeline_apply(*args, **static)
        out_rf = delta_pipeline_ref(*args, **static)

        def leaves(o):
            return o if isinstance(o, tuple) else (o,)

        d_un = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(leaves(out_sh), leaves(out_un))
        )
        d_rf = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(leaves(out_sh), leaves(out_rf))
        )
        # ONE delta-sized all-reduce crossing the client axis. The psum
        # payload is the replicated (P+2,) partial-sum pack: 4·(P+2) B.
        n_ar = count_axis_crossing(
            analyze_hlo(compiled.as_text()), mesh,
            axes=("client",), kinds=("all-reduce",), min_bytes=2.0 * p,
        )
        # fedadam divides by (|agg| + 1e-3): where the aggregate crosses
        # zero that amplifies the psum-reassociation error (~2e-7) by up
        # to 1e3 — an epsilon-conditioning effect, not an implementation
        # difference (the unsharded kernel and ref disagree with each
        # other by the same magnitude under reordering).
        tol = 5e-3 if static.get("server_optimizer") == "fedadam" else 1e-5
        case_ok = d_un < tol and d_rf < tol and n_ar == 1
        result["cases"][name] = {
            "max_diff_vs_unsharded": d_un,
            "max_diff_vs_ref": d_rf,
            "client_all_reduces": n_ar,
            "ok": case_ok,
        }
        result["ok"] = bool(result["ok"] and case_ok)

    if bench:
        cb, pb = 32, 1 << 15
        updb = jnp.asarray(rng.normal(size=(cb, pb)), jnp.float32)
        baseb = jnp.asarray(rng.normal(size=(pb,)), jnp.float32)
        maskb = jnp.ones((cb,), bool)
        wb = jnp.ones((cb,), jnp.float32)

        def timeit(fn, iters=3):
            fn()  # compile + warm
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            return (time.perf_counter() - t0) / iters * 1e6

        sh = jax.jit(lambda u, b, m, w: delta_pipeline_apply_sharded(
            u, b, m, w, mesh=mesh, client_axes=("client",)))
        un = jax.jit(lambda u, b, m, w: delta_pipeline_apply(u, b, m, w))
        result["bench"] = {
            "c": cb, "p": pb,
            "sharded_us": round(
                timeit(lambda: jax.block_until_ready(
                    sh(updb, baseb, maskb, wb))), 1),
            "unsharded_us": round(
                timeit(lambda: jax.block_until_ready(
                    un(updb, baseb, maskb, wb))), 1),
        }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--zero", type=int, default=2)
    ap.add_argument("--bench", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    res = run_selftest(args.devices, zero=args.zero, bench=args.bench)
    if args.json:
        print(json.dumps(res))
    else:
        for k, v in res.items():
            print(f"{k}: {v}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
