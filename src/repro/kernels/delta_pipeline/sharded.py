"""Sharded one-HBM-pass round: ``delta_pipeline`` under mesh rules.

``delta_pipeline_apply_sharded`` wraps the fused pipeline in a
``shard_map`` over the client-sharded (C, P) delta buffer. Each shard
runs the full per-client half locally — clip norms (every client's
(P,) row lives on exactly one shard, so the norms are exact), the
compression table, and the UNnormalized Eq. 6 partial weighted sum via
the ``delta_pipeline_partial`` Pallas kernel. The partial (P,) sums and
the Σdm / Σm weight totals are packed into ONE (P+2,) vector and
combined with a single ``psum`` over the client mesh axes — preserving
the repo's one-inter-client-all-reduce-per-round HLO contract
(``dist/hlo_analysis.analyze_hlo``). The normalize → DP noise →
momentum → apply epilogue runs replicated after the psum, mirroring the
unsharded kernel's formulas term for term.

Fog tier (``fog_nodes > 1``): the FedFog edge → fog → cloud reduction
maps onto the mesh by carving the client axes into a LEADING fog prefix
and an edge suffix — ``fog_nodes`` must equal the product of a leading
prefix of ``client_axes`` (in the multi-pod plans ``("pod", "client")``,
the fog tier IS the pod axis). The combine then runs as one packed psum
per tier: tier 1 reduces the edge suffix axes (each fog aggregator's
partial), tier 2 reduces the fog prefix axes (the cloud combine).
``fog_nodes=1`` keeps the single flat psum — byte-identical to the
pre-fog kernel. ``dist/hlo_analysis.assert_inter_client_contract``
asserts the per-tier collective counts post-compile.

Numerics: the sharded sum reduces per-shard partials in a different
order than the single-device (1, C)×(C, P) matmul, so the result
matches ``delta_pipeline_apply`` / ``ref.py`` to float tolerance, not
bitwise (tests/test_sharded_pipeline.py pins the tolerance). The DP
noise stream is IDENTICAL across paths: the caller builds the (P,)
noise vector from the same key recipe and it is added post-psum.

Robust aggregators (median / trimmed) need every client's coordinate on
one device to sort — they stay on the single-host kernel path; under
mesh rules they keep the reference path (see the gate matrix in
docs/EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.delta_pipeline.delta_pipeline import (
    DEFAULT_BLOCK_D,
    _EPS,
    delta_pipeline_apply,
    delta_pipeline_partial,
)


def _norm_axes(client_axes) -> tuple[str, ...]:
    if isinstance(client_axes, str):
        return (client_axes,)
    return tuple(client_axes)


def split_fog_axes(
    mesh: jax.sharding.Mesh, client_axes, fog_nodes: int
) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Split client mesh axes into (fog prefix, edge suffix).

    The fog tier must align with the device topology for the two-psum
    reduction to be a real hierarchy: ``fog_nodes`` has to equal the
    product of a LEADING prefix of the client axes (pod-major layout).
    Returns ``(fog_axes, edge_axes)``; raises when no prefix matches.
    """
    axes = _norm_axes(client_axes)
    prod = 1
    for i in range(len(axes) + 1):
        if prod == fog_nodes:
            return axes[:i], axes[i:]
        if i < len(axes):
            prod *= mesh.shape[axes[i]]
    sizes = tuple(mesh.shape[a] for a in axes)
    raise ValueError(
        f"fog_nodes={fog_nodes} must equal the product of a leading "
        f"prefix of the client mesh axes {axes} (sizes {sizes}); "
        "use a multi_pod plan whose pod axis is the fog tier"
    )


def combine_epilogue(
    agg_sum: jax.Array,  # (P,) combined UNnormalized weighted delta sum
    sdm: jax.Array,  # scalar Σ mask·|D|·staleness-discount
    sm: jax.Array,  # scalar Σ mask·|D|
    base: jax.Array,  # (P,) fused global model
    lr: jax.Array,
    *,
    has_stale: bool,
    dp_noise: jax.Array | None = None,
    momentum: jax.Array | None = None,
    server_optimizer: str = "fedavg",
    server_momentum: float = 0.9,
) -> tuple[jax.Array, jax.Array | None]:
    """Cloud-side epilogue shared by every hierarchical combine.

    Normalize → DP noise → server momentum/Adam → apply, mirroring the
    unsharded ``delta_pipeline_apply`` formulas term for term. Runs
    replicated after the last psum in the sharded kernel, and on the
    summed fog partials in the single-host ``fl.fog.fog_pipeline_apply``
    path. Returns ``(new_base, new_momentum | None)``.
    """
    if has_stale:
        # normalize by Σdm, then the async_aggregate global damping
        agg = agg_sum / (sdm + _EPS)
        agg = agg * ((sdm + _EPS) / (sm + _EPS))
    else:
        agg = agg_sum / (sm + _EPS)
    if dp_noise is not None:
        agg = agg + dp_noise.astype(jnp.float32)
    if momentum is not None:
        mu2 = server_momentum * momentum.astype(jnp.float32) + agg
        if server_optimizer == "fedadam":
            step = lr * mu2 / (jnp.sqrt(jnp.square(agg)) + 1e-3)
        else:  # fedavgm
            step = lr * mu2
        out = (base.astype(jnp.float32) + step).astype(base.dtype)
        return out, mu2.astype(momentum.dtype)
    out = (base.astype(jnp.float32) + lr * agg).astype(base.dtype)
    return out, None


def delta_pipeline_apply_sharded(
    updates: jax.Array,  # (C, P) fused deltas, sharded over client axes
    base: jax.Array,  # (P,) fused global model (replicated)
    mask: jax.Array,  # (C,) participation, sharded like the client axis
    weights: jax.Array,  # (C,) |D_i| dataset sizes
    lr: jax.Array | float = 1.0,
    staleness: jax.Array | None = None,  # (C,)
    staleness_exponent: jax.Array | float = 0.0,
    dp_noise: jax.Array | None = None,  # (P,) replicated, caller-built
    momentum: jax.Array | None = None,  # (P,) fused server momentum
    *,
    mesh: jax.sharding.Mesh,
    client_axes,  # mesh axis name(s) the client dim is sharded over
    fog_nodes: int = 1,
    clip_norm: float = 0.0,
    compression: str = "none",
    topk_fraction: float = 0.05,
    seg_sizes: tuple[int, ...] | None = None,
    server_optimizer: str = "fedavg",
    server_momentum: float = 0.9,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
):
    """Sharded fused delta pipeline: one HBM pass per shard, one psum
    per reduction tier.

    Same gate semantics and return convention as
    ``delta_pipeline_apply`` (fedavg aggregator only). Designed to be
    called under an enclosing jit that holds the mesh context (the
    sharded round fn); it is NOT itself jitted so the ``mesh`` /
    ``client_axes`` objects never need hashing.
    """
    axes = _norm_axes(client_axes)
    ways = 1
    for a in axes:
        ways *= mesh.shape[a]
    if ways <= 1:
        # Degenerate mesh: no client sharding — the single-device kernel
        # IS the sharded kernel with zero cross-shard combines. A fog
        # tier still changes the reduction order, so it routes to the
        # single-host fog loop.
        if fog_nodes > 1:
            from repro.fl.fog import fog_pipeline_apply

            return fog_pipeline_apply(
                updates, base, mask, weights, lr,
                staleness, staleness_exponent, dp_noise, momentum,
                fog_nodes=fog_nodes,
                clip_norm=clip_norm, compression=compression,
                topk_fraction=topk_fraction, seg_sizes=seg_sizes,
                server_optimizer=server_optimizer,
                server_momentum=server_momentum,
                block_d=block_d, interpret=interpret,
            )
        return delta_pipeline_apply(
            updates, base, mask, weights, lr,
            staleness, staleness_exponent, dp_noise, momentum,
            clip_norm=clip_norm, compression=compression,
            topk_fraction=topk_fraction, seg_sizes=seg_sizes,
            server_optimizer=server_optimizer,
            server_momentum=server_momentum,
            block_d=block_d, interpret=interpret,
        )

    fog_axes: tuple[str, ...] = ()
    edge_axes = axes
    if fog_nodes > 1:
        fog_axes, edge_axes = split_fog_axes(mesh, axes, fog_nodes)

    c, d = updates.shape
    if c % ways:
        raise ValueError(f"client count {c} not divisible by mesh ways {ways}")
    has_mu = momentum is not None and server_optimizer in (
        "fedavgm", "fedadam"
    )
    has_dp = dp_noise is not None
    has_stale = staleness is not None
    mu_in = momentum if has_mu else jnp.zeros((), jnp.float32)
    noise_in = dp_noise if has_dp else jnp.zeros((), jnp.float32)
    stale_in = staleness if has_stale else jnp.zeros_like(mask, jnp.float32)
    lr_in = jnp.asarray(lr, jnp.float32)
    sexp_in = jnp.asarray(staleness_exponent, jnp.float32)

    row = P(axes if len(axes) > 1 else axes[0])
    cxp = P(axes if len(axes) > 1 else axes[0], None)
    rep = P()

    def body(upd, base_l, mask_l, w_l, lr_l, stale_l, sexp_l, noise_l, mu_l):
        # -- per-shard half: exact clip + compression + partial sums --- #
        m = mask_l.astype(jnp.float32) * w_l.astype(jnp.float32)
        if has_stale:
            s = jnp.maximum(stale_l.astype(jnp.float32), 0.0)
            dm = m * (1.0 + s) ** (-sexp_l)
        else:
            dm = m
        partial = delta_pipeline_partial(
            upd, dm,
            clip_norm=clip_norm, compression=compression,
            topk_fraction=topk_fraction, seg_sizes=seg_sizes,
            block_d=block_d, interpret=interpret,
        )
        packed = jnp.concatenate(
            [partial, jnp.sum(dm)[None], jnp.sum(m)[None]]
        )
        if fog_nodes > 1:
            # -- hierarchical combine: one packed psum per tier -------- #
            # Tier 1 (edge → fog): reduce the edge suffix axes; after
            # this, `packed` is the fog aggregator's partial, replicated
            # within each fog group. Skipped when each fog holds exactly
            # one shard (its local partial IS the fog partial).
            edge_ways = 1
            for a in edge_axes:
                edge_ways *= mesh.shape[a]
            if edge_ways > 1:
                packed = jax.lax.psum(packed, edge_axes)
            # Tier 2 (fog → cloud): combine the fog partials across the
            # pod-major fog prefix.
            packed = jax.lax.psum(packed, fog_axes)
        else:
            # -- the ONE cross-shard combine: partials + weight totals - #
            packed = jax.lax.psum(packed, axes)
        agg_sum, sdm, sm = packed[:d], packed[d], packed[d + 1]

        # -- replicated epilogue: mirror the unsharded kernel's math --- #
        out, mu2 = combine_epilogue(
            agg_sum, sdm, sm, base_l, lr_l,
            has_stale=has_stale,
            dp_noise=noise_l if has_dp else None,
            momentum=mu_l if has_mu else None,
            server_optimizer=server_optimizer,
            server_momentum=server_momentum,
        )
        if mu2 is None:
            mu2 = jnp.zeros((), jnp.float32)
        return out, mu2

    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(cxp, rep, row, row, rep, row, rep, rep, rep),
        out_specs=(rep, rep),
        check_rep=False,
    )
    out, mu2 = mapped(
        updates, base, mask, weights, lr_in, stale_in, sexp_in,
        noise_in, mu_in,
    )
    if has_mu:
        return out, mu2
    return out
