from repro.kernels.delta_pipeline.ops import (
    combine_epilogue,
    delta_pipeline_apply,
    delta_pipeline_apply_sharded,
    delta_pipeline_partial,
    delta_sq_norms,
    segment_table,
    split_fog_axes,
)
from repro.kernels.delta_pipeline.ref import delta_pipeline_ref

__all__ = [
    "combine_epilogue",
    "delta_pipeline_apply",
    "delta_pipeline_apply_sharded",
    "delta_pipeline_partial",
    "delta_sq_norms",
    "delta_pipeline_ref",
    "segment_table",
    "split_fog_axes",
]
