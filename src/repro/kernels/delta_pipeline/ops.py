"""Public wrappers for the fused delta-pipeline kernel family."""
from repro.kernels.delta_pipeline.delta_pipeline import (
    delta_pipeline_apply,
    delta_pipeline_partial,
    delta_sq_norms,
    segment_table,
)
from repro.kernels.delta_pipeline.sharded import delta_pipeline_apply_sharded

__all__ = [
    "delta_pipeline_apply",
    "delta_pipeline_apply_sharded",
    "delta_pipeline_partial",
    "delta_sq_norms",
    "segment_table",
]
