"""Public wrappers for the fused delta-pipeline kernel family."""
from repro.kernels.delta_pipeline.delta_pipeline import (
    delta_pipeline_apply,
    delta_pipeline_partial,
    delta_sq_norms,
    segment_table,
)
from repro.kernels.delta_pipeline.sharded import (
    combine_epilogue,
    delta_pipeline_apply_sharded,
    split_fog_axes,
)

__all__ = [
    "combine_epilogue",
    "delta_pipeline_apply",
    "delta_pipeline_apply_sharded",
    "delta_pipeline_partial",
    "delta_sq_norms",
    "segment_table",
    "split_fog_axes",
]
