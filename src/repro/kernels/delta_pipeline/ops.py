"""Public wrappers for the fused delta-pipeline kernel family."""
from repro.kernels.delta_pipeline.delta_pipeline import (
    delta_pipeline_apply,
    delta_sq_norms,
    segment_table,
)

__all__ = ["delta_pipeline_apply", "delta_sq_norms", "segment_table"]
