"""Pure-jnp oracle for the fused delta-pipeline kernel.

Composes the repo's per-stage reference semantics on the fused (C, P)
buffer, in the exact order the round code applies them:

    clip (optim.clip_by_global_norm, per client)
    → compression emulation (fl.compression.apply_compression per-leaf
      semantics, replayed on static segment slices)
    → staleness-discounted Eq. 6 aggregation
      (sim.events.staleness.async_aggregate weighting incl. damping),
      or masked robust aggregation (core.aggregation.median_aggregate /
      trimmed_mean_aggregate on the fused buffer)
    → DP noise on the aggregate (core.privacy.gaussian_mechanism with a
      caller-built noise vector)
    → server momentum / apply (fl.round._server_update math)

The kernel is tested against this oracle bitwise at disabled gates and
to float tolerance at enabled ones (tests/test_delta_pipeline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


def _clip_scales(updates, clip_norm):
    norm = jnp.sqrt(jnp.sum(jnp.square(updates.astype(jnp.float32)), axis=1))
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))


def _compress(updates, compression, topk_fraction, seg_sizes):
    """Per-leaf compression semantics replayed on static segment slices."""
    offs = np.concatenate(([0], np.cumsum(seg_sizes)))
    parts = []
    for l, sz in enumerate(seg_sizes):
        x = updates[:, int(offs[l]):int(offs[l + 1])]
        if compression == "int8":
            scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            parts.append(q.astype(jnp.float32) * scale)
        else:  # topk
            k = max(1, int(sz * topk_fraction))
            thresh = jax.lax.top_k(jnp.abs(x), k)[0][:, -1:]
            parts.append(x * (jnp.abs(x) >= thresh))
    return jnp.concatenate(parts, axis=1)


def delta_pipeline_ref(
    updates,  # (C, P)
    base,  # (P,)
    mask,  # (C,) bool
    weights,  # (C,)
    lr=1.0,
    staleness=None,  # (C,) or None
    staleness_exponent=0.0,
    dp_noise=None,  # (P,) pre-scaled noise or None
    momentum=None,  # (P,) server momentum or None
    clip_norm: float = 0.0,
    compression: str = "none",
    topk_fraction: float = 0.05,
    seg_sizes=None,
    server_optimizer: str = "fedavg",
    server_momentum: float = 0.9,
    aggregator: str = "fedavg",
    trim_fraction=0.1,
):
    x = updates.astype(jnp.float32)
    if clip_norm and clip_norm > 0:
        x = x * _clip_scales(x, clip_norm)[:, None]
    if compression != "none":
        x = _compress(x, compression, topk_fraction, seg_sizes)

    if aggregator in ("median", "trimmed"):
        from repro.core.aggregation import (
            median_aggregate,
            trimmed_mean_aggregate,
        )
        if aggregator == "median":
            agg = median_aggregate(x, mask)
        else:
            agg = trimmed_mean_aggregate(x, mask, trim_fraction)
    else:
        m = mask.astype(jnp.float32) * weights.astype(jnp.float32)
        if staleness is not None:
            s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
            disc = (1.0 + s) ** (
                -jnp.asarray(staleness_exponent, jnp.float32)
            )
            dm = m * disc
            w = dm / (jnp.sum(dm) + _EPS)
            damping = (jnp.sum(dm) + _EPS) / (jnp.sum(m) + _EPS)
        else:
            w = m / (jnp.sum(m) + _EPS)
            damping = None
        agg = jnp.einsum("n,nd->d", w, x)
        if damping is not None:
            agg = agg * damping
    if dp_noise is not None:
        agg = agg + dp_noise.astype(jnp.float32)

    lr = jnp.asarray(lr, jnp.float32)
    if momentum is not None and server_optimizer in ("fedavgm", "fedadam"):
        mu2 = server_momentum * momentum.astype(jnp.float32) + agg
        if server_optimizer == "fedadam":
            step = lr * mu2 / (jnp.sqrt(jnp.square(agg)) + 1e-3)
        else:
            step = lr * mu2
        out = (base.astype(jnp.float32) + step).astype(base.dtype)
        return out, mu2.astype(momentum.dtype)
    return (base.astype(jnp.float32) + lr * agg).astype(base.dtype)
