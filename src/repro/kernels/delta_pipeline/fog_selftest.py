"""Fake-device selftest for the FOG-TIER sharded delta pipeline (SUBPROCESS).

Backs an 8-device host mesh (pod × client × zero) with XLA fake CPU
devices and runs the gate matrix through ``delta_pipeline_apply_sharded``
with ``fog_nodes`` equal to the pod-axis width, so the round reduces
edge → fog → cloud: one psum confined to the edge (client) axis per fog
group, then one psum across the fog (pod) axis. Each case is compared
against the single-device fused kernel and the pure-jnp oracle, and the
compiled HLO is checked two ways:

  * ``count_axis_crossing`` per tier — exactly ONE delta-sized
    all-reduce crossing the edge axes and exactly ONE crossing the fog
    axes (the flat contract would be one crossing their union);
  * ``assert_inter_client_contract(..., fog_nodes=F)`` — the public
    per-tier guard the train path uses.

MUST run in its own process: the fake-device flag has to be set before
jax initializes its backend (tests/test_fog_population.py and
scripts/ci.sh invoke ``python -m
repro.kernels.delta_pipeline.fog_selftest --json``).
"""
import os
import sys

if __name__ == "__main__":  # set BEFORE any jax import in this process
    _n = "8"
    for _i, _a in enumerate(sys.argv):
        if _a == "--devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

# ruff: noqa: E402
import argparse
import functools
import json
import types


def run_selftest(devices: int = 8, *, pods: int = 2, zero: int = 2,
                 c: int = 16, p: int = 2048) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.dist.hlo_analysis import (
        analyze_hlo,
        assert_inter_client_contract,
        count_axis_crossing,
    )
    from repro.kernels.delta_pipeline import (
        delta_pipeline_apply,
        delta_pipeline_apply_sharded,
        delta_pipeline_ref,
    )
    from repro.kernels.delta_pipeline.sharded_selftest import _gate_matrix

    assert len(jax.devices()) >= devices, (
        f"need {devices} devices, have {len(jax.devices())} — run via "
        "python -m repro.kernels.delta_pipeline.fog_selftest"
    )
    edge_ways = devices // (pods * zero)
    mesh = Mesh(
        np.asarray(jax.devices()[:devices]).reshape(pods, edge_ways, zero),
        ("pod", "client", "zero"),
    )
    client_axes = ("pod", "client")
    fog_nodes = pods
    # Lightweight stand-in for dist.sharding_rules: the contract guard
    # only touches .mesh, .plan.client_axes and .client_ways.
    rules = types.SimpleNamespace(
        mesh=mesh,
        plan=types.SimpleNamespace(client_axes=client_axes),
        client_ways=pods * edge_ways,
    )

    rng = np.random.default_rng(0)
    upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    mask = jnp.asarray(rng.random(c) < 0.75)
    weights = jnp.asarray(rng.integers(10, 100, c), jnp.float32)
    stale = jnp.asarray(rng.integers(0, 4, c), jnp.float32)
    noise = jnp.asarray(rng.normal(size=(p,)) * 1e-3, jnp.float32)
    mu = jnp.asarray(rng.normal(size=(p,)) * 0.1, jnp.float32)

    result = {"devices": devices, "pods": pods, "edge_ways": edge_ways,
              "zero": zero, "fog_nodes": fog_nodes, "cases": {}, "ok": True}
    for name, case in _gate_matrix():
        case = dict(case)
        kw = dict(
            lr=0.7,
            staleness=stale if case.pop("staleness", False) else None,
            staleness_exponent=case.pop("staleness_exponent", 0.0),
            dp_noise=noise if case.pop("dp", False) else None,
            momentum=mu if case.pop("momentum", False) else None,
        )
        static = dict(case)

        sharded = functools.partial(
            delta_pipeline_apply_sharded,
            mesh=mesh, client_axes=client_axes, fog_nodes=fog_nodes,
            **static,
        )
        args = (upd, base, mask, weights, kw["lr"], kw["staleness"],
                kw["staleness_exponent"], kw["dp_noise"], kw["momentum"])
        compiled = jax.jit(
            lambda u, b, m, w: sharded(
                u, b, m, w, kw["lr"], kw["staleness"],
                kw["staleness_exponent"], kw["dp_noise"], kw["momentum"],
            )
        ).lower(upd, base, mask, weights).compile()
        out_sh = compiled(upd, base, mask, weights)
        out_un = delta_pipeline_apply(*args, **static)
        out_rf = delta_pipeline_ref(*args, **static)

        def leaves(o):
            return o if isinstance(o, tuple) else (o,)

        d_un = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(leaves(out_sh), leaves(out_un))
        )
        d_rf = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(leaves(out_sh), leaves(out_rf))
        )
        # Per-tier contract: ONE delta-sized all-reduce confined to the
        # edge (client) axis — groups live inside a pod slice — and ONE
        # crossing the fog (pod) axis. Payload per zero-shard is the
        # (P/zero + 2,) partial-sum pack: ≈ 4·p/zero bytes.
        analysis = analyze_hlo(compiled.as_text())
        min_b = 2.0 * p / zero
        n_edge = count_axis_crossing(
            analysis, mesh, axes=("client",), kinds=("all-reduce",),
            min_bytes=min_b, not_axes=("pod",),
        )
        n_fog = count_axis_crossing(
            analysis, mesh, axes=("pod",), kinds=("all-reduce",),
            min_bytes=min_b, not_axes=("client",),
        )
        try:
            assert_inter_client_contract(analysis, rules, p,
                                         fog_nodes=fog_nodes)
            contract_ok = True
        except AssertionError:
            contract_ok = False
        # Same tolerance rationale as sharded_selftest: fedadam's
        # 1e-3-epsilon division amplifies psum-reassociation noise.
        tol = 5e-3 if static.get("server_optimizer") == "fedadam" else 1e-5
        want_edge = 1 if edge_ways > 1 else 0
        case_ok = (d_un < tol and d_rf < tol and n_edge == want_edge
                   and n_fog == 1 and contract_ok)
        result["cases"][name] = {
            "max_diff_vs_unsharded": d_un,
            "max_diff_vs_ref": d_rf,
            "edge_all_reduces": n_edge,
            "fog_all_reduces": n_fog,
            "contract_ok": contract_ok,
            "ok": case_ok,
        }
        result["ok"] = bool(result["ok"] and case_ok)

    # Flat sanity on the SAME mesh: fog_nodes=1 must keep the one
    # union-crossing all-reduce and match bitwise-identical codegen
    # semantics (single psum over ("pod","client")).
    flat = jax.jit(
        lambda u, b, m, w: delta_pipeline_apply_sharded(
            u, b, m, w, mesh=mesh, client_axes=client_axes, fog_nodes=1,
        )
    ).lower(upd, base, mask, weights).compile()
    flat_analysis = analyze_hlo(flat.as_text())
    n_flat = count_axis_crossing(
        flat_analysis, mesh, axes=client_axes, kinds=("all-reduce",),
        min_bytes=2.0 * p / zero,
    )
    d_flat = float(jnp.max(jnp.abs(
        flat(upd, base, mask, weights)
        - delta_pipeline_apply(upd, base, mask, weights)
    )))
    try:
        assert_inter_client_contract(flat_analysis, rules, p)
        flat_contract = True
    except AssertionError:
        flat_contract = False
    flat_ok = n_flat == 1 and flat_contract and d_flat < 1e-5
    result["flat"] = {"client_all_reduces": n_flat,
                      "contract_ok": flat_contract,
                      "max_diff_vs_unsharded": d_flat, "ok": flat_ok}
    result["ok"] = bool(result["ok"] and flat_ok)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--zero", type=int, default=2)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    res = run_selftest(args.devices, pods=args.pods, zero=args.zero)
    if args.json:
        print(json.dumps(res))
    else:
        for k, v in res.items():
            print(f"{k}: {v}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
