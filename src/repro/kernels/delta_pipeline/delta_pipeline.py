"""Pallas TPU kernel family: the fused FedFog delta pipeline.

The server side of a FedFog round (paper §IV, Fig. 1 tail) is a chain of
memory-bound passes over the ``(C, P)`` stacked client-delta buffer:

    clip-by-global-norm → compression emulation (top-k / int8) →
    staleness-discounted Eq. 6 weighting → aggregate → DP noise →
    server momentum (FedAvgM / FedAdam) → apply to the global model

XLA lowers the reference composition as one kernel per stage per leaf —
up to ~6 reads of the C·P delta floats from HBM. This family fuses the
whole chain into at most TWO passes over the delta stack:

  * ``delta_sq_norms`` — the norm reduction (only when clipping is on):
    grid over D-tiles, accumulating per-client Σx² into a (C,) output.
  * ``delta_pipeline_apply`` — everything else in ONE pass: each D-tile
    is read once, transformed in VMEM (clip scale, quant/dequant or
    top-k threshold mask), reduced with a single (1,C)×(C,bd) MXU
    matmul, and combined with the (P,)-sized server-state tiles (base,
    momentum, DP noise) that ride along at 1/C of the delta traffic.

Per-client scalars (clip scales, staleness discounts, Eq. 6 weights)
travel in tiny (1, C) vectors; per-(client, leaf) compression scales /
thresholds travel in a (C, L) table plus a (P,) segment-id row — inside
the kernel the table is expanded per tile with a static ``L``-way select
chain (no gather, VPU-friendly). ``lr`` rides as a (1, 1) SMEM-style
scalar input so a sweep-lifted ``server_lr`` stays data.

The top-k threshold and int8 max-abs reductions themselves are computed
by the caller-side wrapper in XLA (``lax.top_k`` needs a sort); they
read the buffer once more when compression is enabled but write only
(C, L) scalars.

Reference oracle: ``ref.py::delta_pipeline_ref`` (same op order on the
fused buffer, built from the repo's per-stage reference semantics).
Bitwise-equal at disabled gates; tolerance-bounded at enabled ones.
Interpret-mode fallback off-TPU, like the other kernels in the package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, interpret_default

DEFAULT_BLOCK_D = 2048
_EPS = 1e-12  # matches core.aggregation._EPS / sim.events.staleness


# --------------------------------------------------------------------- #
# pass 1: per-client squared norms (the clip reduction)
# --------------------------------------------------------------------- #
def _sq_norms_kernel(upd_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = upd_ref[...].astype(jnp.float32)
    out_ref[...] = out_ref[...] + jnp.sum(x * x, axis=1)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def delta_sq_norms(
    updates: jax.Array,  # (C, P)
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-client Σx² over the fused delta buffer — one HBM pass."""
    interpret = interpret_default(interpret)
    c, d = updates.shape
    block_d = min(block_d, d)
    pad = (-d) % block_d
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    grid = ((d + pad) // block_d,)
    return pl.pallas_call(
        _sq_norms_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((c, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((c,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(updates)


# --------------------------------------------------------------------- #
# shared tile transform (clip scale + compression expansion)
# --------------------------------------------------------------------- #
def _transform_tile(x, pre_ref, seg_ref, tab_ref, compression, n_leaves):
    """The per-tile pre-aggregation transform, shared by the full
    pipeline kernel, the sharded partial-sum kernel and the selection
    kernels: optional clip pre-scale, then compression emulation via a
    static ``n_leaves``-way select chain over the (C, L) table."""
    if pre_ref is not None:
        x = x * pre_ref[0, :][:, None]
    if compression != "none":
        # Expand the (C, L) per-leaf table to per-column values with
        # a static L-way select chain — no dynamic gather, so the
        # tile stays VPU-only on TPU.
        seg = seg_ref[...]  # (bd,) int32 leaf-segment ids
        tab = tab_ref[...].astype(jnp.float32)  # (C, L)
        col = jnp.ones(x.shape, jnp.float32)  # pad columns: benign 1.0
        for l in range(n_leaves):
            col = jnp.where((seg == l)[None, :], tab[:, l][:, None], col)
        if compression == "int8":
            q = jnp.clip(jnp.round(x / col), -127.0, 127.0)
            x = q * col
        else:  # topk: col holds the kth-largest |x| per (client, leaf)
            x = x * (jnp.abs(x) >= col).astype(jnp.float32)
    return x


def _bitonic_sort(x):
    """Ascending sort along axis 0 via a static bitonic compare-exchange
    network (axis-0 extent must be a power of two; callers pad with
    +inf). Produces the exact same sorted VALUES as ``jnp.sort`` — the
    sorted sequence of a float multiset is unique — which is what makes
    the in-kernel median/trimmed selection bitwise-equal to the
    ``core.aggregation`` references. Pure where/compare ops, so it
    lowers on TPU where ``sort`` does not."""
    n = x.shape[0]
    tail = (None,) * (x.ndim - 1)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            idx = jnp.arange(n)
            partner = idx ^ j
            keep_min = (idx < partner) == ((idx & k) == 0)
            px = x[partner]
            lo = jnp.where(x <= px, x, px)
            hi = jnp.where(x <= px, px, x)
            x = jnp.where(keep_min[(...,) + tail], lo, hi)
            j //= 2
        k *= 2
    return x


def _select_aggregate(x, sel, cnt_ref, aggregator):
    """Masked coordinate-wise median / trimmed mean over the client axis
    of one (C, bd) tile — bitwise ``core.aggregation.median_aggregate``/
    ``trimmed_mean_aggregate`` semantics (+inf sentinel sort, identical
    index arithmetic). ``cnt_ref`` is the (1, 2) int32 [num_sel, k_trim]
    pair, traced data so participation masks stay dynamic."""
    c = x.shape[0]
    big = jnp.where(sel[:, None], x, jnp.inf)
    n2 = 1 << max((c - 1).bit_length(), 0)
    if n2 > c:  # pad the client axis to a power of two for the network
        big = jnp.concatenate(
            [big, jnp.full((n2 - c,) + x.shape[1:], jnp.inf, big.dtype)],
            axis=0,
        )
    s = _bitonic_sort(big)
    num_sel = cnt_ref[0, 0]
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    if aggregator == "median":
        lo_idx = jnp.maximum((num_sel - 1) // 2, 0)
        hi_idx = num_sel // 2
        lo = jnp.sum(jnp.where(row == lo_idx, s, 0.0), axis=0)
        hi = jnp.sum(jnp.where(row == hi_idx, s, 0.0), axis=0)
        return 0.5 * (lo + hi)
    k_trim = cnt_ref[0, 1]
    keep = (row >= k_trim) & (row < num_sel - k_trim)
    total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
    cnt = jnp.maximum(num_sel - 2 * k_trim, 1).astype(jnp.float32)
    return total / cnt


# --------------------------------------------------------------------- #
# pass 2: the fused transform + aggregate + server update
# --------------------------------------------------------------------- #
def _make_pipeline_kernel(
    n_leaves: int,
    has_pre: bool,
    compression: str,
    has_dp: bool,
    has_mu: bool,
    server_optimizer: str,
    server_momentum: float,
    aggregator: str = "fedavg",
):
    robust = aggregator in ("median", "trimmed")

    def kernel(*refs):
        it = iter(refs)
        wn_ref = next(it)  # (1, C): Eq. 6 weights, or the 0/1 mask (robust)
        cnt_ref = next(it) if robust else None  # (1, 2) [num_sel, k_trim]
        lr_ref = next(it)
        upd_ref = next(it)
        base_ref = next(it)
        pre_ref = next(it) if has_pre else None
        seg_ref = next(it) if compression != "none" else None
        tab_ref = next(it) if compression != "none" else None
        noise_ref = next(it) if has_dp else None
        mu_ref = next(it) if has_mu else None
        out_ref = next(it)
        new_mu_ref = next(it) if has_mu else None

        x = upd_ref[...].astype(jnp.float32)  # (C, bd)
        x = _transform_tile(x, pre_ref, seg_ref, tab_ref, compression,
                            n_leaves)
        if robust:
            agg = _select_aggregate(
                x, wn_ref[0, :] > 0.0, cnt_ref, aggregator
            )
        else:
            agg = jax.lax.dot_general(
                wn_ref[0, :][None, :].astype(jnp.float32), x,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )[0]  # (bd,)
        if has_dp:
            agg = agg + noise_ref[...].astype(jnp.float32)
        lr = lr_ref[0, 0].astype(jnp.float32)
        if has_mu:
            mu2 = server_momentum * mu_ref[...].astype(jnp.float32) + agg
            new_mu_ref[...] = mu2.astype(new_mu_ref.dtype)
            if server_optimizer == "fedadam":
                step = lr * mu2 / (jnp.sqrt(jnp.square(agg)) + 1e-3)
            else:  # fedavgm
                step = lr * mu2
        else:
            step = lr * agg
        out_ref[...] = (
            base_ref[...].astype(jnp.float32) + step
        ).astype(out_ref.dtype)

    return kernel


def segment_table(updates, compression, topk_fraction, seg_sizes, pre=None):
    """(C, L) compression table: int8 dequant scales or top-k thresholds.

    THE single definition of the per-(client, leaf) reduction — the
    fused ``fl.compression.apply_compression`` path and the Pallas
    pipeline both consume it, so the epsilon / k-rounding rules cannot
    drift apart. The int8 scale is the per-leaf reference
    ``max|x|/127 + 1e-12`` via a segment scatter-max; the top-k
    threshold is the per-leaf kth-largest |x| from static leaf slices
    (``lax.top_k`` needs the static per-leaf ``k``).

    ``pre``: optional (C,) positive clip scales. The table is computed
    on the RAW deltas and rescaled — for a positive per-client scale s,
    ``max|s·x| = s·max|x|`` and the kth largest of ``|s·x|`` is
    ``s·(kth largest |x|)`` bitwise, so this equals computing the table
    on the clipped values without a second elementwise pass (the int8
    epsilon lands after the rescale, within the enabled-gate tolerance).
    """
    c = updates.shape[0]
    n_leaves = len(seg_sizes)
    if compression == "int8":
        seg = jnp.asarray(
            np.repeat(np.arange(n_leaves), seg_sizes), jnp.int32
        )
        tab = (
            jnp.zeros((c, n_leaves), jnp.float32)
            .at[:, seg].max(jnp.abs(updates))
        )
        if pre is not None:
            tab = tab * pre[:, None]
        return tab / 127.0 + 1e-12
    # topk: kth-largest |x| per (client, leaf); k is static per leaf.
    offs = np.concatenate(([0], np.cumsum(seg_sizes)))
    cols = []
    for l, sz in enumerate(seg_sizes):
        k = max(1, int(sz * topk_fraction))
        sl = jnp.abs(updates[:, int(offs[l]):int(offs[l + 1])])
        cols.append(jax.lax.top_k(sl, k)[0][:, -1:])
    tab = jnp.concatenate(cols, axis=1)
    if pre is not None:
        tab = tab * pre[:, None]
    return tab


@functools.partial(
    jax.jit,
    static_argnames=(
        "clip_norm", "compression", "topk_fraction", "seg_sizes",
        "server_optimizer", "server_momentum", "aggregator",
        "block_d", "interpret",
    ),
)
def delta_pipeline_apply(
    updates: jax.Array,  # (C, P) fused client deltas
    base: jax.Array,  # (P,) fused global model
    mask: jax.Array,  # (C,) bool participation
    weights: jax.Array,  # (C,) |D_i| dataset sizes
    lr: jax.Array | float = 1.0,  # server lr (traced-safe)
    staleness: jax.Array | None = None,  # (C,) staleness counts
    staleness_exponent: jax.Array | float = 0.0,  # a in (1+s)^-a
    dp_noise: jax.Array | None = None,  # (P,) pre-scaled Gaussian noise
    momentum: jax.Array | None = None,  # (P,) fused server momentum
    trim_fraction: jax.Array | float = 0.1,  # traced: sweep-liftable
    *,
    clip_norm: float = 0.0,  # static gate: per-client delta clip (0 = off)
    compression: str = "none",  # static: none | int8 | topk
    topk_fraction: float = 0.05,
    seg_sizes: tuple[int, ...] | None = None,  # fused-buffer leaf sizes
    server_optimizer: str = "fedavg",  # fedavg | fedavgm | fedadam
    server_momentum: float = 0.9,
    aggregator: str = "fedavg",  # fedavg | median | trimmed
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
):
    """One-pass fused delta pipeline over the (C, P) buffer.

    Returns the updated (P,) model — or ``(model, new_mu)`` when a
    ``momentum`` buffer is supplied with a momentum server optimizer.

    Gate semantics mirror the per-stage reference paths exactly:
    ``clip_norm > 0`` → ``optim.clip_by_global_norm`` per client;
    ``compression`` → ``fl.compression.apply_compression``;
    ``staleness`` → ``sim.events.staleness.async_aggregate`` weighting
    (discount + global damping); ``dp_noise`` → noise added to the
    aggregate BEFORE the momentum/apply step (``core.privacy``);
    ``momentum`` → ``fl.round._server_update``; ``aggregator`` →
    ``core.aggregation.median_aggregate`` / ``trimmed_mean_aggregate``
    via the in-kernel bitonic selection network (bitwise; ``weights``
    and ``staleness`` do not apply — the robust aggregators are
    unweighted by construction, so staleness raises).
    """
    interpret = interpret_default(interpret)
    c, d = updates.shape
    block_d = min(block_d, d)
    pad = (-d) % block_d
    if compression not in ("none", "int8", "topk"):
        raise ValueError(f"unknown compression {compression!r}")
    if compression != "none" and seg_sizes is None:
        raise ValueError("compression requires seg_sizes (fused leaf sizes)")
    if compression != "none" and int(sum(seg_sizes)) != d:
        raise ValueError(f"seg_sizes sum {sum(seg_sizes)} != P {d}")
    if aggregator not in ("fedavg", "median", "trimmed"):
        raise ValueError(f"unknown aggregator {aggregator!r}")
    robust = aggregator in ("median", "trimmed")
    if robust and staleness is not None:
        raise ValueError(
            f"aggregator={aggregator!r} is unweighted; staleness weighting "
            "does not compose with it"
        )
    has_mu = momentum is not None and server_optimizer in (
        "fedavgm", "fedadam"
    )
    has_dp = dp_noise is not None

    # -- per-client scalars: Eq. 6 weights, staleness, clip scales ------ #
    if robust:
        # The wn row carries the raw participation mask; selection counts
        # travel in a (1, 2) int32 [num_sel, k_trim] pair so a lifted
        # ``trim_fraction`` stays traced data.
        wn = mask.astype(jnp.float32)
        num_sel = jnp.sum(mask.astype(jnp.int32))
        k_trim = jnp.floor(
            num_sel.astype(jnp.float32)
            * jnp.asarray(trim_fraction, jnp.float32)
        ).astype(jnp.int32)
        cnt = jnp.stack([num_sel, k_trim]).reshape(1, 2)
    else:
        m = mask.astype(jnp.float32) * weights.astype(jnp.float32)
        if staleness is not None:
            # (1+s)^-a discount + global damping — the async_aggregate
            # rule, bitwise ``fedavg_stacked`` at zero staleness
            # (damping == 1.0).
            s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
            disc = (1.0 + s) ** (
                -jnp.asarray(staleness_exponent, jnp.float32)
            )
            dm = m * disc
            wn = dm / (jnp.sum(dm) + _EPS)
            wn = wn * ((jnp.sum(dm) + _EPS) / (jnp.sum(m) + _EPS))
        else:
            wn = m / (jnp.sum(m) + _EPS)

    pre = None
    if clip_norm and clip_norm > 0:
        norm = jnp.sqrt(delta_sq_norms(updates, block_d, interpret))
        pre = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))

    def padded(x):  # pad the P axis out to a block multiple
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x

    inputs = [wn[None, :]]
    in_specs = [pl.BlockSpec((1, c), lambda i: (0, 0))]
    if robust:
        inputs.append(cnt)
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
    inputs += [
        jnp.asarray(lr, jnp.float32).reshape(1, 1),
        padded(updates),
        padded(base),
    ]
    in_specs += [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((c, block_d), lambda i: (0, i)),
        pl.BlockSpec((block_d,), lambda i: (i,)),
    ]
    n_leaves = len(seg_sizes) if seg_sizes else 0
    if pre is not None:
        inputs.append(pre[None, :])
        in_specs.append(pl.BlockSpec((1, c), lambda i: (0, 0)))
    if compression != "none":
        seg = jnp.asarray(
            np.repeat(np.arange(n_leaves), seg_sizes), jnp.int32
        )
        tab = segment_table(
            updates, compression, topk_fraction, seg_sizes, pre=pre
        )
        inputs += [padded(seg), tab]
        in_specs += [
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((c, n_leaves), lambda i: (0, 0)),
        ]
    if has_dp:
        inputs.append(padded(dp_noise))
        in_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))
    if has_mu:
        inputs.append(padded(momentum))
        in_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))

    dp_total = d + pad
    grid = (dp_total // block_d,)
    out_shape = [jax.ShapeDtypeStruct((dp_total,), base.dtype)]
    out_specs = [pl.BlockSpec((block_d,), lambda i: (i,))]
    if has_mu:
        out_shape.append(jax.ShapeDtypeStruct((dp_total,), momentum.dtype))
        out_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))

    kernel = _make_pipeline_kernel(
        n_leaves, pre is not None, compression, has_dp, has_mu,
        server_optimizer, float(server_momentum), aggregator,
    )
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if has_mu else out_specs[0],
        out_shape=out_shape if has_mu else out_shape[0],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    if has_mu:
        return outs[0][:d], outs[1][:d]
    return outs[:d]


# --------------------------------------------------------------------- #
# sharded building block: per-shard partial weighted sums
# --------------------------------------------------------------------- #
def _make_partial_kernel(n_leaves: int, has_pre: bool, compression: str):
    def kernel(*refs):
        it = iter(refs)
        dm_ref = next(it)  # (1, C_local) UNnormalized weights
        upd_ref = next(it)
        pre_ref = next(it) if has_pre else None
        seg_ref = next(it) if compression != "none" else None
        tab_ref = next(it) if compression != "none" else None
        out_ref = next(it)

        x = upd_ref[...].astype(jnp.float32)
        x = _transform_tile(x, pre_ref, seg_ref, tab_ref, compression,
                            n_leaves)
        out_ref[...] = jax.lax.dot_general(
            dm_ref[0, :][None, :].astype(jnp.float32), x,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[0]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "clip_norm", "compression", "topk_fraction", "seg_sizes",
        "block_d", "interpret",
    ),
)
def delta_pipeline_partial(
    updates: jax.Array,  # (C_local, P) fused client deltas, one shard
    dm: jax.Array,  # (C_local,) UNnormalized Eq. 6 weights (mask·|D|·disc)
    *,
    clip_norm: float = 0.0,
    compression: str = "none",
    topk_fraction: float = 0.05,
    seg_sizes: tuple[int, ...] | None = None,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-shard half of the sharded pipeline: clip + compression +
    UNnormalized weighted sum over this shard's clients — one HBM pass
    over the local delta slab. The clip norms are exact (each client's
    full (P,) row lives on one shard) and the compression table is
    shard-local, so the only cross-shard data the caller must combine is
    the (P,) partial plus the Σdm / Σm scalars → exactly one psum."""
    interpret = interpret_default(interpret)
    c, d = updates.shape
    block_d = min(block_d, d)
    pad = (-d) % block_d

    pre = None
    if clip_norm and clip_norm > 0:
        norm = jnp.sqrt(delta_sq_norms(updates, block_d, interpret))
        pre = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))

    def padded(x):
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x

    inputs = [dm[None, :].astype(jnp.float32), padded(updates)]
    in_specs = [
        pl.BlockSpec((1, c), lambda i: (0, 0)),
        pl.BlockSpec((c, block_d), lambda i: (0, i)),
    ]
    n_leaves = len(seg_sizes) if seg_sizes else 0
    if pre is not None:
        inputs.append(pre[None, :])
        in_specs.append(pl.BlockSpec((1, c), lambda i: (0, 0)))
    if compression != "none":
        seg = jnp.asarray(
            np.repeat(np.arange(n_leaves), seg_sizes), jnp.int32
        )
        tab = segment_table(
            updates, compression, topk_fraction, seg_sizes, pre=pre
        )
        inputs += [padded(seg), tab]
        in_specs += [
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((c, n_leaves), lambda i: (0, 0)),
        ]

    dp_total = d + pad
    kernel = _make_partial_kernel(n_leaves, pre is not None, compression)
    out = pl.pallas_call(
        kernel,
        grid=(dp_total // block_d,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp_total,), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    return out[:d]
