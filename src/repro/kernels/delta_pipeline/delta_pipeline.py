"""Pallas TPU kernel family: the fused FedFog delta pipeline.

The server side of a FedFog round (paper §IV, Fig. 1 tail) is a chain of
memory-bound passes over the ``(C, P)`` stacked client-delta buffer:

    clip-by-global-norm → compression emulation (top-k / int8) →
    staleness-discounted Eq. 6 weighting → aggregate → DP noise →
    server momentum (FedAvgM / FedAdam) → apply to the global model

XLA lowers the reference composition as one kernel per stage per leaf —
up to ~6 reads of the C·P delta floats from HBM. This family fuses the
whole chain into at most TWO passes over the delta stack:

  * ``delta_sq_norms`` — the norm reduction (only when clipping is on):
    grid over D-tiles, accumulating per-client Σx² into a (C,) output.
  * ``delta_pipeline_apply`` — everything else in ONE pass: each D-tile
    is read once, transformed in VMEM (clip scale, quant/dequant or
    top-k threshold mask), reduced with a single (1,C)×(C,bd) MXU
    matmul, and combined with the (P,)-sized server-state tiles (base,
    momentum, DP noise) that ride along at 1/C of the delta traffic.

Per-client scalars (clip scales, staleness discounts, Eq. 6 weights)
travel in tiny (1, C) vectors; per-(client, leaf) compression scales /
thresholds travel in a (C, L) table plus a (P,) segment-id row — inside
the kernel the table is expanded per tile with a static ``L``-way select
chain (no gather, VPU-friendly). ``lr`` rides as a (1, 1) SMEM-style
scalar input so a sweep-lifted ``server_lr`` stays data.

The top-k threshold and int8 max-abs reductions themselves are computed
by the caller-side wrapper in XLA (``lax.top_k`` needs a sort); they
read the buffer once more when compression is enabled but write only
(C, L) scalars.

Reference oracle: ``ref.py::delta_pipeline_ref`` (same op order on the
fused buffer, built from the repo's per-stage reference semantics).
Bitwise-equal at disabled gates; tolerance-bounded at enabled ones.
Interpret-mode fallback off-TPU, like the other kernels in the package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BLOCK_D = 2048
_EPS = 1e-12  # matches core.aggregation._EPS / sim.events.staleness


# --------------------------------------------------------------------- #
# pass 1: per-client squared norms (the clip reduction)
# --------------------------------------------------------------------- #
def _sq_norms_kernel(upd_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = upd_ref[...].astype(jnp.float32)
    out_ref[...] = out_ref[...] + jnp.sum(x * x, axis=1)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def delta_sq_norms(
    updates: jax.Array,  # (C, P)
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-client Σx² over the fused delta buffer — one HBM pass."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c, d = updates.shape
    block_d = min(block_d, d)
    pad = (-d) % block_d
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    grid = ((d + pad) // block_d,)
    return pl.pallas_call(
        _sq_norms_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((c, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((c,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(updates)


# --------------------------------------------------------------------- #
# pass 2: the fused transform + aggregate + server update
# --------------------------------------------------------------------- #
def _make_pipeline_kernel(
    n_leaves: int,
    has_pre: bool,
    compression: str,
    has_dp: bool,
    has_mu: bool,
    server_optimizer: str,
    server_momentum: float,
):
    def kernel(*refs):
        it = iter(refs)
        wn_ref = next(it)
        lr_ref = next(it)
        upd_ref = next(it)
        base_ref = next(it)
        pre_ref = next(it) if has_pre else None
        seg_ref = next(it) if compression != "none" else None
        tab_ref = next(it) if compression != "none" else None
        noise_ref = next(it) if has_dp else None
        mu_ref = next(it) if has_mu else None
        out_ref = next(it)
        new_mu_ref = next(it) if has_mu else None

        x = upd_ref[...].astype(jnp.float32)  # (C, bd)
        if has_pre:
            x = x * pre_ref[0, :][:, None]
        if compression != "none":
            # Expand the (C, L) per-leaf table to per-column values with
            # a static L-way select chain — no dynamic gather, so the
            # tile stays VPU-only on TPU.
            seg = seg_ref[...]  # (bd,) int32 leaf-segment ids
            tab = tab_ref[...].astype(jnp.float32)  # (C, L)
            col = jnp.ones(x.shape, jnp.float32)  # pad columns: benign 1.0
            for l in range(n_leaves):
                col = jnp.where((seg == l)[None, :], tab[:, l][:, None], col)
            if compression == "int8":
                q = jnp.clip(jnp.round(x / col), -127.0, 127.0)
                x = q * col
            else:  # topk: col holds the kth-largest |x| per (client, leaf)
                x = x * (jnp.abs(x) >= col).astype(jnp.float32)

        agg = jax.lax.dot_general(
            wn_ref[0, :][None, :].astype(jnp.float32), x,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[0]  # (bd,)
        if has_dp:
            agg = agg + noise_ref[...].astype(jnp.float32)
        lr = lr_ref[0, 0].astype(jnp.float32)
        if has_mu:
            mu2 = server_momentum * mu_ref[...].astype(jnp.float32) + agg
            new_mu_ref[...] = mu2.astype(new_mu_ref.dtype)
            if server_optimizer == "fedadam":
                step = lr * mu2 / (jnp.sqrt(jnp.square(agg)) + 1e-3)
            else:  # fedavgm
                step = lr * mu2
        else:
            step = lr * agg
        out_ref[...] = (
            base_ref[...].astype(jnp.float32) + step
        ).astype(out_ref.dtype)

    return kernel


def segment_table(updates, compression, topk_fraction, seg_sizes, pre=None):
    """(C, L) compression table: int8 dequant scales or top-k thresholds.

    THE single definition of the per-(client, leaf) reduction — the
    fused ``fl.compression.apply_compression`` path and the Pallas
    pipeline both consume it, so the epsilon / k-rounding rules cannot
    drift apart. The int8 scale is the per-leaf reference
    ``max|x|/127 + 1e-12`` via a segment scatter-max; the top-k
    threshold is the per-leaf kth-largest |x| from static leaf slices
    (``lax.top_k`` needs the static per-leaf ``k``).

    ``pre``: optional (C,) positive clip scales. The table is computed
    on the RAW deltas and rescaled — for a positive per-client scale s,
    ``max|s·x| = s·max|x|`` and the kth largest of ``|s·x|`` is
    ``s·(kth largest |x|)`` bitwise, so this equals computing the table
    on the clipped values without a second elementwise pass (the int8
    epsilon lands after the rescale, within the enabled-gate tolerance).
    """
    c = updates.shape[0]
    n_leaves = len(seg_sizes)
    if compression == "int8":
        seg = jnp.asarray(
            np.repeat(np.arange(n_leaves), seg_sizes), jnp.int32
        )
        tab = (
            jnp.zeros((c, n_leaves), jnp.float32)
            .at[:, seg].max(jnp.abs(updates))
        )
        if pre is not None:
            tab = tab * pre[:, None]
        return tab / 127.0 + 1e-12
    # topk: kth-largest |x| per (client, leaf); k is static per leaf.
    offs = np.concatenate(([0], np.cumsum(seg_sizes)))
    cols = []
    for l, sz in enumerate(seg_sizes):
        k = max(1, int(sz * topk_fraction))
        sl = jnp.abs(updates[:, int(offs[l]):int(offs[l + 1])])
        cols.append(jax.lax.top_k(sl, k)[0][:, -1:])
    tab = jnp.concatenate(cols, axis=1)
    if pre is not None:
        tab = tab * pre[:, None]
    return tab


@functools.partial(
    jax.jit,
    static_argnames=(
        "clip_norm", "compression", "topk_fraction", "seg_sizes",
        "server_optimizer", "server_momentum", "block_d", "interpret",
    ),
)
def delta_pipeline_apply(
    updates: jax.Array,  # (C, P) fused client deltas
    base: jax.Array,  # (P,) fused global model
    mask: jax.Array,  # (C,) bool participation
    weights: jax.Array,  # (C,) |D_i| dataset sizes
    lr: jax.Array | float = 1.0,  # server lr (traced-safe)
    staleness: jax.Array | None = None,  # (C,) staleness counts
    staleness_exponent: jax.Array | float = 0.0,  # a in (1+s)^-a
    dp_noise: jax.Array | None = None,  # (P,) pre-scaled Gaussian noise
    momentum: jax.Array | None = None,  # (P,) fused server momentum
    *,
    clip_norm: float = 0.0,  # static gate: per-client delta clip (0 = off)
    compression: str = "none",  # static: none | int8 | topk
    topk_fraction: float = 0.05,
    seg_sizes: tuple[int, ...] | None = None,  # fused-buffer leaf sizes
    server_optimizer: str = "fedavg",  # fedavg | fedavgm | fedadam
    server_momentum: float = 0.9,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
):
    """One-pass fused delta pipeline over the (C, P) buffer.

    Returns the updated (P,) model — or ``(model, new_mu)`` when a
    ``momentum`` buffer is supplied with a momentum server optimizer.

    Gate semantics mirror the per-stage reference paths exactly:
    ``clip_norm > 0`` → ``optim.clip_by_global_norm`` per client;
    ``compression`` → ``fl.compression.apply_compression``;
    ``staleness`` → ``sim.events.staleness.async_aggregate`` weighting
    (discount + global damping); ``dp_noise`` → noise added to the
    aggregate BEFORE the momentum/apply step (``core.privacy``);
    ``momentum`` → ``fl.round._server_update``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c, d = updates.shape
    block_d = min(block_d, d)
    pad = (-d) % block_d
    if compression not in ("none", "int8", "topk"):
        raise ValueError(f"unknown compression {compression!r}")
    if compression != "none" and seg_sizes is None:
        raise ValueError("compression requires seg_sizes (fused leaf sizes)")
    if compression != "none" and int(sum(seg_sizes)) != d:
        raise ValueError(f"seg_sizes sum {sum(seg_sizes)} != P {d}")
    has_mu = momentum is not None and server_optimizer in (
        "fedavgm", "fedadam"
    )
    has_dp = dp_noise is not None

    # -- per-client scalars: Eq. 6 weights, staleness, clip scales ------ #
    m = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    if staleness is not None:
        # (1+s)^-a discount + global damping — the async_aggregate rule,
        # bitwise ``fedavg_stacked`` at zero staleness (damping == 1.0).
        s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
        disc = (1.0 + s) ** (-jnp.asarray(staleness_exponent, jnp.float32))
        dm = m * disc
        wn = dm / (jnp.sum(dm) + _EPS)
        wn = wn * ((jnp.sum(dm) + _EPS) / (jnp.sum(m) + _EPS))
    else:
        wn = m / (jnp.sum(m) + _EPS)

    pre = None
    if clip_norm and clip_norm > 0:
        norm = jnp.sqrt(delta_sq_norms(updates, block_d, interpret))
        pre = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))

    def padded(x):  # pad the P axis out to a block multiple
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x

    inputs = [
        wn[None, :],
        jnp.asarray(lr, jnp.float32).reshape(1, 1),
        padded(updates),
        padded(base),
    ]
    in_specs = [
        pl.BlockSpec((1, c), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((c, block_d), lambda i: (0, i)),
        pl.BlockSpec((block_d,), lambda i: (i,)),
    ]
    n_leaves = len(seg_sizes) if seg_sizes else 0
    if pre is not None:
        inputs.append(pre[None, :])
        in_specs.append(pl.BlockSpec((1, c), lambda i: (0, 0)))
    if compression != "none":
        seg = jnp.asarray(
            np.repeat(np.arange(n_leaves), seg_sizes), jnp.int32
        )
        tab = segment_table(
            updates, compression, topk_fraction, seg_sizes, pre=pre
        )
        inputs += [padded(seg), tab]
        in_specs += [
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((c, n_leaves), lambda i: (0, 0)),
        ]
    if has_dp:
        inputs.append(padded(dp_noise))
        in_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))
    if has_mu:
        inputs.append(padded(momentum))
        in_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))

    dp_total = d + pad
    grid = (dp_total // block_d,)
    out_shape = [jax.ShapeDtypeStruct((dp_total,), base.dtype)]
    out_specs = [pl.BlockSpec((block_d,), lambda i: (i,))]
    if has_mu:
        out_shape.append(jax.ShapeDtypeStruct((dp_total,), momentum.dtype))
        out_specs.append(pl.BlockSpec((block_d,), lambda i: (i,)))

    kernel = _make_pipeline_kernel(
        n_leaves, pre is not None, compression, has_dp, has_mu,
        server_optimizer, float(server_momentum),
    )
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if has_mu else out_specs[0],
        out_shape=out_shape if has_mu else out_shape[0],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    if has_mu:
        return outs[0][:d], outs[1][:d]
    return outs[:d]
