"""Pure-jnp oracle for the flash-attention kernel.

Layout convention for the kernel (heads-major, seq blocked):
    q: (B, H, Sq, hd)   k/v: (B, Hkv, Sk, hd)
Mask: causal + optional sliding window (window <= 0 means global), with
q tokens occupying the LAST Sq positions of the Sk-long key sequence
(so prefill with Sq == Sk is the usual causal case).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int = 0,
    bidirectional: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    groups = h // hkv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    scale = hd**-0.5
    scores = (
        jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    if not bidirectional:
        q_pos = jnp.arange(sq) + (sk - sq)
        k_pos = jnp.arange(sk)
        visible = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            visible &= (q_pos[:, None] - k_pos[None, :]) < window
        scores = jnp.where(visible[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
