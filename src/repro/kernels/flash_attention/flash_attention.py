"""Pallas TPU flash-attention forward kernel (causal / SWA / GQA).

Design (TPU-native, not a CUDA port):
  * grid = (B, H, num_q_blocks, num_kv_blocks) — kv innermost ("arbitrary"
    semantics), so the online-softmax state for one q tile lives in VMEM
    scratch across kv steps and is flushed to HBM exactly once per q tile.
  * BlockSpec tiles: q (1,1,block_q,hd), k/v (1,1,block_kv,hd) — for the
    default (block_q, block_kv, hd) = (256, 512, 128) that is a
    ~(256+2·512)·128·2B ≈ 0.3 MB streaming working set plus (256×128) fp32
    accumulators, comfortably inside the ~16 MB/core VMEM budget, with the
    MXU-aligned 128-lane last dim.
  * GQA via the k/v index_map (head h reads kv head h // group) — no
    repeated-KV materialization in HBM.
  * causal + sliding-window handled by *block skipping* (out-of-mask tiles
    are never visited: the kv grid dimension is bounded per q tile) plus an
    in-tile mask on the boundary tiles.

Validated against ref.py in interpret mode (tests/test_kernels.py sweeps
shapes/dtypes); on real TPUs drop-in via ops.flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 512
_NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch, acc_scratch,
    *, scale: float, block_q: int, block_kv: int, sq: int, sk: int,
    window: int, bidirectional: bool,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    # Positions: q rows sit at the tail of the key timeline.
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + (sk - sq)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if not bidirectional:
            mask = k_pos <= q_pos
            if window > 0:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scratch[...]  # (bq, 128) lane-broadcast stats
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        m_safe = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, :1])  # (bq, bkv)
        corr = jnp.exp(
            jnp.where(m_prev <= _NEG_INF / 2, _NEG_INF, m_prev) - m_safe
        )
        l_new = l_prev * corr + jnp.broadcast_to(
            jnp.sum(p, axis=-1, keepdims=True), l_prev.shape
        )
        v = v_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, hd)
        acc_scratch[...] = acc_scratch[...] * corr[:, :1] + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    if bidirectional:
        compute()
    else:
        # Block-level skip: tile is dead if entirely above the diagonal or
        # entirely outside the sliding window.
        first_q = qi * block_q + (sk - sq)
        last_q = first_q + block_q - 1
        first_k = kj * block_kv
        dead_causal = first_k > last_q
        dead_window = (
            (first_q - (first_k + block_kv - 1)) >= window if window > 0 else False
        )
        pl.when(jnp.logical_not(jnp.logical_or(dead_causal, dead_window)))(compute)

    @pl.when(kj == nk - 1)
    def _flush():
        l = l_scratch[...][:, :1]
        o_ref[0, 0, ...] = (
            acc_scratch[...] / jnp.maximum(l, 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "window", "bidirectional", "block_q", "block_kv", "interpret"
    ),
)
def flash_attention_fwd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,
    *,
    window: int = 0,
    bidirectional: bool = False,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    groups = h // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0, (sq, block_q, sk, block_kv)
    nq, nk = sq // block_q, sk // block_kv

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _fwd_kernel,
        scale=hd**-0.5,
        block_q=block_q,
        block_kv=block_kv,
        sq=sq,
        sk=sk,
        window=window,
        bidirectional=bidirectional,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd),
                lambda bb, hh, qq, kk, g=groups: (bb, hh // g, kk, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd),
                lambda bb, hh, qq, kk, g=groups: (bb, hh // g, kk, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda bb, hh, qq, kk: (bb, hh, qq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max (lane-bcast)
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
