"""jit'd public wrapper for the flash-attention kernel.

Accepts the model-layer layout (B, S, H, hd) and window semantics used by
``models/layers.select_attention`` (window == -1 means global) and handles
CPU fallback to interpret mode so the same call-site runs everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK_KV,
    DEFAULT_BLOCK_Q,
    flash_attention_fwd,
)
from repro.kernels.pallas_compat import interpret_default


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    q_positions=None,
    k_positions=None,
    window=-1,
    *,
    bidirectional: bool = False,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
) -> jax.Array:
    del q_positions, k_positions  # contiguous tail-aligned layout assumed
    win = int(window) if window is not None else -1
    win = 0 if win < 0 else win  # kernel convention: 0 = global
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_fwd(
        qt,
        kt,
        vt,
        window=win,
        bidirectional=bidirectional,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret_default(),
    )
    return out.swapaxes(1, 2)
