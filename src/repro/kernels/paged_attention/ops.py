"""jit'd public wrapper for the paged flash-decode attention kernel.

Accepts the serving engine's layout — q ``(S, H, hd)`` (one query token
per slot), the physical page pool and the slot page table — with the
model-layer window convention (``-1``/GLOBAL = unbounded causal). Falls
back to interpret mode off-TPU via the shared ``pallas_compat`` policy so
the same call-site runs everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_fwd
from repro.kernels.pallas_compat import interpret_default


def paged_attention(
    q: jax.Array,  # (S, H, hd)
    k_pages: jax.Array,  # (P, page, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (S, pages_per_slot) int32
    lengths: jax.Array,  # (S,) int32 — valid tokens per slot incl. current
    window=-1,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    s, h, hd = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    assert g * hkv == h, (h, hkv)
    win = int(window) if window is not None else -1
    win = 0 if win < 0 else win  # kernel convention: 0 = global
    out = paged_attention_fwd(
        q.reshape(s, hkv, g, hd),
        k_pages,
        v_pages,
        page_table,
        lengths,
        window=win,
        interpret=interpret_default(interpret),
    )
    out = out.reshape(s, h, hd)
    return jnp.where((lengths > 0)[:, None, None], out, 0).astype(q.dtype)
