"""Pallas TPU paged flash-decode attention kernel (ragged slot batch).

One query token per slot against a physical page pool, with the slot ->
page indirection resolved *in the HBM pass*:

  * grid = (S, Hkv, pages_per_slot) — pages innermost ("arbitrary"
    semantics) so the online-softmax state for one (slot, kv-head) lives
    in VMEM scratch across page steps and is flushed exactly once.
  * the page gather rides the k/v BlockSpec index_map through scalar
    prefetch (``pltpu.PrefetchScalarGridSpec``): block ``p`` of slot
    ``s`` is fetched from physical page ``page_table[s, p]`` — no
    gathered copy of the cache is ever materialized in HBM.
  * raggedness is handled in-kernel: ``lengths[s]`` (prefetched to SMEM)
    masks the boundary page and *skips* fully-dead pages (beyond the
    slot's length, outside its sliding window, or an empty slot), so a
    freshly-admitted short request costs only its own pages while a
    long-lived slot in the same batch streams all of its pages.
  * GQA via the q reshape (S, Hkv, groups, hd): each grid step scores
    one kv head's ``groups`` query heads against one page — the kv page
    is read once per kv head, never repeated.

Validated bitwise-adjacent (fp32 tolerance: online softmax reassociates)
against ``ref.paged_attention_ref`` in interpret mode across archetypes
(GQA/MHA, sliding window, ragged lengths) in tests/test_serving.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG_INF = -1e30


def _decode_kernel(
    tab_ref,  # (S, n_pages) int32 SMEM — scalar-prefetched page table
    len_ref,  # (S,) int32 SMEM — valid tokens per slot (incl. current)
    q_ref,  # (1, 1, g, hd) VMEM
    k_ref,  # (1, page, 1, hd) VMEM — physical page tab[s, p]
    v_ref,  # (1, page, 1, hd) VMEM
    o_ref,  # (1, 1, g, hd) VMEM
    m_scratch,  # (g, 128) f32 — running max, lane-broadcast
    l_scratch,  # (g, 128) f32 — running denominator
    acc_scratch,  # (g, hd) f32 — output accumulator
    *,
    scale: float,
    page: int,
    window: int,  # kernel convention: 0 = unbounded causal
):
    s = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    length = len_ref[s]
    q_pos = length - 1  # the query token sits at the slot's last position
    first_k = p * page
    live = first_k < length
    if window > 0:
        # Pages entirely below the sliding window are dead too.
        live = jnp.logical_and(live, (first_k + page - 1) > q_pos - window)

    @pl.when(live)
    def _compute():
        g = q_ref.shape[2]
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (g, page)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        scores = jnp.where(mask, scores, _NEG_INF)

        m_prev = m_scratch[...]
        l_prev = l_scratch[...]
        m_cur = jnp.max(scores, axis=-1, keepdims=True)  # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        m_safe = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
        probs = jnp.exp(scores - m_safe[:, :1])  # (g, page)
        corr = jnp.exp(
            jnp.where(m_prev <= _NEG_INF / 2, _NEG_INF, m_prev) - m_safe
        )
        l_new = l_prev * corr + jnp.broadcast_to(
            jnp.sum(probs, axis=-1, keepdims=True), l_prev.shape
        )
        v = v_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        pv = jax.lax.dot_general(
            probs, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (g, hd)
        acc_scratch[...] = acc_scratch[...] * corr[:, :1] + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(p == n_pages - 1)
    def _flush():
        l = l_scratch[...][:, :1]
        o_ref[0, 0, ...] = (
            acc_scratch[...] / jnp.maximum(l, 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret")
)
def paged_attention_fwd(
    q: jax.Array,  # (S, Hkv, g, hd) — query heads grouped under kv heads
    k_pages: jax.Array,  # (P, page, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (S, pages_per_slot) int32
    lengths: jax.Array,  # (S,) int32
    *,
    window: int = 0,
    interpret: bool = False,
) -> jax.Array:
    s, hkv, g, hd = q.shape
    _, page, _, _ = k_pages.shape
    n_pages = page_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda ss, hh, pp, tab, ln: (ss, hh, 0, 0)),
            pl.BlockSpec(
                (1, page, 1, hd),
                lambda ss, hh, pp, tab, ln: (tab[ss, pp], 0, hh, 0),
            ),
            pl.BlockSpec(
                (1, page, 1, hd),
                lambda ss, hh, pp, tab, ln: (tab[ss, pp], 0, hh, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, hd), lambda ss, hh, pp, tab, ln: (ss, hh, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, scale=hd**-0.5, page=page, window=window
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, hkv, g, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.asarray(page_table, jnp.int32),
        jnp.asarray(lengths, jnp.int32),
        q,
        k_pages,
        v_pages,
    )
