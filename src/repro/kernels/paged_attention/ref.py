"""Dense-gather reference for paged decode attention.

The oracle gathers each slot's pages back into a contiguous
``(S, max_len, Hkv, hd)`` cache and calls the exact decode-attention the
static serving path uses (``models.layers.attention_decode``) — so the
paged kernel is tested against the SAME attention the sequential
per-request oracle runs, keeping the serving engine's token-for-token
contract and the kernel's oracle discipline one and the same check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_decode

Array = jax.Array


def gather_pages(pages: Array, page_table: Array) -> Array:
    """(P, page, Hkv, hd) pool + (S, n) table -> contiguous (S, n*page, Hkv, hd).

    Logical pages are gathered in table order, so position ``t`` of slot
    ``s`` lands at row ``t`` — identical layout to a contiguous KV cache.
    """
    s, n = page_table.shape
    g = pages[page_table]  # (S, n, page, Hkv, hd)
    return g.reshape(s, n * pages.shape[1], *pages.shape[2:])


def paged_attention_ref(
    q: Array,  # (S, H, hd) — one query token per slot
    k_pages: Array,  # (P, page, Hkv, hd) physical page pool
    v_pages: Array,  # (P, page, Hkv, hd)
    page_table: Array,  # (S, pages_per_slot) int32 — logical -> physical
    lengths: Array,  # (S,) int32 — valid tokens per slot INCLUDING current
    window: int = -1,  # model convention: -1/GLOBAL = unbounded causal
) -> Array:
    """Ragged decode attention over the paged cache, dense-gather form.

    Slots with ``lengths == 0`` (empty/evicted) return exact zeros.
    """
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    out = attention_decode(q[:, None], k, v, lengths - 1, window)[:, 0]
    return jnp.where((lengths > 0)[:, None, None], out, 0).astype(q.dtype)
