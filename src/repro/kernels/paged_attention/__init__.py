"""Paged flash-decode attention for the continuous-batching slot batch.

``ops.paged_attention`` is the public entry point; ``ref.paged_attention_ref``
is the dense-gather oracle (page-table gather + ``models.layers.
attention_decode``) every kernel change is tested against.
"""
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention", "paged_attention_ref"]
