"""Public wrappers for the fused FedAvg aggregation kernel."""
from repro.kernels.fedavg.fedavg import fedavg_apply, fedavg_apply_tree

__all__ = ["fedavg_apply", "fedavg_apply_tree"]
