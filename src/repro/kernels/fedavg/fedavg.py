"""Pallas TPU kernel: fused masked-weighted FedAvg aggregation + apply.

The aggregation step of Eq. 6 touches every parameter once per client —
it is purely memory-bound. XLA lowers the naive expression as (mask·weight
broadcast) → (N,D) multiply → reduce → add: up to three passes over the
(N, D) update matrix in HBM. This kernel fuses normalization, weighting,
reduction and the server apply into ONE pass with a single (1,N)×(N,bd)
MXU matmul per tile:

  grid = (D / block_d,)
  blocks: updates (N, block_d) VMEM tile, base (block_d,), out (block_d,)
  normalized client weights are tiny (N,) and ride along as a full block.

block_d = 2048 with N = 64 clients is a 512 KB bf16 tile — VMEM-friendly
and wide enough to saturate HBM bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, interpret_default

DEFAULT_BLOCK_D = 2048


def _fedavg_kernel(wn_ref, upd_ref, base_ref, out_ref):
    wn = wn_ref[0, :].astype(jnp.float32)  # (N,) lr-scaled normalized weights
    upd = upd_ref[...].astype(jnp.float32)  # (N, bd)
    agg = jax.lax.dot_general(
        wn[None, :], upd, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, bd)
    out_ref[...] = (
        base_ref[...].astype(jnp.float32) + agg[0]
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fedavg_apply(
    updates: jax.Array,  # (N, D)
    base: jax.Array,  # (D,)
    mask: jax.Array,  # (N,) bool
    weights: jax.Array,  # (N,) |D_i|
    lr: jax.Array | float = 1.0,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = interpret_default(interpret)
    n, d = updates.shape
    wn = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    # lr rides in the tiny (1, N) weight vector rather than as a kernel
    # compile-time constant, so a traced server_lr (sweep-lifted config
    # data) does not force a recompile per grid point.
    wn = (jnp.asarray(lr, jnp.float32) * wn / (jnp.sum(wn) + 1e-12))[None, :]

    block_d = min(block_d, d)
    pad = (-d) % block_d
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
        base = jnp.pad(base, (0, pad))
    dp = d + pad
    grid = (dp // block_d,)

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), base.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(wn, updates, base)
    return out[:d]


def fedavg_apply_tree(updates_tree, base_tree, mask, weights, lr=1.0):
    """Apply the kernel leaf-wise over parameter pytrees.

    updates_tree leaves: (N, ...) stacked client deltas; base_tree: (...)."""
    def one(upd, base):
        flat_u = upd.reshape(upd.shape[0], -1)
        flat_b = base.reshape(-1)
        return fedavg_apply(flat_u, flat_b, mask, weights, lr=lr).reshape(
            base.shape
        )

    return jax.tree.map(one, updates_tree, base_tree)
