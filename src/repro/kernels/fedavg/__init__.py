from repro.kernels.fedavg.ops import fedavg_apply, fedavg_apply_tree
from repro.kernels.fedavg.ref import fedavg_apply_ref

__all__ = ["fedavg_apply", "fedavg_apply_tree", "fedavg_apply_ref"]
