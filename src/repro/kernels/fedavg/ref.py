"""Pure-jnp oracle for the fused FedAvg aggregation kernel.

    out = base + lr · Σ_i  m_i·ω_i·Δ_i / Σ_j m_j·ω_j

updates: (N, D) client deltas; base: (D,); mask: (N,) bool; weights: (N,)
(|D_i| dataset sizes). Matches core/aggregation.fedavg_stacked + server
apply in one expression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg_apply_ref(updates, base, mask, weights, lr: float = 1.0):
    w = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    w = w / (jnp.sum(w) + 1e-12)
    agg = jnp.einsum("n,nd->d", w, updates.astype(jnp.float32))
    return (base.astype(jnp.float32) + lr * agg).astype(base.dtype)
