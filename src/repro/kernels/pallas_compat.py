"""Version-compat shims + shared defaults for the Pallas TPU API.

``pltpu.CompilerParams`` was renamed across JAX releases (older releases
expose ``TPUCompilerParams``; newer ones ``CompilerParams``). Every kernel
imports the name from here so the repo tracks whichever the installed JAX
provides.

``interpret_default`` is the single definition of the kernel families'
interpret-mode fallback: run the real Mosaic lowering on TPU, the Pallas
interpreter everywhere else (CPU/GPU hosts — a correctness tool, not a
perf path). Kernels take ``interpret: bool | None = None`` and resolve it
through here so the TPU-detection logic cannot drift between families.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def interpret_default(interpret: bool | None = None) -> bool:
    """Resolve a kernel's interpret-mode argument: an explicit value wins;
    ``None`` means "interpret everywhere except a real TPU backend"."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


__all__ = ["CompilerParams", "interpret_default"]
