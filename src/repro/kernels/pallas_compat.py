"""Version-compat shims for the Pallas TPU API.

``pltpu.CompilerParams`` was renamed across JAX releases (older releases
expose ``TPUCompilerParams``; newer ones ``CompilerParams``). Every kernel
imports the name from here so the repo tracks whichever the installed JAX
provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
