"""Pure-jnp oracle for the wkv6 kernel: sequential RWKV6 recurrence.

    y_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

r/k/v/w: (B, T, H, K|V); u: (H, K); state: (B, H, K, V) fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, initial_state=None):
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    s0 = (
        jnp.zeros((b, h, dk, dv), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    u32 = u.astype(jnp.float32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = (z.astype(jnp.float32) for z in xs)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u32[None, :, :, None] * kv)
        return w_t[..., None] * s + kv, y

    s_final, ys = jax.lax.scan(
        step, s0, (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), w.swapaxes(0, 1))
    )
    return ys.swapaxes(0, 1).astype(r.dtype), s_final
