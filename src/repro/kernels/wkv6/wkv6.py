"""Pallas TPU kernel for the RWKV6 (Finch) recurrence — chunked-parallel form.

TPU adaptation (DESIGN.md §6): instead of a step-by-step recurrence (VPU
serial, no MXU work), each time chunk of length C is processed in closed
form with three MXU matmuls:

    P_t   = Π_{s≤t} w_s                          (in-chunk cumulative decay)
    R~    = r ⊙ P_prev      K~ = k / P           (decay-adjusted views)
    inter = R~ @ S                               (contribution of carry-in)
    intra = tril_strict(R~ @ K~ᵀ + diag(r·(u⊙k))) @ V
    S'    = diag(P_C) S + diag(P_C) (K~ᵀ @ V)    (carry-out)

Grid = (B, H, num_chunks), chunk dim "arbitrary": the (K, V) state lives in
VMEM scratch across chunk steps. Default C=32 with fp32 math keeps the
in-chunk decay ratios P_C/P_s well-conditioned (w = exp(-exp(·)) < 1; see
module comment on stability in ops.py).

Blocks: r/k/v/w tiles (1, C, 1, K) stream through VMEM; scratch state
(K, V) fp32 = 16 KB/head at K=V=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_CHUNK = 32


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state,
                 *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (C, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (C, V)
    w = w_ref[0, :, 0, :].astype(jnp.float32)  # (C, K), in (0, 1)
    u = u_ref[0, :].astype(jnp.float32)  # (K,)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    logp = jnp.cumsum(logw, axis=0)  # (C, K): log Π_{s<=t}
    p = jnp.exp(logp)
    p_prev = jnp.exp(logp - logw)  # Π_{s<t} (exclusive)
    p_last = jnp.exp(logp[-1:])  # (1, K)

    s = state[...]  # (K, V) carry-in
    r_adj = r * p_prev  # (C, K)
    k_adj = k * jnp.exp(-logp)  # k / P

    inter = jax.lax.dot_general(
        r_adj, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, V)
    scores = jax.lax.dot_general(
        r_adj, k_adj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C): entry (t, s) = r_t·(P_{t-1}/P_s ⊙ k_s)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(s_idx < t_idx, scores, 0.0)  # strictly causal
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (C,) current-token bonus
    intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + diag[:, None] * v

    y_ref[0, :, 0, :] = (inter + intra).astype(y_ref.dtype)

    ktv = jax.lax.dot_general(
        k_adj, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (K, V)
    state[...] = p_last.T * (s + ktv)

    @pl.when(ci == nc - 1)
    def _flush():
        s_out_ref[0, 0] = state[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def wkv6_fwd(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, T, H, V)
    w: jax.Array,  # (B, T, H, K) decays in (0,1)
    u: jax.Array,  # (H, K)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """Returns (y (B,T,H,V), final_state (B,H,K,V) fp32)."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    grid = (b, h, nc)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    seq_spec = lambda: pl.BlockSpec(
        (1, chunk, 1, dk), lambda bb, hh, cc: (bb, cc, hh, 0)
    )
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec(),
            seq_spec(),
            pl.BlockSpec((1, chunk, 1, dv), lambda bb, hh, cc: (bb, cc, hh, 0)),
            seq_spec(),
            pl.BlockSpec((1, dk), lambda bb, hh, cc: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dv), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, dv), r.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_final
