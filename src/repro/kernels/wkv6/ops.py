"""jit'd public wrapper for the wkv6 kernel.

Stability note: the chunked-parallel form divides by in-chunk cumulative
decay products P. With the Finch parameterization w = exp(-exp(ww)) the
per-step decay can be tiny, so P can underflow across a long chunk; the
default chunk of 32 with fp32 math keeps log(P) > -38·32 only for
pathological ww > 2.9 — we clamp w to exp(-20) per step (an exact no-op for
any state that could still matter numerically: 20 nats of decay ≈ 1e-9).

Falls back to interpret mode off-TPU; model code uses ssm.wkv6 (jnp) by
default and switches here when cfg routes through the kernel path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.wkv6 import DEFAULT_CHUNK, wkv6_fwd
from repro.kernels.pallas_compat import interpret_default


def wkv6(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK):
    """r/k/w: (B,T,H,K), v: (B,T,H,V), u: (H,K) -> (y, final_state)."""
    t = r.shape[1]
    pad = (-t) % chunk
    if pad:
        zp = lambda z: jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    w = jnp.maximum(w, jnp.asarray(jnp.exp(-20.0), w.dtype))
    y, s = wkv6_fwd(r, k, v, w, u, chunk=chunk, interpret=interpret_default())
    return y[:, :t], s
