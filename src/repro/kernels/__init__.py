"""Pallas TPU kernels (TPU-target; validated via interpret mode on CPU).

Each kernel ships three files: the pl.pallas_call implementation with
explicit BlockSpec VMEM tiling, ops.py (jit'd public wrapper with CPU
interpret fallback) and ref.py (pure-jnp oracle used by the allclose
sweeps in tests/test_kernels.py).
"""
