"""Production mesh contract (launch brief, verbatim).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants for the roofline (EXPERIMENTS.md §Roofline).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
CHIPS_PER_POD = 256
