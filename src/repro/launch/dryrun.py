"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the fake-device flag before ANY other import (jax locks the
device count on first init).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_skips
from repro.configs.shapes import (
    SHAPES,
    ShapeSpec,
    batch_specs,
    cache_specs,
    decode_token_specs,
)
from repro.dist.hlo_analysis import analyze_hlo
from repro.dist.sharding import ShardingRules, make_rules
from repro.fl import FLConfig, abstract_fl_state, make_round_fn
from repro.launch import mesh as mesh_mod
from repro.models import Runtime, build_model
from repro.models.config import Family

# Resolved against the CWD (NOT the module: an installed package would
# point outside the tree) and created lazily in main().
RESULT_DIR = os.path.join("results", "dryrun")


# --------------------------------------------------------------------- #
# Per-cell builders
# --------------------------------------------------------------------- #
def _scan_block(num_layers: int) -> int:
    for b in (8, 6, 4):
        if num_layers % b == 0:
            return b
    return 0


TRAIN_MICROBATCH = int(os.environ.get("REPRO_MICROBATCH", "4"))


def _cell_config(arch: str, reduced: bool):
    """Full assigned config, or the reduced variant (CI smoke: identical
    mesh/sharding wiring, minutes-not-hours compile)."""
    if reduced:
        from repro.configs import get_reduced

        return get_reduced(arch, loss_chunk=0)
    return get_config(arch)


def shape_tuned_config(cfg, shape: ShapeSpec):
    """Runtime knobs per shape (architecture untouched)."""
    knobs = dict(loss_chunk=512,
                 remat=os.environ.get("REPRO_REMAT", "1") == "1",
                 remat_policy="nothing",
                 scan_layers=os.environ.get("REPRO_SCAN", "1") == "1")
    if shape.kind == "train":
        knobs.update(attn_impl="xla_chunked", attn_chunk_q=512,
                     attn_chunk_kv=1024,
                     scan_block=int(os.environ.get(
                         "REPRO_SCAN_BLOCK", _scan_block(cfg.num_layers))))
    elif shape.kind == "prefill":
        knobs.update(attn_impl="xla_chunked", attn_chunk_q=512, attn_chunk_kv=2048)
    return dataclasses.replace(cfg, **knobs)


def make_runtime(cfg, rules: ShardingRules, *, serving: bool = False) -> Runtime:
    if cfg.num_experts:
        tp = "tp" if rules.mesh.shape.get("tp", 1) > 1 else None
        # Under the train path the model runs inside the client-vmap, so the
        # MoE group dim only sees the intra-slot ("zero") axes; serving has
        # no client stacking and uses the full data axes.
        group_axes = rules.serve_batch_axes if serving else tuple(
            a for a in ("zero",) if a in rules.mesh.shape
        )
        return Runtime(
            mesh=rules.mesh,
            batch_axes=rules.batch_axes,
            expert_axis="expert",
            tp_axis=tp,
            # gshard: pure-einsum GSPMD expert parallelism. The shard_map
            # "ep" variant trips an XLA SPMD-partitioner CHECK on these
            # meshes (b/433785288-adjacent); see DESIGN.md §4.
            moe_impl=os.environ.get("REPRO_MOE_IMPL", "gshard"),
            moe_group_axes=group_axes,
        )
    return Runtime(mesh=rules.mesh, batch_axes=rules.batch_axes)


def fl_batch_specs(cfg, rules: ShardingRules, shape: ShapeSpec, fl_cfg: FLConfig):
    """Train-cell inputs: model batch + FL scheduler inputs."""
    n = fl_cfg.num_clients
    specs = dict(batch_specs(cfg, shape))
    specs.update(
        slot_data_sizes=jax.ShapeDtypeStruct((fl_cfg.slots,), jnp.float32),
        telemetry_cpu=jax.ShapeDtypeStruct((n,), jnp.float32),
        telemetry_mem=jax.ShapeDtypeStruct((n,), jnp.float32),
        telemetry_batt=jax.ShapeDtypeStruct((n,), jnp.float32),
        telemetry_energy=jax.ShapeDtypeStruct((n,), jnp.float32),
        hist=jax.ShapeDtypeStruct((n, fl_cfg.hist_bins), jnp.float32),
    )
    return specs, rules.fl_batch_shardings(specs)


def build_train(arch: str, shape: ShapeSpec, multi_pod: bool,
                reduced: bool = False):
    cfg = shape_tuned_config(_cell_config(arch, reduced), shape)
    pm = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    zero_env = os.environ.get("REPRO_ZERO")
    rules = make_rules(
        pm, cfg, multi_pod=multi_pod,
        zero=int(zero_env) if zero_env else None,
    )
    if os.environ.get("REPRO_FSDP") == "0":  # perf knob: ZeRO w/o param FSDP
        rules = dataclasses.replace(
            rules, plan=dataclasses.replace(rules.plan, fsdp_params=False)
        )
    if os.environ.get("REPRO_UNROLL_LAYERS") == "1":  # static-window knob
        cfg = dataclasses.replace(cfg, scan_layers=False, scan_block=0)
    model = build_model(cfg)
    per_slot = shape.global_batch // rules.plan.num_clients
    fl_cfg = FLConfig(
        num_clients=64,
        slots=rules.plan.num_clients,
        local_steps=int(os.environ.get("REPRO_LOCAL_STEPS", "1")),
        microbatch=min(TRAIN_MICROBATCH, per_slot),
        inner_optimizer="sgdm",
        server_optimizer="fedavgm",
    )
    runtime = make_runtime(cfg, rules)
    tokens_per_client = shape.seq_len * shape.global_batch / fl_cfg.slots
    round_fn = make_round_fn(
        model,
        fl_cfg,
        runtime,
        flops_per_client_round=model.flops_per_token() * tokens_per_client,
        rules=rules,
    )

    state_abs = abstract_fl_state(model, fl_cfg)
    state_shardings = rules.shardings(rules.fl_state_specs(model, state_abs))
    batch_abs, batch_shardings = fl_batch_specs(cfg, rules, shape, fl_cfg)

    jitted = jax.jit(
        round_fn,
        in_shardings=(state_shardings, batch_shardings),
        donate_argnums=(0,),
    )
    return jitted, (state_abs, batch_abs), rules, pm, cfg


def build_prefill(arch: str, shape: ShapeSpec, multi_pod: bool,
                  reduced: bool = False):
    cfg = shape_tuned_config(_cell_config(arch, reduced), shape)
    if os.environ.get("REPRO_UNROLL_LAYERS") == "1":  # static-window knob
        cfg = dataclasses.replace(cfg, scan_layers=False)
    pm = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(pm, cfg, multi_pod=multi_pod)
    model = build_model(cfg)
    runtime = make_runtime(cfg, rules, serving=True)

    def prefill_fn(params, batch):
        return model.prefill(params, batch, cache_len=shape.seq_len, runtime=runtime)

    shapes, laxes = model.param_shapes(), model.param_axes()
    p_shardings = rules.shardings(
        rules.param_specs(shapes, laxes, stacked=False, fsdp=False)
    )
    batch_abs = batch_specs(cfg, shape)
    b_shardings = {
        k: jax.sharding.NamedSharding(rules.mesh, v)
        for k, v in rules.serve_batch_specs(batch_abs).items()
    }
    jitted = jax.jit(prefill_fn, in_shardings=(p_shardings, b_shardings))
    return jitted, (shapes, batch_abs), rules, pm, cfg


def build_decode(arch: str, shape: ShapeSpec, multi_pod: bool,
                 reduced: bool = False):
    cfg = shape_tuned_config(_cell_config(arch, reduced), shape)
    if os.environ.get("REPRO_DECODE_F32") == "1":  # legalization probe
        cfg = dataclasses.replace(
            cfg, compute_dtype="float32", param_dtype="float32"
        )
    pm = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(pm, cfg, multi_pod=multi_pod)
    model = build_model(cfg)
    runtime = make_runtime(cfg, rules, serving=True)

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens, runtime)

    shapes, laxes = model.param_shapes(), model.param_axes()
    p_shardings = rules.shardings(
        rules.param_specs(shapes, laxes, stacked=False, fsdp=False)
    )
    cache_abs = cache_specs(model, shape)
    c_shardings = rules.shardings(rules.cache_specs(cache_abs))
    tok_abs = decode_token_specs(shape)
    t_sharding = jax.sharding.NamedSharding(
        rules.mesh, rules.serve_batch_specs({"t": tok_abs})["t"]
    )
    jitted = jax.jit(
        decode_fn,
        in_shardings=(p_shardings, c_shardings, t_sharding),
        donate_argnums=(1,),
    )
    return jitted, (shapes, cache_abs, tok_abs), rules, pm, cfg


# --------------------------------------------------------------------- #
# Cell runner
# --------------------------------------------------------------------- #
def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             reduced: bool = False):
    shape = SHAPES[shape_name]
    skips = get_skips(arch)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
    }
    if shape_name in skips:
        result["status"] = "SKIP"
        result["skip_reason"] = skips[shape_name]
        return result

    t0 = time.time()
    try:
        if shape.kind == "train":
            jitted, args, rules, pm, cfg = build_train(
                arch, shape, multi_pod, reduced)
        elif shape.kind == "prefill":
            jitted, args, rules, pm, cfg = build_prefill(
                arch, shape, multi_pod, reduced)
        else:
            jitted, args, rules, pm, cfg = build_decode(
                arch, shape, multi_pod, reduced)

        with pm:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax>=0.4.35: list per program
            cost = cost[0] if cost else {}
        hlo = analyze_hlo(compiled.as_text())
        stats = hlo.collectives

        mem_dict = {}
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(mem, attr):
                mem_dict[attr] = int(getattr(mem, attr))
        if verbose:
            print(f"  memory_analysis: {mem_dict}")
            print(
                "  cost_analysis: flops=%.3e bytes=%.3e"
                % (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0))
            )
            print(
                f"  collectives: total={stats.total_bytes:.3e} B "
                f"{ {k: f'{v:.2e}' for k, v in stats.bytes_by_kind.items()} }"
            )

        result.update(
            status="OK",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem_dict,
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            transcendentals=cost.get("transcendentals", 0.0),
            dot_flops=hlo.dot_flops,
            hbm_bytes=hlo.hbm_bytes,
            hbm_bytes_out=hlo.hbm_bytes_out,
            collective_bytes=stats.bytes_by_kind,
            collective_total=stats.total_bytes,
            collective_counts=stats.count_by_kind,
            trip_warnings=stats.trip_count_warnings[:5],
            plan={
                "zero": rules.plan.zero,
                "slots": rules.plan.num_clients,
                "model_axes": list(rules.plan.model_axes),
                "model_split": list(rules.plan.model_split),
                "fsdp": rules.plan.fsdp_params,
            },
        )
        if shape.kind == "decode" and os.environ.get("REPRO_DECODE_F32") != "1":
            # The CPU backend's bf16->f32 legalization wraps every KV-cache
            # dynamic-update-slice in convert round-trips that defeat buffer
            # aliasing (~50x temp inflation vs TPU's native-bf16 in-place
            # updates). Record a native-f32 companion compile whose temp is
            # the TPU-faithful memory proxy (EXPERIMENTS.md §Dry-run notes).
            try:
                os.environ["REPRO_DECODE_F32"] = "1"
                jax.clear_caches()
                jitted2, args2, *_ = build_decode(arch, shape, multi_pod,
                                                  reduced)
                with pm:
                    compiled2 = jitted2.lower(*args2).compile()
                mem2 = compiled2.memory_analysis()
                result["memory_f32_native"] = {
                    a: int(getattr(mem2, a))
                    for a in (
                        "argument_size_in_bytes",
                        "temp_size_in_bytes",
                    )
                    if hasattr(mem2, a)
                }
                if verbose:
                    print(f"  f32-native probe: {result['memory_f32_native']}")
            finally:
                os.environ.pop("REPRO_DECODE_F32", None)
    except Exception as e:  # record the failure, keep sweeping
        result.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    finally:
        jax.clear_caches()  # keep host RSS bounded across the 80-cell sweep
    result["elapsed_s"] = round(time.time() - t0, 1)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULT_DIR)
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--limit", type=int, default=0,
                    help="stop after N non-cached cells (CI smoke)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced configs on the full production mesh "
                         "(same sharding wiring, fast compiles)")
    args = ap.parse_args()

    arches = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    n_run = 0
    for arch in arches:
        for shape_name in shapes:
            for multi_pod in meshes:
                if args.limit and n_run >= args.limit:
                    print(f"done (limit {args.limit}); failures: {n_fail}")
                    return 1 if n_fail else 0
                tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
                if args.reduced:
                    tag += "__reduced"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        cached = json.load(f)
                    print(f"[cached] {tag}: {cached['status']}")
                    n_fail += cached["status"] == "FAIL"
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                res = run_cell(arch, shape_name, multi_pod, reduced=args.reduced)
                n_run += 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(
                    f"[dryrun] {tag}: {res['status']} "
                    f"({res.get('elapsed_s', 0)}s)"
                    + (f" ERROR: {res.get('error', '')[:200]}" if res["status"] == "FAIL" else ""),
                    flush=True,
                )
                n_fail += res["status"] == "FAIL"
    print(f"done; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
