"""Federated training driver (pod-scale path on real hardware; CPU-scaled
here). Wires: configs → model → mesh plan + sharding rules → FedFog round
→ data pipeline → checkpointing, with auto-resume.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --rounds 100 --scale tiny --ckpt-dir /tmp/fedfog_ckpt

``--scale tiny`` substitutes the reduced config + a 1-device plan so the
full driver logic (including checkpoint/restart) runs on this CPU
container. ``--scale full`` is the distribution-aware path: it builds the
mesh plan from ``repro.dist``, jits the round with in/out shardings from
``ShardingRules`` and verifies via ``analyze_hlo`` that the compiled
round contains exactly the paper's ONE inter-client all-reduce. On a TPU
pod it uses the 256-chip production mesh; on CPU, back it with fake
devices:

    python -m repro.launch.train --scale full --devices 256 --compile-only
    python -m repro.launch.train --scale full --devices 8 \
        --reduced --rounds 2          # actually executes sharded rounds
"""
from __future__ import annotations

import argparse
import os
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch-per-slot", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--inner-lr", type=float, default=0.05)
    ap.add_argument("--track", default="",
                    help="stream per-round metrics to a tracker spec: "
                         "'jsonl:PATH', 'csv:PATH', comma-separated for "
                         "multiple sinks, '' disables (see repro.obs)")
    ap.add_argument("--track-every", type=int, default=1,
                    help="decimation for --track: log every k-th round")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # --scale full knobs
    ap.add_argument("--devices", type=int, default=0,
                    help="back the full-scale mesh with N fake CPU devices "
                         "(XLA_FLAGS; must be set before jax initializes). "
                         "0 = use the real platform's device pool")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fog-nodes", type=int, default=1,
                    help="fog-tier width of the edge->fog->cloud "
                         "reduction; under a multi-pod mesh this must "
                         "equal the pod count (fog <-> pod axis), and "
                         "the HLO contract is asserted per tier")
    ap.add_argument("--population", type=int, default=None,
                    help="virtual client registry size (>= --clients); "
                         "rounds gather a stratified --clients window")
    ap.add_argument("--pallas-agg", action="store_true",
                    help="fuse the server delta pipeline into the Pallas "
                         "kernel (sharded shard_map entry under --scale "
                         "full; single-HBM-pass kernel on one host)")
    ap.add_argument("--fault-timeout-rate", type=float, default=0.0,
                    help="cold-start timeout probability (attempt 0)")
    ap.add_argument("--fault-crash-rate", type=float, default=0.0,
                    help="per-attempt function-crash probability")
    ap.add_argument("--fault-drop-rate", type=float, default=0.0,
                    help="per-attempt payload-drop probability")
    ap.add_argument("--fault-corrupt-rate", type=float, default=0.0,
                    help="arrived-payload corruption probability")
    ap.add_argument("--fault-partition-rate", type=float, default=0.0,
                    help="per-round transient network-partition probability")
    ap.add_argument("--fault-fog-outage-rate", type=float, default=0.0,
                    help="per-round per-fog-node outage probability")
    ap.add_argument("--fault-failover", action="store_true",
                    help="reassign a dead fog's clients to survivors")
    ap.add_argument("--fault-retries", type=int, default=0,
                    help="per-client retry cap (exponential backoff)")
    ap.add_argument("--fault-deadline-ms", type=float, default=None,
                    help="server round deadline (None = barrier)")
    ap.add_argument("--fault-quorum", type=float, default=0.0,
                    help="min arrived/admitted fraction to aggregate; "
                         "below quorum the round is skipped")
    ap.add_argument("--reduced", action="store_true",
                    help="with --scale full: reduced config on the real "
                         "mesh plan (CPU-executable sharded rounds)")
    ap.add_argument("--compile-only", action="store_true",
                    help="with --scale full: lower+compile the sharded "
                         "round, report collectives, skip execution")
    return ap.parse_args(argv)


def fault_config_from_args(args):
    """Build the round's ``FaultConfig`` from ``--fault-*`` flags; None
    when every knob is at its faults-off default (the round then takes
    its verbatim pre-fault path)."""
    rates = dict(
        timeout_rate=args.fault_timeout_rate,
        crash_rate=args.fault_crash_rate,
        drop_rate=args.fault_drop_rate,
        corrupt_rate=args.fault_corrupt_rate,
        partition_rate=args.fault_partition_rate,
        fog_outage_rate=args.fault_fog_outage_rate,
    )
    if not any(rates.values()) and args.fault_deadline_ms is None:
        return None
    from repro.sim.faults import FaultConfig

    return FaultConfig(
        **rates,
        fog_failover=args.fault_failover,
        max_retries=args.fault_retries,
        deadline_ms=args.fault_deadline_ms,
        quorum_frac=args.fault_quorum,
    )


def main(argv=None):
    args = parse_args(argv)
    if args.scale == "full" and args.devices:
        # Must precede the first jax backend init in this process.
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    import jax
    import jax.numpy as jnp

    from repro import checkpoint as ckpt
    from repro.configs import get_config, get_reduced
    from repro.data.synthetic import (
        FedDataConfig,
        all_client_histograms,
        client_data_sizes,
        round_batch,
    )
    from repro.data.telemetry import (
        TelemetryConfig,
        init_telemetry,
        make_profiles,
        step_telemetry,
    )
    from repro.fl import FLConfig, init_fl_state, make_round_fn
    from repro.models import Runtime, build_model
    from repro.obs import tracker_from_spec

    full = args.scale == "full"
    cfg = (
        get_config(args.arch)
        if full and not args.reduced
        else get_reduced(args.arch, loss_chunk=0)
    )
    model = build_model(cfg)

    rules = None
    if full:
        from repro.dist import make_rules
        from repro.launch import mesh as mesh_mod

        pods = 2 if args.multi_pod else 1
        if args.devices and args.devices != 256 * pods:
            # Scaled host plan (client × zero only) on N local devices.
            rules = make_rules(
                None, cfg, multi_pod=args.multi_pod,
                device_count=args.devices,
            )
        else:
            pm = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
            rules = make_rules(pm, cfg, multi_pod=args.multi_pod)
        args.slots = rules.plan.num_clients
        args.clients = max(args.clients, 2 * args.slots)
        print(f"[train] mesh plan: {dict(rules.mesh.shape)}")

    fl_cfg = FLConfig(
        num_clients=args.clients,
        slots=args.slots,
        local_steps=args.local_steps,
        inner_lr=args.inner_lr,
        use_pallas_agg=args.pallas_agg,
        fog_nodes=args.fog_nodes,
        population=args.population,
        faults=fault_config_from_args(args),
    )
    data_cfg = FedDataConfig(
        vocab_size=cfg.vocab_size, drift_period=10, seed=args.seed
    )
    tel_cfg = TelemetryConfig(num_clients=args.clients, seed=args.seed)
    profiles = make_profiles(tel_cfg)
    telemetry = init_telemetry(tel_cfg)
    sizes = client_data_sizes(data_cfg, args.clients)

    tokens_per_client = args.batch_per_slot * args.seq_len * args.local_steps
    flops_round = model.flops_per_token() * tokens_per_client

    if rules is not None:
        # Compile against abstract inputs FIRST: --compile-only never
        # allocates full-size parameters on the host.
        round_fn = _sharded_round_fn(args, cfg, model, fl_cfg, rules,
                                     flops_round)
        if args.compile_only:
            return None
    else:
        round_fn = jax.jit(
            make_round_fn(
                model,
                fl_cfg,
                Runtime(moe_impl="dropless" if cfg.num_experts else "reference"),
                flops_per_client_round=flops_round,
            ),
            donate_argnums=(0,),
        )

    key = jax.random.PRNGKey(args.seed)
    state = init_fl_state(model, fl_cfg, key)
    start_round = 0
    checkpointer = None
    if args.ckpt_dir:
        checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state = ckpt.restore(args.ckpt_dir, latest, state)
                start_round = latest
                print(f"[train] resumed from round {latest}")

    data_key = jax.random.PRNGKey(args.seed + 1)
    tracker = tracker_from_spec(args.track)
    with tracker:
        state = _train_loop(
            args, fl_cfg, data_cfg, tel_cfg, round_fn, state, telemetry,
            profiles, sizes, start_round, checkpointer, tracker,
        )
    return state


def _train_loop(args, fl_cfg, data_cfg, tel_cfg, round_fn, state, telemetry,
                profiles, sizes, start_round, checkpointer, tracker):
    import jax
    import jax.numpy as jnp

    from repro.data.synthetic import all_client_histograms, round_batch
    from repro.data.telemetry import step_telemetry

    data_key = jax.random.PRNGKey(args.seed + 1)
    for r in range(start_round, args.rounds):
        t0 = time.time()
        data_key, kb = jax.random.split(data_key)
        r_idx = jnp.asarray(r, jnp.int32)
        # Occupants for this round: previous utility order isn't known
        # host-side before the jit call, so the pipeline streams data for
        # the scheduler's PREDICTED top slots (previous-round order); the
        # round function re-ranks internally. Here: round-robin cohort.
        slot_ids = (jnp.arange(fl_cfg.slots) + r * fl_cfg.slots) % args.clients
        tokens = round_batch(
            data_cfg, slot_ids, r_idx, kb,
            args.batch_per_slot * args.local_steps, args.seq_len,
        )
        batch = {
            "tokens": tokens,
            "slot_data_sizes": sizes[slot_ids],
            "telemetry_cpu": telemetry.cpu,
            "telemetry_mem": telemetry.mem,
            "telemetry_batt": telemetry.batt,
            "telemetry_energy": telemetry.energy,
            "hist": all_client_histograms(
                data_cfg, args.clients, r_idx, fl_cfg.hist_bins
            ),
        }
        state, metrics = round_fn(state, batch)
        sel = metrics["num_selected"]
        if r % max(args.track_every, 1) == 0:
            tracker.log(
                {"event": "round", "arch": args.arch, "scale": args.scale,
                 **{k: v for k, v in metrics.items()},
                 "round_wall_s": time.time() - t0},
                step=r,
            )
        data_key, kt = jax.random.split(data_key)
        telemetry = step_telemetry(
            tel_cfg,
            telemetry,
            jnp.zeros((args.clients,), bool)
            .at[slot_ids]
            .set(True),
            jnp.zeros((args.clients,)),
            profiles,
            kt,
        )
        print(
            f"[round {r:4d}] loss={float(metrics['loss']):.4f} "
            f"selected={int(sel)} cold={int(metrics['cold_starts'])} "
            f"latency={float(metrics['round_latency_ms']):.0f}ms "
            f"energy={float(metrics['energy_j']):.1f}J "
            + (
                f"retries={int(metrics['fault_retries'])} "
                f"lost={int(metrics['fault_lost'])} "
                f"skipped={int(metrics['round_skipped'])} "
                if fl_cfg.faults is not None
                else ""
            )
            + f"({time.time() - t0:.2f}s)",
            flush=True,
        )
        if checkpointer and (r + 1) % args.ckpt_every == 0:
            checkpointer.save(r + 1, state)
    if checkpointer:
        checkpointer.wait()
    tracker.log_summary(
        {"arch": args.arch, "scale": args.scale,
         "rounds": args.rounds - start_round,
         "final_loss": float(metrics["loss"]) if args.rounds > start_round
         else 0.0}
    )
    return state


def _sharded_round_fn(args, cfg, model, fl_cfg, rules, flops_round):
    """AOT-compile the round with shardings from the rules against
    abstract inputs (so --compile-only never allocates parameters and
    round 0 doesn't re-trace), and enforce the paper's communication
    contract: exactly ONE inter-client all-reduce (the Eq. 6 delta
    aggregation) in the compiled round body. Returns the compiled
    executable."""
    import jax
    import jax.numpy as jnp

    from repro.dist import analyze_hlo
    from repro.dist.hlo_analysis import assert_inter_client_contract
    from repro.fl import abstract_fl_state, make_round_fn
    from repro.models import Runtime

    mesh_shape = rules.mesh.shape
    runtime = Runtime(
        mesh=rules.mesh,
        batch_axes=rules.batch_axes,
        expert_axis="expert" if cfg.num_experts else None,
        tp_axis="tp" if mesh_shape.get("tp", 1) > 1 else None,
        moe_impl="gshard" if cfg.num_experts else "dropless",
        moe_group_axes=tuple(a for a in ("zero",) if mesh_shape.get(a, 1) > 1),
    )
    round_fn = make_round_fn(
        model, fl_cfg, runtime,
        flops_per_client_round=flops_round, rules=rules,
    )

    state_abs = abstract_fl_state(model, fl_cfg)
    state_sh = rules.shardings(rules.fl_state_specs(model, state_abs))

    gb = fl_cfg.slots * args.batch_per_slot * args.local_steps
    n = fl_cfg.num_clients
    f32 = jnp.float32
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((gb, args.seq_len + 1), jnp.int32),
        "slot_data_sizes": jax.ShapeDtypeStruct((fl_cfg.slots,), f32),
        "telemetry_cpu": jax.ShapeDtypeStruct((n,), f32),
        "telemetry_mem": jax.ShapeDtypeStruct((n,), f32),
        "telemetry_batt": jax.ShapeDtypeStruct((n,), f32),
        "telemetry_energy": jax.ShapeDtypeStruct((n,), f32),
        "hist": jax.ShapeDtypeStruct((n, fl_cfg.hist_bins), f32),
    }
    batch_sh = rules.fl_batch_shardings(batch_abs)

    # out_shardings pins the advanced state to the SAME layout as the
    # input: the compiled object's strict call-time sharding check must
    # accept round r's output as round r+1's input. Without this the
    # sharded kernel path hands params back replicated (the shard_map
    # epilogue's layout) and round 1 rejects them.
    jitted = jax.jit(
        round_fn, in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None), donate_argnums=(0,),
    )
    t0 = time.time()
    compiled = jitted.lower(state_abs, batch_abs).compile()
    print(f"[train] sharded round compiled in {time.time() - t0:.1f}s")

    hlo = analyze_hlo(compiled.as_text())
    stats = hlo.collectives
    print(f"[train] collectives: {stats.count_by_kind} "
          f"bytes={ {k: f'{v:.2e}' for k, v in stats.bytes_by_kind.items()} }")
    for w in stats.trip_count_warnings[:3]:
        print(f"[train] note: {w}")

    # Raises on violation — holds on both the reference aggregation and
    # the sharded delta-pipeline kernel path (--pallas-agg). With a fog
    # tier on the kernel path the contract is per-tier (edge psum + fog
    # psum); the reference fog path is GSPMD-scheduled and legally
    # fuses back to the flat single all-reduce.
    contract_fog = fl_cfg.fog_nodes if fl_cfg.use_pallas_agg else 1
    _, delta_bytes = assert_inter_client_contract(
        hlo, rules, model.param_count(), fog_nodes=contract_fog
    )
    if rules.client_ways > 1:
        tiers = ("one delta all-reduce PER TIER (edge+fog)"
                 if contract_fog > 1 else "ONE inter-client all-reduce")
        print(f"[train] verified: {tiers} "
              f"({delta_bytes:.2e} B delta payload)")
    return compiled


if __name__ == "__main__":
    main()
