"""Federated training driver (pod-scale path on real hardware; CPU-scaled
here). Wires: configs → model → sharding rules → FedFog round → data
pipeline → checkpointing, with auto-resume.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --rounds 100 --scale tiny --ckpt-dir /tmp/fedfog_ckpt

``--scale tiny|smoke`` substitutes the reduced config + a 1-device plan so
the full driver logic (including checkpoint/restart) runs on this CPU
container; on a TPU pod, drop --scale and the production mesh is used.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config, get_reduced
from repro.configs.shapes import SHAPES
from repro.data.synthetic import (
    FedDataConfig,
    all_client_histograms,
    client_data_sizes,
    round_batch,
)
from repro.data.telemetry import TelemetryConfig, init_telemetry, make_profiles, step_telemetry
from repro.fl import FLConfig, init_fl_state, make_round_fn
from repro.models import Runtime, build_model


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch-per-slot", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--inner-lr", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = (
        get_reduced(args.arch, loss_chunk=0)
        if args.scale == "tiny"
        else get_config(args.arch)
    )
    model = build_model(cfg)
    fl_cfg = FLConfig(
        num_clients=args.clients,
        slots=args.slots,
        local_steps=args.local_steps,
        inner_lr=args.inner_lr,
    )
    data_cfg = FedDataConfig(
        vocab_size=cfg.vocab_size, drift_period=10, seed=args.seed
    )
    tel_cfg = TelemetryConfig(num_clients=args.clients, seed=args.seed)
    profiles = make_profiles(tel_cfg)
    telemetry = init_telemetry(tel_cfg)
    sizes = client_data_sizes(data_cfg, args.clients)

    key = jax.random.PRNGKey(args.seed)
    state = init_fl_state(model, fl_cfg, key)
    start_round = 0
    checkpointer = None
    if args.ckpt_dir:
        checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state = ckpt.restore(args.ckpt_dir, latest, state)
                start_round = latest
                print(f"[train] resumed from round {latest}")

    tokens_per_client = args.batch_per_slot * args.seq_len * args.local_steps
    round_fn = jax.jit(
        make_round_fn(
            model,
            fl_cfg,
            Runtime(moe_impl="dropless" if cfg.num_experts else "reference"),
            flops_per_client_round=model.flops_per_token() * tokens_per_client,
        ),
        donate_argnums=(0,),
    )

    gb = args.slots * args.batch_per_slot * args.local_steps
    data_key = jax.random.PRNGKey(args.seed + 1)
    for r in range(start_round, args.rounds):
        t0 = time.time()
        data_key, kb = jax.random.split(data_key)
        r_idx = jnp.asarray(r, jnp.int32)
        # Occupants for this round: previous utility order isn't known
        # host-side before the jit call, so the pipeline streams data for
        # the scheduler's PREDICTED top slots (previous-round order); the
        # round function re-ranks internally. Here: round-robin cohort.
        slot_ids = (jnp.arange(fl_cfg.slots) + r * fl_cfg.slots) % args.clients
        tokens = round_batch(
            data_cfg, slot_ids, r_idx, kb,
            args.batch_per_slot * args.local_steps, args.seq_len,
        )
        batch = {
            "tokens": tokens,
            "slot_data_sizes": sizes[slot_ids],
            "telemetry_cpu": telemetry.cpu,
            "telemetry_mem": telemetry.mem,
            "telemetry_batt": telemetry.batt,
            "telemetry_energy": telemetry.energy,
            "hist": all_client_histograms(
                data_cfg, args.clients, r_idx, fl_cfg.hist_bins
            ),
        }
        state, metrics = round_fn(state, batch)
        sel = metrics["num_selected"]
        data_key, kt = jax.random.split(data_key)
        telemetry = step_telemetry(
            tel_cfg,
            telemetry,
            jnp.zeros((args.clients,), bool)
            .at[slot_ids]
            .set(True),
            jnp.zeros((args.clients,)),
            profiles,
            kt,
        )
        print(
            f"[round {r:4d}] loss={float(metrics['loss']):.4f} "
            f"selected={int(sel)} cold={int(metrics['cold_starts'])} "
            f"latency={float(metrics['round_latency_ms']):.0f}ms "
            f"energy={float(metrics['energy_j']):.1f}J "
            f"({time.time() - t0:.2f}s)",
            flush=True,
        )
        if checkpointer and (r + 1) % args.ckpt_every == 0:
            checkpointer.save(r + 1, state)
    if checkpointer:
        checkpointer.wait()
    return state


if __name__ == "__main__":
    main()
