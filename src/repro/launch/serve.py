"""Serving driver: static batch or continuous batching with any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --scale tiny --batch 4 --prompt-len 32 --gen 16

``--scale tiny`` runs the reduced config on one CPU device. ``--scale
full`` is the distribution-aware path: it builds the ``repro.dist`` mesh
plan, shards params (no FSDP on the decode path), batch and KV cache via
``ShardingRules`` — batch-parallel when the batch divides the data axes,
sequence-parallel otherwise (the long-context fallback) — and reports the
decode step's collectives via ``analyze_hlo``. On a TPU pod it uses the
production mesh; on CPU back it with fake devices:

    python -m repro.launch.serve --scale full --devices 8 --reduced \
        --batch 8 --prompt-len 32 --gen 8

Engines:

  * ``--engine static`` (default) — one fixed batch, prefill + N decode
    steps. Greedy tokens accumulate in a device-resident buffer inside
    the compiled step program; the host syncs ONCE at the end. This path
    is the serving oracle.
  * ``--engine continuous`` — the slot-scheduled continuous-batching
    engine (``repro.serve``): Poisson/diurnal arrivals off the DES event
    queue, mid-flight slot eviction/refill on two AOT executables,
    §IV.F latency/energy/cold-start accounting, and optionally the
    Pallas paged flash-decode kernel (``--attn paged``). Reproduces the
    sequential per-request decode token-for-token (``--attn dense``).

``--track jsonl:PATH --track-every K`` streams per-step serving metrics
through the shared ``repro.obs`` tracker stack on either engine.
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--engine", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="back the full-scale mesh with N fake CPU devices "
                         "(XLA_FLAGS; set before jax initializes)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="with --scale full: reduced config on the real "
                         "mesh plan (CPU-executable sharded decode)")
    # Continuous-batching knobs (--engine continuous).
    ap.add_argument("--requests", type=int, default=16,
                    help="trace length for --engine continuous")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean request arrival rate (per virtual second)")
    ap.add_argument("--slots", type=int, default=0,
                    help="slot count (default: --batch)")
    ap.add_argument("--slo-ms", type=float, default=4000.0,
                    help="per-request latency SLO (virtual ms)")
    ap.add_argument("--attn", default="dense", choices=["dense", "paged"],
                    help="slot attention: dense gather (oracle-exact) or "
                         "the Pallas paged flash-decode kernel")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "edf"])
    ap.add_argument("--page-size", type=int, default=16)
    # Observability (either engine).
    ap.add_argument("--track", default=None,
                    help="tracker spec, e.g. jsonl:/tmp/serve.jsonl")
    ap.add_argument("--track-every", type=int, default=1)
    args = ap.parse_args(argv)

    if args.scale == "full" and args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_reduced
    from repro.models import Runtime, build_model
    from repro.models.config import Family

    full = args.scale == "full"
    cfg = (
        get_config(args.arch)
        if full and not args.reduced
        else get_reduced(args.arch, loss_chunk=0)
    )
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)

    tap = None
    if args.track:
        from repro.obs import MetricTap, tracker_from_spec

        tap = MetricTap(
            tracker_from_spec(args.track), every=args.track_every,
            const={"arch": cfg.name}, channel="serve",
        )

    rules = None
    runtime = Runtime()
    if full:
        from repro.dist import make_rules
        from repro.launch import mesh as mesh_mod

        pods = 2 if args.multi_pod else 1
        if args.devices and args.devices != 256 * pods:
            rules = make_rules(None, cfg, multi_pod=args.multi_pod,
                               device_count=args.devices)
        else:
            pm = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
            rules = make_rules(pm, cfg, multi_pod=args.multi_pod)
        mesh_shape = rules.mesh.shape
        runtime = Runtime(
            mesh=rules.mesh,
            batch_axes=rules.serve_batch_axes,
            expert_axis="expert" if cfg.num_experts else None,
            tp_axis="tp" if mesh_shape.get("tp", 1) > 1 else None,
            moe_impl="gshard" if cfg.num_experts else "dropless",
            moe_group_axes=rules.serve_batch_axes,
        )
        print(f"[serve] mesh plan: {dict(mesh_shape)}")

    params = model.init(key)
    if rules is not None:
        shapes, laxes = model.param_shapes(), model.param_axes()
        # Decode-path weights: model-parallel only, no ZeRO sharding.
        p_sh = rules.shardings(
            rules.param_specs(shapes, laxes, stacked=False, fsdp=False)
        )
        params = jax.device_put(params, p_sh)

    if args.engine == "continuous":
        return _run_continuous(args, cfg, model, params, rules, runtime, tap)

    cache_len = args.prompt_len + args.gen
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family is Family.VLM:
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model)
        ).astype(cfg.compute_dtype)
        cache_len += 8
    if cfg.family is Family.ENCDEC:
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)
        ).astype(cfg.compute_dtype)

    def prefill_fn(p, b, buf):
        logits, cache = model.prefill(p, b, cache_len=cache_len,
                                      runtime=runtime)
        toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, toks[:, None], buf.at[:, 0].set(toks)

    def step_fn(p, cache, toks, buf, i):
        """One decode step + greedy pick + device-buffer write: tokens
        never leave the device until the single terminal sync."""
        logits, cache = model.decode_step(p, cache, toks, runtime)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, nxt[:, None], buf.at[:, i].set(nxt)

    gen_buf = jnp.zeros((args.batch, args.gen), jnp.int32)
    if rules is not None:
        from jax.sharding import NamedSharding

        b_sh = {
            k: NamedSharding(rules.mesh, v)
            for k, v in rules.serve_batch_specs(batch).items()
        }
        batch = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        prefill = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh, None))
        decode = jax.jit(step_fn, donate_argnums=(1, 3))
    else:
        prefill = jax.jit(prefill_fn)
        decode = jax.jit(step_fn, donate_argnums=(1, 3))

    t0 = time.time()
    cache, toks, gen_buf = prefill(params, batch, gen_buf)
    toks.block_until_ready()
    t_prefill = time.time() - t0

    if rules is not None:
        # Pin the cache to the rules' layout (batch- or sequence-parallel),
        # AOT-compile ONE decode program against it, and report its
        # collective census — the same executable then serves every step.
        from repro.dist import analyze_hlo

        cache = jax.device_put(
            cache, rules.shardings(rules.cache_specs(cache))
        )
        decode = decode.lower(
            params, cache, toks, gen_buf,
            jax.ShapeDtypeStruct((), jnp.int32),
        ).compile()
        stats = analyze_hlo(decode.as_text()).collectives
        print(f"[serve] decode collectives: {stats.count_by_kind} "
              f"total={stats.total_bytes:.2e} B")

    t0 = time.time()
    for i in range(1, args.gen):
        cache, toks, gen_buf = decode(params, cache, toks, gen_buf,
                                      jnp.int32(i))
        if tap is not None:
            tap.host_log({"step": i, "batch": args.batch}, step=i)
    out = jax.block_until_ready(gen_buf)  # the ONE device->host sync
    t_decode = time.time() - t0

    print(f"arch={cfg.name} prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode / max(args.gen - 1, 1) * 1e3:.2f}ms/tok")
    print("generated token ids (first row):", out[0].tolist())
    return out


def _run_continuous(args, cfg, model, params, rules, runtime, tap):
    import jax

    from repro.serve import (
        ContinuousBatchingEngine,
        EngineConfig,
        TraceConfig,
        make_trace,
    )

    slots = args.slots or args.batch
    ecfg = EngineConfig(
        slots=slots,
        page_size=args.page_size,
        prompt_len=args.prompt_len,
        max_gen=args.gen,
        max_requests=max(args.requests, 1),
        attn=args.attn,
        policy=args.policy,
    )
    engine = ContinuousBatchingEngine(
        model, params, ecfg, runtime=runtime, tap=tap
    )
    if rules is not None:
        from repro.dist import analyze_hlo

        stats = analyze_hlo(engine.decode_hlo_text()).collectives
        print(f"[serve] decode collectives: {stats.count_by_kind} "
              f"total={stats.total_bytes:.2e} B")

    trace = make_trace(
        jax.random.PRNGKey(args.seed + 1),
        TraceConfig(
            n_requests=args.requests,
            rate_per_s=args.rate,
            slo_ms=args.slo_ms,
            prompt_len=args.prompt_len,
            min_gen=max(args.gen // 2, 1),
            max_gen=args.gen,
        ),
        cfg,
    )
    rep = engine.serve(trace)
    pct = rep.percentiles
    print(
        f"arch={cfg.name} engine=continuous slots={slots} attn={args.attn} "
        f"requests={rep.n_requests} completed={rep.completed} "
        f"rejected={rep.rejected}"
    )
    print(
        f"[serve] latency p50={pct['p50']:.0f}ms p95={pct['p95']:.0f}ms "
        f"p99={pct['p99']:.0f}ms slo_violations={rep.slo_violations} "
        f"goodput={rep.goodput_rps:.2f} req/s"
    )
    print(
        f"[serve] tokens={rep.tokens_generated} "
        f"decode_steps={rep.decode_steps} cold_starts={rep.cold_starts} "
        f"energy_per_token={rep.energy_per_token_j:.2e} J "
        f"throughput={rep.tokens_per_wall_s:.0f} tok/s(wall) "
        f"n_compiles={rep.n_compiles}"
    )
    print("generated token ids (first request):", rep.tokens_for(0))
    return rep


if __name__ == "__main__":
    main()
