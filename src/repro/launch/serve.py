"""Serving driver: prefill + batched decode with any --arch config.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --scale tiny --batch 4 --prompt-len 32 --gen 16

``--scale tiny`` runs the reduced config on one CPU device. ``--scale
full`` is the distribution-aware path: it builds the ``repro.dist`` mesh
plan, shards params (no FSDP on the decode path), batch and KV cache via
``ShardingRules`` — batch-parallel when the batch divides the data axes,
sequence-parallel otherwise (the long-context fallback) — and reports the
decode step's collectives via ``analyze_hlo``. On a TPU pod it uses the
production mesh; on CPU back it with fake devices:

    python -m repro.launch.serve --scale full --devices 8 --reduced \
        --batch 8 --prompt-len 32 --gen 8
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="back the full-scale mesh with N fake CPU devices "
                         "(XLA_FLAGS; set before jax initializes)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="with --scale full: reduced config on the real "
                         "mesh plan (CPU-executable sharded decode)")
    args = ap.parse_args(argv)

    if args.scale == "full" and args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_reduced
    from repro.models import Runtime, build_model
    from repro.models.config import Family

    full = args.scale == "full"
    cfg = (
        get_config(args.arch)
        if full and not args.reduced
        else get_reduced(args.arch, loss_chunk=0)
    )
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)

    rules = None
    runtime = Runtime()
    if full:
        from repro.dist import make_rules
        from repro.launch import mesh as mesh_mod

        pods = 2 if args.multi_pod else 1
        if args.devices and args.devices != 256 * pods:
            rules = make_rules(None, cfg, multi_pod=args.multi_pod,
                               device_count=args.devices)
        else:
            pm = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
            rules = make_rules(pm, cfg, multi_pod=args.multi_pod)
        mesh_shape = rules.mesh.shape
        runtime = Runtime(
            mesh=rules.mesh,
            batch_axes=rules.serve_batch_axes,
            expert_axis="expert" if cfg.num_experts else None,
            tp_axis="tp" if mesh_shape.get("tp", 1) > 1 else None,
            moe_impl="gshard" if cfg.num_experts else "dropless",
            moe_group_axes=rules.serve_batch_axes,
        )
        print(f"[serve] mesh plan: {dict(mesh_shape)}")

    params = model.init(key)

    cache_len = args.prompt_len + args.gen
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family is Family.VLM:
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model)
        ).astype(cfg.compute_dtype)
        cache_len += 8
    if cfg.family is Family.ENCDEC:
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)
        ).astype(cfg.compute_dtype)

    if rules is not None:
        from jax.sharding import NamedSharding

        shapes, laxes = model.param_shapes(), model.param_axes()
        # Decode-path weights: model-parallel only, no ZeRO sharding.
        p_sh = rules.shardings(
            rules.param_specs(shapes, laxes, stacked=False, fsdp=False)
        )
        params = jax.device_put(params, p_sh)
        b_sh = {
            k: NamedSharding(rules.mesh, v)
            for k, v in rules.serve_batch_specs(batch).items()
        }
        batch = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len,
                                       runtime=runtime),
            in_shardings=(p_sh, b_sh),
        )
        decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, runtime),
            donate_argnums=(1,),
        )
    else:
        prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
        decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    if rules is not None:
        # Pin the cache to the rules' layout (batch- or sequence-parallel),
        # AOT-compile ONE decode program against it, and report its
        # collective census — the same executable then serves every step.
        from repro.dist import analyze_hlo

        cache = jax.device_put(
            cache, rules.shardings(rules.cache_specs(cache))
        )
        decode = decode.lower(params, cache, toks).compile()
        stats = analyze_hlo(decode.as_text()).collectives
        print(f"[serve] decode collectives: {stats.count_by_kind} "
              f"total={stats.total_bytes:.2e} B")

    generated = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(toks)
    jax.block_until_ready(generated[-1])
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode / max(args.gen - 1, 1) * 1e3:.2f}ms/tok")
    print("generated token ids (first row):", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
