"""Serving driver: prefill + batched decode with any --arch config.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --scale tiny --batch 4 --prompt-len 32 --gen 16

Runs the reduced config on CPU; on a TPU pod drop --scale to get the
production mesh + sharded KV caches (sequence-parallel flash-decode for
batch-unshardable long-context cells; see dist/sharding.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.models.config import Family


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (
        get_reduced(args.arch, loss_chunk=0)
        if args.scale == "tiny"
        else get_config(args.arch)
    )
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    cache_len = args.prompt_len + args.gen
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family is Family.VLM:
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model)
        ).astype(cfg.compute_dtype)
        cache_len += 8
    if cfg.family is Family.ENCDEC:
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)
        ).astype(cfg.compute_dtype)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(toks)
    jax.block_until_ready(generated[-1])
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode / max(args.gen - 1, 1) * 1e3:.2f}ms/tok")
    print("generated token ids (first row):", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
