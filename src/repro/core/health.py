"""Health scoring — paper Eq. 1.

``H(c_i) = a1 * CPU_i + a2 * MEM_i + a3 * BATT_i`` with ``a1+a2+a3 = 1``.

Inputs are already-normalized resource availabilities in [0, 1]; the output
is a scalar health score per client, also in [0, 1]. Vectorized over the
whole client registry — shape (N,).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array, ClientTelemetry


def health_score(telemetry: ClientTelemetry, alpha: Array) -> Array:
    """Eq. 1: convex combination of CPU / MEM / BATT availability.

    Args:
      telemetry: per-client readings, each field shape (N,).
      alpha: (3,) weights ``(a1, a2, a3)``, summing to 1.

    Returns:
      (N,) float32 health scores in [0, 1].
    """
    stacked = jnp.stack([telemetry.cpu, telemetry.mem, telemetry.batt], axis=-1)
    return jnp.asarray(stacked @ alpha.astype(stacked.dtype), jnp.float32)
