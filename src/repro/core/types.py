"""Core value types for the FedFog orchestration layer.

Everything is vectorized over a static client population of size ``N``
(``num_clients``). Fields are plain ``jnp`` arrays so the whole scheduler is
jit/pjit-safe and can live on-device next to the training step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def static_on(x) -> bool:
    """Static truthiness of a config scalar that gates a Python branch.

    The sweep layer (``repro.sim.sweep``) lifts *numeric* config fields
    into traced data so a whole grid shares one compiled program — but it
    only lifts a branch-gating field when the gate is ACTIVE for every
    grid point in the group (the gate's truthiness is part of the
    structural signature). Inside the trace such a field is a tracer,
    and "is the gate on?" must then answer True without calling
    ``bool()`` on it. Concrete values answer ``value > 0`` as before.
    """
    if isinstance(x, jax.core.Tracer):
        return True
    return x is not None and bool(x > 0)


def static_zero(x) -> bool:
    """Static ``x == 0`` for config scalars (False for tracers) — the
    complement of ``static_on`` for identity-shortcut branches."""
    if isinstance(x, jax.core.Tracer):
        return False
    return bool(x == 0)


def static_any(*xs) -> bool:
    """``static_on`` over several gate scalars: True iff ANY gate is
    active. Used by composite subsystems (e.g. the fault layer) whose
    single structural gate is the OR of many rate fields — a tracer in
    any position means that field was lifted with its gate registered,
    so the composite gate must answer True."""
    return any(static_on(x) for x in xs)


def _pytree_dataclass(cls):
    """Register a frozen dataclass as a JAX pytree node."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return [getattr(obj, name) for name in fields], None

    def unflatten(_, children):
        return cls(**dict(zip(fields, children)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@_pytree_dataclass
class ClientTelemetry:
    """Raw per-client resource readings, each shape ``(N,)`` in [0, 1].

    Mirrors the paper's Eq. 1 inputs: CPU availability, memory availability,
    battery level — plus the normalized energy level E(c_i) used by Eq. 3/7.
    """

    cpu: Array
    mem: Array
    batt: Array
    energy: Array

    @property
    def num_clients(self) -> int:
        return self.cpu.shape[0]


@_pytree_dataclass
class SchedulerWeights:
    """The (alpha, beta) weight vectors of Eq. 1 and Eq. 7."""

    alpha: Array  # (3,) health weights: cpu, mem, batt. Sum to 1.
    beta: Array  # (3,) utility weights: health, energy, drift. Sum to 1.


@_pytree_dataclass
class Thresholds:
    """Selection thresholds of Eq. 3. theta_e may be scalar or per-client (N,)."""

    health: Array  # theta_h
    energy: Array  # theta_e  (adaptive per-client under Eq. 10)
    drift: Array  # theta_d


@_pytree_dataclass
class SchedulerState:
    """Carried across rounds by the scheduler.

    prev_hist:    (N, V) previous-round empirical distributions (Eq. 2 input).
    theta_e:      (N,) adaptive per-client energy thresholds (Eq. 10).
    warm:         (N,) bool — container warm/cold state (Eq. 4).
    last_used:    (N,) int32 — round index of last invocation (LRU eviction).
    energy_spent: (N,) cumulative Joules (sim units) per client.
    round_index:  () int32.
    """

    prev_hist: Array
    theta_e: Array
    warm: Array
    last_used: Array
    energy_spent: Array
    round_index: Array


@_pytree_dataclass
class PopulationSchedulerState:
    """Population-scale scheduler registry: cheap ``(M,)`` rows only.

    The per-round cohort gather materializes a C-sized
    :class:`SchedulerState` from these rows (``fl.fog
    .gather_cohort_sched``) and scatters the advanced rows back. The one
    field deliberately NOT stored is ``prev_hist`` — an ``(M, V)`` float
    table is the single scheduler buffer that does not stay cheap at a
    million clients (1M × 62 bins ≈ 248 MB); instead
    ``last_hist_round`` records when each client's histogram was last
    observed, and the drift reference is recomputed for cohort members
    only (histograms are deterministic in (client, round)).

    theta_e:         (M,) adaptive per-client energy thresholds (Eq. 10).
    warm:            (M,) bool — container warm/cold state (Eq. 4).
    last_used:       (M,) int32 — round index of last invocation.
    energy_spent:    (M,) cumulative Joules (sim units) per client.
    last_hist_round: (M,) int32 — round the drift reference was taken at.
    round_index:     () int32.
    """

    theta_e: Array
    warm: Array
    last_used: Array
    energy_spent: Array
    last_hist_round: Array
    round_index: Array


@_pytree_dataclass
class SelectionResult:
    """Output of one scheduling decision.

    mask:     (N,) bool — Eq. 3 threshold gate ∧ top-K utility gate.
    utility:  (N,) float — Eq. 7 scores.
    health:   (N,) float — Eq. 1 scores.
    drift:    (N,) float — Eq. 2 scores.
    order:    (N,) int32 — client indices sorted by descending utility
              (the paper's priority queue, §V.A).
    num_selected: () int32.
    """

    mask: Array
    utility: Array
    health: Array
    drift: Array
    order: Array
    num_selected: Array


def validate_weights(alpha: Any, beta: Any, atol: float = 1e-5) -> None:
    """Host-side sanity check that weight vectors are convex combinations."""
    import numpy as np

    a = np.asarray(alpha, dtype=np.float64)
    b = np.asarray(beta, dtype=np.float64)
    if a.shape != (3,) or b.shape != (3,):
        raise ValueError(f"alpha/beta must be shape (3,), got {a.shape}/{b.shape}")
    if abs(float(a.sum()) - 1.0) > atol:
        raise ValueError(f"alpha must sum to 1, got {a.sum()}")
    if abs(float(b.sum()) - 1.0) > atol:
        raise ValueError(f"beta must sum to 1, got {b.sum()}")
    if (a < 0).any() or (b < 0).any():
        raise ValueError("alpha/beta must be non-negative")


def init_scheduler_state(
    num_clients: int, hist_bins: int, theta_e0: float = 0.5
) -> SchedulerState:
    """Fresh scheduler state: uniform histograms, cold containers."""
    return SchedulerState(
        prev_hist=jnp.full((num_clients, hist_bins), 1.0 / hist_bins, jnp.float32),
        theta_e=jnp.full((num_clients,), theta_e0, jnp.float32),
        warm=jnp.zeros((num_clients,), bool),
        last_used=jnp.full((num_clients,), -1, jnp.int32),
        energy_spent=jnp.zeros((num_clients,), jnp.float32),
        round_index=jnp.zeros((), jnp.int32),
    )


def init_population_scheduler_state(
    population: int, theta_e0: float = 0.5
) -> PopulationSchedulerState:
    """Fresh population registry: cold containers, round-0 drift refs."""
    return PopulationSchedulerState(
        theta_e=jnp.full((population,), theta_e0, jnp.float32),
        warm=jnp.zeros((population,), bool),
        last_used=jnp.full((population,), -1, jnp.int32),
        energy_spent=jnp.zeros((population,), jnp.float32),
        last_hist_round=jnp.zeros((population,), jnp.int32),
        round_index=jnp.zeros((), jnp.int32),
    )
