"""Energy model + adaptive energy budgeting — paper §III.J Eq. 10 and §IV.F.

Adaptive per-client energy threshold:

    θ_e^{(i)}(t) = θ_e^{(i)}(t-1) · exp( -λ · E_i(t-1) / E_avg )        (Eq. 10)

i.e. clients that burned more energy than the system average last round get
a *lower* participation threshold this round... note the sign: the paper's
controller lets "energy-constrained devices back off temporarily while
preventing dominant clients from monopolizing participation" — a client
whose spend is above average sees its threshold decay *faster*, which in the
paper's convention (θ_e is the bar the client's energy level must clear,
per Eq. 3: E(c_i) > θ_e) would make it *easier* to select. To realize the
stated intent we apply the decay to the *budget*, and expose both readings;
the scheduler consumes ``adaptive_thresholds`` which raises the bar for
heavy spenders:

    θ_e^{(i)}(t) = clip( θ_e^{(i)}(t-1) · exp( +λ · (E_i/E_avg - 1) ), θ_min, θ_max )

with λ>0: above-average spenders get a higher bar (back off), below-average
spenders drift toward lower bars (invited back in). At E_i == E_avg the
threshold is unchanged, and with λ→0 it reduces to the static θ_e — so the
paper's Eq. 10 exponential-controller *form* is preserved exactly, with the
sign arranged to match its stated behaviour. Recorded in DESIGN.md §2.

Per-round energy accounting (§IV.F):

    E_i = Σ_r ( C_cpu · CPU_{i,r} + C_tx · TX_{i,r} )
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.types import Array

_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class EnergyModelConfig:
    c_cpu: float = 1e-9  # Joules per CPU cycle (sim units)
    c_tx: float = 5e-8  # Joules per transmitted byte
    lam: float = 0.3  # λ in Eq. 10
    theta_min: float = 0.05
    theta_max: float = 0.95
    cold_start_energy_j: float = 0.4  # e_c in §IV.F T_cold


def round_energy(
    cpu_cycles: Array, tx_bytes: Array, config: EnergyModelConfig
) -> Array:
    """§IV.F: per-client energy for one round, in Joules (sim units)."""
    return (
        config.c_cpu * cpu_cycles.astype(jnp.float32)
        + config.c_tx * tx_bytes.astype(jnp.float32)
    )


def decay_energy_threshold(
    theta_e: Array, energy_last_round: Array, config: EnergyModelConfig
) -> Array:
    """Eq. 10 exponential controller (sign per stated intent; see module doc).

    Args:
      theta_e: (N,) previous per-client thresholds.
      energy_last_round: (N,) E_i(t-1). Zero for non-participants.

    Returns:
      (N,) updated thresholds, clipped to [theta_min, theta_max].
    """
    e_avg = jnp.mean(energy_last_round) + _EPS
    factor = jnp.exp(config.lam * (energy_last_round / e_avg - 1.0))
    return jnp.clip(theta_e * factor, config.theta_min, config.theta_max)


def paper_eq10_literal(
    theta_e: Array, energy_last_round: Array, lam: float
) -> Array:
    """Eq. 10 exactly as printed: θ·exp(-λ·E_i/E_avg). Kept for fidelity tests."""
    e_avg = jnp.mean(energy_last_round) + _EPS
    return theta_e * jnp.exp(-lam * energy_last_round / e_avg)


def battery_drain(batt: Array, energy_j: Array, capacity_j: float) -> Array:
    """Deplete normalized battery level by this round's spend."""
    return jnp.clip(batt - energy_j / capacity_j, 0.0, 1.0)
