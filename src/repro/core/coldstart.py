"""Cold-start delay model — paper Eq. 4 + FogFaaS-style container cache.

``δ_i = δ_cold`` on first-time (or evicted-container) invocation,
``δ_i = δ_warm`` otherwise.

The paper keeps containers warm between rounds and credits FedFog's
scheduler with reducing cold-start frequency through "intelligent container
caching and predictive scheduling" (§IV.F). We model that concretely:

  * every selected client's container becomes warm after it runs;
  * a warm container survives at most ``keep_alive_rounds`` rounds without
    being invoked (the serverless platform's keep-alive), after which it is
    evicted and the next invocation pays ``δ_cold`` again;
  * an optional LRU capacity caps how many containers the platform keeps
    warm simultaneously (capacity pressure at the fog tier).

On the TPU-pod mapping (DESIGN.md §2, adaptation #2) a "cold start" is a
client group re-entering after preemption: recompile + checkpoint restore.
The two-level δ model is unchanged; only the constants differ.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.types import Array


@dataclasses.dataclass(frozen=True)
class ColdStartConfig:
    delta_cold_ms: float = 2000.0  # paper §III.G worked example
    delta_warm_ms: float = 200.0
    keep_alive_rounds: int = 3
    warm_capacity: int | None = None  # max simultaneously-warm containers


def invocation_delay(warm: Array, config: ColdStartConfig) -> Array:
    """Eq. 4: per-client delay in ms given current container state."""
    return jnp.where(warm, config.delta_warm_ms, config.delta_cold_ms).astype(
        jnp.float32
    )


def count_cold_starts(mask: Array, warm: Array) -> Array:
    """Number of selected clients paying δ_cold this round."""
    return jnp.sum((mask & ~warm).astype(jnp.int32))


def update_container_cache(
    warm: Array,
    last_used: Array,
    mask: Array,
    round_index: Array,
    config: ColdStartConfig,
) -> tuple[Array, Array]:
    """Advance the container cache one round.

    Args:
      warm: (N,) bool container state entering the round.
      last_used: (N,) int32 last round each client was invoked (-1 = never).
      mask: (N,) bool — clients invoked this round.
      round_index: () int32 current round.

    Returns:
      (new_warm, new_last_used).
    """
    new_last_used = jnp.where(mask, round_index, last_used).astype(jnp.int32)
    # Invoked clients end the round warm; others stay warm only within the
    # keep-alive window.
    age = round_index - new_last_used
    within_keep_alive = (new_last_used >= 0) & (age < config.keep_alive_rounds)
    new_warm = mask | (warm & within_keep_alive)

    if config.warm_capacity is not None:
        # LRU eviction: keep the `warm_capacity` most-recently-used warm
        # containers. Rank by recency (higher last_used = more recent).
        recency = jnp.where(new_warm, new_last_used, jnp.int32(-2**30))
        order = jnp.argsort(-recency, stable=True)
        rank = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
        new_warm = new_warm & (rank < config.warm_capacity)
    return new_warm, new_last_used
