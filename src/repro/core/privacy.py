"""Differential privacy accounting + Gaussian mechanism — paper §III.K Eq. 12.

    ε = sqrt(2·log(1.25/δ)) / σ  ·  S / |C_t|                       (Eq. 12)

with S the ℓ2 sensitivity (update clip norm), σ the relative noise scale,
and |C_t| the participating-client count (privacy amplification).

The paper's worked example: σ=0.3, S=1.1, |C_t|=30, δ=1e-5  →  ε ≈ 1.76
("≈ 1.8" in the text) — encoded in tests/test_paper_example.py.

Beyond the paper's estimate we actually *implement* the mechanism it
sketches: per-client clipping to S (core/aggregation.clipped_fedavg) and
Gaussian noise injection on aggregated updates, plus simple composition
accounting across rounds.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.types import Array


@dataclasses.dataclass(frozen=True)
class DPConfig:
    sigma: float = 0.3  # noise scale, relative to sensitivity
    sensitivity: float = 1.1  # S: update clip norm
    delta: float = 1e-5


def epsilon(sigma: float, sensitivity: float, num_clients, delta: float):
    """Eq. 12, verbatim."""
    c = math.sqrt(2.0 * math.log(1.25 / delta))
    return (c / sigma) * (sensitivity / num_clients)


def epsilon_composed(
    sigma: float, sensitivity: float, num_clients, delta: float, rounds: int
):
    """Basic (linear) composition across T rounds — a conservative bound the
    paper's future-work section implies. Advanced (moments) accounting would
    tighten this by ~sqrt(T); we report the conservative figure."""
    return rounds * epsilon(sigma, sensitivity, num_clients, delta)


def required_sigma(eps: float, sensitivity: float, num_clients, delta: float):
    """Invert Eq. 12: the σ needed to hit a target ε."""
    c = math.sqrt(2.0 * math.log(1.25 / delta))
    return (c / eps) * (sensitivity / num_clients)


def gaussian_mechanism(updates, key: Array, config: DPConfig):
    """Add N(0, (σ·S)²) noise to every leaf of an aggregated update pytree.

    Applied *after* clipping to S and *after* aggregation (central DP at the
    fog aggregator), matching the paper's description of noise "during
    aggregation".
    """
    flat, treedef = jax.tree.flatten(updates)
    keys = jax.random.split(key, len(flat))
    std = config.sigma * config.sensitivity
    noisy = [
        l + std * jax.random.normal(k, l.shape, dtype=jnp.float32).astype(l.dtype)
        for l, k in zip(flat, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)
