"""Scheduler utility function — paper Eq. 7.

``U(c_i) = b1 * H(c_i) + b2 * E(c_i) - b3 * D(c_i)``  with  ``b1+b2+b3 = 1``.

Higher health/energy raise the utility; drift lowers it. FedFog ranks
clients by utility (a priority queue in the paper, §V.A — here a sort on
device, O(N log N) worst case exactly as the paper analyzes, amortized
near-linear because utilities are stable across rounds and XLA's sort on
nearly-sorted input is cheap).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array


def utility_score(health: Array, energy: Array, drift: Array, beta: Array) -> Array:
    """Eq. 7 — vectorized over clients.

    Args:
      health: (N,) Eq. 1 scores.
      energy: (N,) normalized energy levels.
      drift:  (N,) Eq. 2 scores.
      beta:   (3,) weights (b1, b2, b3) summing to 1.

    Returns:
      (N,) float32 utility scores.
    """
    beta = beta.astype(jnp.float32)
    return (
        beta[0] * health.astype(jnp.float32)
        + beta[1] * energy.astype(jnp.float32)
        - beta[2] * drift.astype(jnp.float32)
    )


def utility_ranking(utility: Array) -> Array:
    """Descending-utility client ordering (the paper's priority queue).

    Returns (N,) int32 indices; ``ranking[0]`` is the highest-priority client.
    Ties broken by client index (stable sort) for determinism.
    """
    return jnp.argsort(-utility, stable=True).astype(jnp.int32)
