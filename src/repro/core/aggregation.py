"""Aggregation — paper Eq. 6 (weighted FedAvg) + robust variants (§IV.D outlook).

``w_{t+1} = Σ_{i∈C_t}  |D_i| / Σ_{j∈C_t} |D_j|  ·  Δw_i``

Two call styles are provided:

  * ``fedavg_stacked``   — updates stacked on a leading client axis (the
    single-host / simulator path, and the oracle for the Pallas kernel in
    ``kernels/fedavg``).
  * ``fedavg_collective``— each client group holds only *its own* Δw shard;
    aggregation is a masked weighted ``psum`` over the mesh client axis
    (the pod-scale path; see dist/collectives.py for the shard_map wiring).

Both share the same weighting rule so tests can cross-check them.

The paper notes (§IV.D) that plain FedAvg is vulnerable to poisoning and
calls for robust aggregation in future work; we ship coordinate-wise median
and norm-clipped FedAvg as the beyond-paper extension it asks for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array

_EPS = 1e-12


def fedavg_weights(mask: Array, data_sizes: Array) -> Array:
    """Normalized FedAvg weights ``m_i·|D_i| / Σ m_j·|D_j|``. Shape (N,)."""
    w = mask.astype(jnp.float32) * data_sizes.astype(jnp.float32)
    return w / (jnp.sum(w) + _EPS)


def fedavg_stacked(updates, mask: Array, data_sizes: Array):
    """Eq. 6 over a pytree whose leaves have a leading client axis.

    Args:
      updates: pytree; every leaf (N, ...) — client model updates Δw_i.
      mask: (N,) bool participation mask (Eq. 3 output).
      data_sizes: (N,) local dataset sizes |D_i|.

    Returns:
      pytree of aggregated updates (leading axis reduced away).
    """
    w = fedavg_weights(mask, data_sizes)

    def agg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(wb * leaf, axis=0)

    return jax.tree.map(agg, updates)


def median_aggregate(updates, mask: Array):
    """Coordinate-wise median over selected clients (Byzantine-robust).

    Unselected clients are replaced by the masked median's neutral element
    via a large sentinel trick: we sort with ±inf padding so the median is
    taken over selected entries only.
    """
    n = mask.shape[0]
    num_sel = jnp.sum(mask.astype(jnp.int32))

    def agg(leaf):
        m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
        hi = jnp.where(m, leaf, jnp.inf)  # unselected -> +inf (sort to top)
        s = jnp.sort(hi, axis=0)
        # median index among the first num_sel valid entries
        lo_idx = jnp.maximum((num_sel - 1) // 2, 0)
        hi_idx = num_sel // 2
        lo = jnp.take_along_axis(s, jnp.broadcast_to(lo_idx, (1,) + leaf.shape[1:]).astype(jnp.int32), axis=0)
        hi_v = jnp.take_along_axis(s, jnp.broadcast_to(hi_idx, (1,) + leaf.shape[1:]).astype(jnp.int32), axis=0)
        med = 0.5 * (lo + hi_v)
        return jnp.squeeze(med, axis=0)

    del n
    return jax.tree.map(agg, updates)


def clipped_fedavg(updates, mask: Array, data_sizes: Array, clip_norm: float):
    """Norm-clipped FedAvg: each Δw_i is clipped to ℓ2 ≤ clip_norm first.

    This is both the Byzantine mitigation the paper calls for and the
    sensitivity bound ``S`` that the DP accounting (Eq. 12) assumes.
    """
    flat, treedef = jax.tree.flatten(updates)
    # Per-client global norm across the whole pytree.
    sq = sum(jnp.sum(jnp.reshape(l.astype(jnp.float32) ** 2, (l.shape[0], -1)), axis=1) for l in flat)
    norms = jnp.sqrt(sq + _EPS)
    scale = jnp.minimum(1.0, clip_norm / norms)  # (N,)
    clipped = [
        l * scale.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype) for l in flat
    ]
    return fedavg_stacked(jax.tree.unflatten(treedef, clipped), mask, data_sizes)


def trimmed_mean_aggregate(updates, mask: Array, trim_fraction: float = 0.1):
    """Coordinate-wise trimmed mean (robust aggregation, beyond-paper).

    Sorts each coordinate across selected clients and averages the middle
    ``1 - 2·trim_fraction`` mass. Masked-out clients contribute zero weight
    by being sorted to the edges with sentinels and excluded from the count.
    """
    num_sel = jnp.sum(mask.astype(jnp.int32))
    k_trim = jnp.floor(num_sel.astype(jnp.float32) * trim_fraction).astype(jnp.int32)

    def agg(leaf):
        n = leaf.shape[0]
        m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
        hi = jnp.where(m, leaf.astype(jnp.float32), jnp.inf)
        s = jnp.sort(hi, axis=0)  # selected values first (ascending), then +inf
        idx = jnp.arange(n).reshape((-1,) + (1,) * (leaf.ndim - 1))
        keep = (idx >= k_trim) & (idx < num_sel - k_trim)
        total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
        cnt = jnp.maximum(num_sel - 2 * k_trim, 1).astype(jnp.float32)
        return (total / cnt).astype(leaf.dtype)

    return jax.tree.map(agg, updates)
