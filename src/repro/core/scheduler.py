"""FedFogScheduler — composes Eqs. 1/2/3/4/7/10 into one jit-safe decision.

One call = one round of the paper's functional flow (Fig. 1):

    telemetry ──► health (Eq.1) ─┐
    histograms ─► drift  (Eq.2) ─┼─► selection (Eq.3 ∧ top-K of Eq.7)
    θ_e state ──► energy (Eq.10)─┘          │
    container cache (Eq.4) ◄────────────────┘ (delays, cold-start counts)

The scheduler is *stateless logic over explicit state* (SchedulerState), so
it can be carried through lax.scan for multi-round simulation, checkpointed
for fault tolerance, and lowered inside the pod-scale train step.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import coldstart as cs
from repro.core import drift as drift_mod
from repro.core import energy as energy_mod
from repro.core.health import health_score
from repro.core.selection import select_clients
from repro.core.types import (
    Array,
    ClientTelemetry,
    SchedulerState,
    SchedulerWeights,
    SelectionResult,
    Thresholds,
    _pytree_dataclass,
)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    # Paper defaults: §III.I adopts (θ_h, θ_e, θ_d) = (0.6, 0.5, 0.1);
    # §III.G worked example uses α=(0.4,0.3,0.3), β=(0.4,0.4,0.2).
    alpha: tuple[float, float, float] = (0.4, 0.3, 0.3)
    beta: tuple[float, float, float] = (0.4, 0.4, 0.2)
    theta_h: float = 0.6
    theta_e: float = 0.5
    theta_d: float = 0.1
    top_k: int | None = None  # participation budget per round
    adaptive_energy: bool = True  # Eq. 10 controller on/off (ablation knob)
    drift_gating: bool = True  # drift gate on/off (ablation knob)
    health_gating: bool = True  # health gate on/off (ablation knob)
    cold_start: cs.ColdStartConfig = dataclasses.field(
        default_factory=cs.ColdStartConfig
    )
    energy_model: energy_mod.EnergyModelConfig = dataclasses.field(
        default_factory=energy_mod.EnergyModelConfig
    )

    def weights(self) -> SchedulerWeights:
        return SchedulerWeights(
            alpha=jnp.asarray(self.alpha, jnp.float32),
            beta=jnp.asarray(self.beta, jnp.float32),
        )


@_pytree_dataclass
class RoundDecision:
    """Everything the runtime needs to execute one FL round."""

    selection: SelectionResult
    delays_ms: Array  # (N,) Eq. 4 per-client invocation delay
    cold_starts: Array  # () int32 — selected clients paying δ_cold
    new_state: SchedulerState


def schedule_round(
    state: SchedulerState,
    telemetry: ClientTelemetry,
    current_hist: Array,
    config: SchedulerConfig,
) -> RoundDecision:
    """One scheduling decision over the full client registry.

    Args:
      state: carried SchedulerState (prev histograms, θ_e, container cache).
      telemetry: current CPU/MEM/BATT/energy readings, (N,) each.
      current_hist: (N, V) this round's local data histograms (drift input).
      config: weights/thresholds.

    Returns:
      RoundDecision. ``new_state`` has prev_hist/θ_e/cache advanced; the
      caller adds observed energy via ``account_energy`` after the round.
    """
    w = config.weights()
    health = health_score(telemetry, w.alpha)
    drift = drift_mod.drift_score(current_hist, state.prev_hist)

    # Ablation knobs (§IV.E): disabled gates become always-pass.
    eff_health = health if config.health_gating else jnp.ones_like(health)
    eff_drift = drift if config.drift_gating else jnp.zeros_like(drift)
    theta_e = state.theta_e if config.adaptive_energy else jnp.full_like(
        state.theta_e, config.theta_e
    )

    thresholds = Thresholds(
        health=jnp.asarray(config.theta_h, jnp.float32),
        energy=theta_e,
        drift=jnp.asarray(config.theta_d, jnp.float32),
    )
    selection = select_clients(
        eff_health, telemetry.energy, eff_drift, thresholds, w.beta, config.top_k
    )
    # Report true health/drift in the result even when gating is ablated.
    selection = dataclasses.replace(selection, health=health, drift=drift)

    delays = cs.invocation_delay(state.warm, config.cold_start)
    n_cold = cs.count_cold_starts(selection.mask, state.warm)
    new_warm, new_last_used = cs.update_container_cache(
        state.warm, state.last_used, selection.mask, state.round_index,
        config.cold_start,
    )

    new_state = SchedulerState(
        prev_hist=drift_mod.normalize_histogram(current_hist),
        theta_e=state.theta_e,  # decayed in account_energy (needs E_i obs)
        warm=new_warm,
        last_used=new_last_used,
        energy_spent=state.energy_spent,
        round_index=state.round_index + 1,
    )
    return RoundDecision(
        selection=selection,
        delays_ms=delays,
        cold_starts=n_cold,
        new_state=new_state,
    )


def account_energy(
    state: SchedulerState,
    round_energy_j: Array,
    config: SchedulerConfig,
) -> SchedulerState:
    """Post-round energy bookkeeping: Eq. 10 threshold decay + cumulative spend."""
    theta_e = state.theta_e
    if config.adaptive_energy:
        theta_e = energy_mod.decay_energy_threshold(
            theta_e, round_energy_j, config.energy_model
        )
    return SchedulerState(
        prev_hist=state.prev_hist,
        theta_e=theta_e,
        warm=state.warm,
        last_used=state.last_used,
        energy_spent=state.energy_spent + round_energy_j,
        round_index=state.round_index,
    )
