"""Client selection — paper Eq. 3 (+ top-K utility gating, §V.A).

``C_t = { c_i in C  |  H(c_i) > θ_h  ∧  E(c_i) > θ_e  ∧  D(c_i) < θ_d }``

The threshold gate is the paper's Eq. 3 verbatim (strict inequalities, as in
the worked example where H=0.65 > θ_h=0.6 selects). On top of it FedFog's
scheduler keeps only the top-K clients by utility (Eq. 7) when the round has
a participation budget — the priority-queue behaviour of §V.A.

Everything is shape-static: the output is a boolean mask over the fixed
client registry, never a dynamic-length set — which is exactly what the
masked weighted-FedAvg collective (core/aggregation.py) consumes, and what
keeps the whole scheduler inside one jitted program.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array, SelectionResult, Thresholds
from repro.core.utility import utility_ranking, utility_score


def threshold_mask(
    health: Array, energy: Array, drift: Array, thresholds: Thresholds
) -> Array:
    """Eq. 3: strict-threshold eligibility gate. Returns (N,) bool."""
    return (
        (health > thresholds.health)
        & (energy > thresholds.energy)
        & (drift < thresholds.drift)
    )


def topk_mask(utility: Array, eligible: Array, k: int | None) -> Array:
    """Keep at most ``k`` eligible clients, preferring higher utility.

    ``k=None`` (or k >= N) keeps every eligible client. ``k`` may be a
    traced int32 scalar (the sweep layer lifts ``top_k`` grids into data
    so every grid point shares one compiled program); the rank-compare
    below is already k-agnostic, only the static short-circuit needs the
    concrete-int guard. Implemented with a rank-compare rather than a
    scatter so it stays O(N log N) and shard-friendly.
    """
    if k is None or (isinstance(k, int) and k >= utility.shape[0]):
        return eligible
    # Push ineligible clients to -inf so they never crowd out eligible ones.
    masked_u = jnp.where(eligible, utility, -jnp.inf)
    order = jnp.argsort(-masked_u, stable=True)
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return eligible & (rank < k)


def select_clients(
    health: Array,
    energy: Array,
    drift: Array,
    thresholds: Thresholds,
    beta: Array,
    k: int | None = None,
) -> SelectionResult:
    """Full FedFog selection: Eq. 3 gate, Eq. 7 utility, top-K budget.

    Args:
      health/energy/drift: (N,) per-client scores.
      thresholds: θ_h, θ_e, θ_d (θ_e may be per-client — Eq. 10 adaptivity).
      beta: (3,) utility weights.
      k: optional participation budget (top-K by utility).

    Returns:
      SelectionResult with a static-shape (N,) participation mask.
    """
    eligible = threshold_mask(health, energy, drift, thresholds)
    utility = utility_score(health, energy, drift, beta)
    mask = topk_mask(utility, eligible, k)
    order = utility_ranking(utility)
    return SelectionResult(
        mask=mask,
        utility=utility,
        health=health,
        drift=drift,
        order=order,
        num_selected=jnp.sum(mask.astype(jnp.int32)),
    )


def random_selection_mask(key, num_clients: int, k: int) -> Array:
    """The RCS baseline (§IV.B): sample k clients uniformly, no telemetry."""
    import jax

    perm = jax.random.permutation(key, num_clients)
    rank = jnp.zeros((num_clients,), jnp.int32).at[perm].set(
        jnp.arange(num_clients, dtype=jnp.int32)
    )
    return rank < k
