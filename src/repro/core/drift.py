"""Data-drift detection — paper Eq. 2.

``D(c_i) = KL( P_t(D_i) || P_{t-1}(D_i) )``

where ``P_t`` is client ``i``'s empirical class (vision tasks) or token
(LM tasks) distribution at round ``t``. A higher value means the client's
local data shifted more since the previous round.

The paper runs this on label histograms; for the LM architectures we apply
the identical math to token histograms (DESIGN.md §2, adaptation #3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array

_EPS = 1e-8


def normalize_histogram(counts: Array, eps: float = _EPS) -> Array:
    """Counts -> probability distribution along the last axis (smoothed).

    Laplace-style smoothing keeps KL finite when a bin is empty on one side —
    matching how any practical implementation of Eq. 2 must behave.
    """
    counts = jnp.asarray(counts, jnp.float32)
    counts = counts + eps
    return counts / jnp.sum(counts, axis=-1, keepdims=True)


def kl_divergence(p: Array, q: Array, eps: float = _EPS) -> Array:
    """``KL(p || q)`` along the last axis. Inputs are probability vectors.

    Guaranteed >= 0 (up to float error) and 0 iff p == q.
    """
    p = jnp.asarray(p, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    ratio = jnp.log(p + eps) - jnp.log(q + eps)
    return jnp.sum(p * ratio, axis=-1)


def drift_score(current_hist: Array, prev_hist: Array) -> Array:
    """Eq. 2: per-client KL between this round's and last round's distribution.

    Args:
      current_hist: (N, V) raw counts or distributions at round t.
      prev_hist:    (N, V) distributions at round t-1.

    Returns:
      (N,) float32 drift scores, >= 0.
    """
    p = normalize_histogram(current_hist)
    q = normalize_histogram(prev_hist)
    return kl_divergence(p, q)


def token_histogram(tokens: Array, vocab_bins: int, vocab_size: int) -> Array:
    """Bucketed token histogram for LM clients.

    Full-vocab histograms (152k for qwen) would be wasteful for a drift
    signal; we fold the vocab into ``vocab_bins`` buckets, which preserves
    distribution-shift sensitivity while keeping scheduler state tiny.

    Args:
      tokens: (..., seq) int32 token ids.
      vocab_bins: number of histogram buckets (e.g. 64).
      vocab_size: true vocabulary size.

    Returns:
      (..., vocab_bins) float32 counts.
    """
    bucket = (tokens.astype(jnp.uint32) * vocab_bins // vocab_size).astype(jnp.int32)
    bucket = jnp.clip(bucket, 0, vocab_bins - 1)
    # one-hot accumulate along the trailing axis; works under vmap/pjit.
    oh = (bucket[..., None] == jnp.arange(vocab_bins, dtype=jnp.int32)).astype(
        jnp.float32
    )
    return jnp.sum(oh, axis=-2)
