"""FedFog core: the paper's contribution (Eqs. 1-12) as composable JAX modules."""
from repro.core.aggregation import (
    clipped_fedavg,
    fedavg_stacked,
    fedavg_weights,
    median_aggregate,
    trimmed_mean_aggregate,
)
from repro.core.coldstart import (
    ColdStartConfig,
    count_cold_starts,
    invocation_delay,
    update_container_cache,
)
from repro.core.drift import drift_score, kl_divergence, normalize_histogram, token_histogram
from repro.core.energy import (
    EnergyModelConfig,
    battery_drain,
    decay_energy_threshold,
    round_energy,
)
from repro.core.health import health_score
from repro.core.privacy import (
    DPConfig,
    epsilon,
    epsilon_composed,
    gaussian_mechanism,
    required_sigma,
)
from repro.core.scheduler import (
    RoundDecision,
    SchedulerConfig,
    account_energy,
    schedule_round,
)
from repro.core.selection import random_selection_mask, select_clients, threshold_mask, topk_mask
from repro.core.types import (
    ClientTelemetry,
    SchedulerState,
    SchedulerWeights,
    SelectionResult,
    Thresholds,
    init_scheduler_state,
    validate_weights,
)
from repro.core.utility import utility_ranking, utility_score

__all__ = [
    "ClientTelemetry",
    "ColdStartConfig",
    "DPConfig",
    "EnergyModelConfig",
    "RoundDecision",
    "SchedulerConfig",
    "SchedulerState",
    "SchedulerWeights",
    "SelectionResult",
    "Thresholds",
    "account_energy",
    "battery_drain",
    "clipped_fedavg",
    "count_cold_starts",
    "decay_energy_threshold",
    "drift_score",
    "epsilon",
    "epsilon_composed",
    "fedavg_stacked",
    "fedavg_weights",
    "gaussian_mechanism",
    "health_score",
    "init_scheduler_state",
    "invocation_delay",
    "kl_divergence",
    "median_aggregate",
    "normalize_histogram",
    "random_selection_mask",
    "required_sigma",
    "round_energy",
    "schedule_round",
    "select_clients",
    "threshold_mask",
    "token_histogram",
    "topk_mask",
    "trimmed_mean_aggregate",
    "update_container_cache",
    "utility_ranking",
    "utility_score",
    "validate_weights",
]
