"""Fault-tolerant checkpointing: atomic, journaled, async-capable.

Layout:  <dir>/step_<N>/shard_<host>.npz  + manifest.json (journal)

  * atomic: written to ``step_<N>.tmp`` then os.rename'd — a crash mid-save
    can never corrupt the latest valid checkpoint.
  * journaled: manifest.json records the step and pytree structure;
    ``latest_step`` scans for the newest COMPLETE checkpoint, so restart
    after failure auto-resumes from the last good round (train.py --resume).
  * sharded: each host saves only its addressable shards (process_index
    suffix); on this single-host container that is one file, but the format
    and restore path are multi-host-shaped.
  * async: AsyncCheckpointer snapshots to host memory synchronously
    (jax.device_get) and writes on a background thread, double-buffered —
    training never blocks on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx)
            if hasattr(p, "idx")
            else str(p)
            for p in path
        )
        arr = np.asarray(jax.device_get(leaf))
        # npz cannot store bf16 directly; view as uint16 with a dtype tag.
        if arr.dtype == jax.numpy.bfloat16:
            out[name + "::bf16"] = arr.view(np.uint16)
        else:
            out[name] = arr
    return out


def save(directory: str, step: int, state: Any) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_names(state)
    host = jax.process_index()
    np.savez(os.path.join(tmp, f"shard_{host}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {
                "step": step,
                "num_hosts": jax.process_count(),
                "keys": sorted(arrays),
            },
            f,
        )
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    """Newest step with a complete (manifest-bearing) checkpoint."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if os.path.exists(os.path.join(directory, name, "manifest.json")):
            best = max(best or 0, int(m.group(1)))
    return best


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure (and shardings, if concrete) of ``like``."""
    path = os.path.join(directory, f"step_{step:08d}")
    host = jax.process_index()
    data = np.load(os.path.join(path, f"shard_{host}.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        name = "/".join(
            str(q.key) if hasattr(q, "key") else str(q.idx)
            if hasattr(q, "idx")
            else str(q)
            for q in p
        )
        if name + "::bf16" in data:
            arr = data[name + "::bf16"].view(jax.numpy.bfloat16)
        else:
            arr = data[name]
        if hasattr(leaf, "sharding") and hasattr(leaf, "devices"):
            arr = jax.device_put(arr, leaf.sharding)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Double-buffered background-thread checkpointing."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        # Snapshot synchronously (device -> host) so training can mutate.
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _run():
            try:
                save(self.directory, step, snapshot)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for m in (
                re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory)
            )
            if m
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
