"""Serverless (FaaS) execution model — legacy functional façade.

The actual §IV.F formulas live in ``repro.sim.des.RoundCostModel``, which
both the paper-scale simulator and the pod-scale round engine consume.
This module keeps the original function-style API (used by tests and
external callers) as thin delegating wrappers.

Note: ``round_times_ms`` returns a fully masked ``per_client`` vector —
unselected clients report 0 ms (they used to leak the amortized
orchestration share).
"""
from __future__ import annotations

import jax

from repro.data.telemetry import DeviceProfiles
from repro.sim.des import FaasSimConfig, RoundCostModel

__all__ = ["FaasSimConfig", "round_energy_j", "round_times_ms"]

Array = jax.Array


def round_times_ms(
    cfg: FaasSimConfig,
    profiles: DeviceProfiles,
    selected: Array,  # (N,) bool
    warm: Array,  # (N,) bool
    workload_flops: Array | float,
    upload_bytes: Array | float,
    download_bytes: Array | float,
    policy: str = "fedfog",
):
    """Returns (per_client_ms (N,), round_ms (), orchestration_ms ())."""
    return RoundCostModel(cfg).times_ms(
        profiles, selected, warm, workload_flops, upload_bytes, download_bytes,
        policy,
    )


def round_energy_j(
    cfg: FaasSimConfig,
    profiles: DeviceProfiles,
    selected: Array,
    warm: Array,
    workload_flops: Array | float,
    upload_bytes: Array | float,
):
    """Per-client Joules for the round (§IV.F energy model)."""
    del profiles  # energy constants are profile-independent in sim units
    return RoundCostModel(cfg).energy_j(
        selected, warm, workload_flops, upload_bytes
    )
