"""Serverless (FaaS) execution model: per-round latency & energy (§IV.F).

Per selected client i in round r:

    t_compute = workload_flops / MIPS_i
    t_network = upload_bytes / bw_up_i + download_bytes / bw_down_i + RTT_i
    t_orchestration = scheduler dispatch cost (policy-dependent, §V.A)
    δ_i = δ_cold | δ_warm (Eq. 4, container cache)
    t_i = δ_i + t_compute + t_network + t_orchestration
    round latency = max_{i ∈ C_t} t_i          (synchronous round)

    E_i = C_cpu·CPU_cycles + C_tx·TX_bytes (+ e_c per cold start)
    T_cold = Σ_r S_r · (δ_c + e_c)            (§IV.F)

Orchestration models (Table IX):
    fedfog : priority-queue scheduling O(N log N) + O(K) dispatch,
             container reuse (keep-alive cache)
    fogfaas: flat scan O(N) + stateless per-round redeploy O(N²) —
             every function re-deployed and status-polled against every
             active deployment, no orchestration memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.coldstart import ColdStartConfig
from repro.core.energy import EnergyModelConfig
from repro.data.telemetry import DeviceProfiles

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FaasSimConfig:
    cold_start: ColdStartConfig = dataclasses.field(default_factory=ColdStartConfig)
    energy: EnergyModelConfig = dataclasses.field(default_factory=EnergyModelConfig)
    # Orchestration cost constants (ms) — calibrated so a 16-client FedFog
    # round lands near the paper's Table VII (2.45 s at 16 clients).
    dispatch_ms: float = 1.5  # per scheduled client (FedFog O(K))
    sort_ms_per_nlogn: float = 0.02  # FedFog priority queue per N·log2(N)
    deploy_ms: float = 2.0  # FogFaaS per-deployment
    poll_ms: float = 0.08  # FogFaaS per (deployment × active) status poll


def round_times_ms(
    cfg: FaasSimConfig,
    profiles: DeviceProfiles,
    selected: Array,  # (N,) bool
    warm: Array,  # (N,) bool
    workload_flops: Array | float,
    upload_bytes: Array | float,
    download_bytes: Array | float,
    policy: str = "fedfog",
):
    """Returns (per_client_ms (N,), round_ms (), orchestration_ms ())."""
    n = selected.shape[0]
    k = jnp.sum(selected.astype(jnp.float32))
    t_compute = workload_flops / profiles.mips * 1e3
    t_net = (
        upload_bytes / profiles.bw_up + download_bytes / profiles.bw_down
    ) * 1e3 + profiles.rtt_ms
    delta = jnp.where(warm, cfg.cold_start.delta_warm_ms, cfg.cold_start.delta_cold_ms)

    if policy == "fedfog":
        orch = cfg.sort_ms_per_nlogn * n * jnp.log2(float(max(n, 2))) + (
            cfg.dispatch_ms * k
        )
    else:  # fogfaas-style: redeploy everything, poll everything pairwise
        orch = cfg.deploy_ms * n + cfg.poll_ms * n * n
    per_client = (delta + t_compute + t_net) * selected + orch / jnp.maximum(k, 1.0)
    round_ms = jnp.max(jnp.where(selected, per_client, 0.0))
    return per_client, round_ms, orch


def round_energy_j(
    cfg: FaasSimConfig,
    profiles: DeviceProfiles,
    selected: Array,
    warm: Array,
    workload_flops: Array | float,
    upload_bytes: Array | float,
):
    """Per-client Joules for the round (§IV.F energy model)."""
    cpu_cycles = workload_flops  # 1 cycle ≈ 1 flop in sim units
    e = (
        cfg.energy.c_cpu * cpu_cycles
        + cfg.energy.c_tx * upload_bytes
        + (~warm) * cfg.energy.cold_start_energy_j
    )
    return e * selected
