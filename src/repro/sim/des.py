"""Unified discrete-event cost model for one FL round (§IV.F / Table IX).

This module is the single source of truth for the latency / energy /
cold-start accounting that both simulation engines consume:

  * the paper-scale simulator (``repro.fl.simulator.FedFogSimulator``),
    which vmaps all N edge clients and needs the full per-round
    ``RoundCosts`` (latency straggler, orchestration, energy, cold starts);
  * the pod-scale runtime (``repro.fl.round.make_round_fn``), which only
    needs the per-client energy bookkeeping feeding Eq. 10.

Before this module existed the two engines carried duplicated formulas
(``sim/faas.py`` vs. an inlined expression in ``fl/round.py``) that could
silently drift apart; now both call ``RoundCostModel``.

Per selected client i in round r (§IV.F):

    t_compute = workload_flops / MIPS_i
    t_network = upload_bytes / bw_up_i + download_bytes / bw_down_i + RTT_i
    δ_i       = δ_cold | δ_warm                  (Eq. 4, container cache)
    t_i       = δ_i + t_compute + t_network + orchestration share
    round latency = max_{i ∈ C_t} t_i            (synchronous round)

    E_i = C_cpu·CPU_cycles + C_tx·TX_bytes (+ e_c per cold start)

Orchestration models (Table IX):

    fedfog : priority-queue scheduling O(N log N) + O(K) dispatch,
             container reuse (keep-alive cache)
    fogfaas: flat scan O(N) + stateless per-round redeploy O(N²) —
             every function re-deployed and status-polled against every
             active deployment, no orchestration memory.

Everything here is shape-static and jit/vmap/scan-safe: masks over the
fixed client registry, never dynamic sets — which is what lets the
scan-compiled engine and the vmapped sweep subsystem carry these costs
through one XLA program.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.coldstart import ColdStartConfig
from repro.core.energy import EnergyModelConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FaasSimConfig:
    cold_start: ColdStartConfig = dataclasses.field(default_factory=ColdStartConfig)
    energy: EnergyModelConfig = dataclasses.field(default_factory=EnergyModelConfig)
    # Orchestration cost constants (ms) — calibrated so a 16-client FedFog
    # round lands near the paper's Table VII (2.45 s at 16 clients).
    dispatch_ms: float = 1.5  # per scheduled client (FedFog O(K))
    sort_ms_per_nlogn: float = 0.02  # FedFog priority queue per N·log2(N)
    deploy_ms: float = 2.0  # FogFaaS per-deployment
    poll_ms: float = 0.08  # FogFaaS per (deployment × active) status poll


class RoundCosts(NamedTuple):
    """Everything the DES accounts for in one synchronous round.

    NamedTuple so it is a pytree: stackable by ``lax.scan`` and batchable
    by ``vmap`` without registration.
    """

    per_client_ms: Array  # (N,) — 0 for unselected clients
    round_ms: Array  # () straggler-defined round latency
    orchestration_ms: Array  # () scheduler/platform overhead
    energy_j: Array  # (N,) — 0 for unselected clients
    cold_starts: Array  # () int32 — selected clients paying δ_cold


@dataclasses.dataclass(frozen=True)
class RoundCostModel:
    """The shared §IV.F cost model, parameterized by ``FaasSimConfig``."""

    cfg: FaasSimConfig = dataclasses.field(default_factory=FaasSimConfig)

    @classmethod
    def from_scheduler(cls, sched_cfg) -> "RoundCostModel":
        """Build from a ``SchedulerConfig`` — the pod-scale engine's entry
        point, so both engines derive §IV.F semantics from one place."""
        return cls(
            FaasSimConfig(
                cold_start=sched_cfg.cold_start, energy=sched_cfg.energy_model
            )
        )

    # ------------------------------------------------------------------ #
    def orchestration_ms(self, n: int, k: Array, policy: str = "fedfog") -> Array:
        """Platform overhead for one round (Table IX).

        ``n`` is the static registry size; ``k`` the (possibly traced)
        number of selected clients.
        """
        if policy == "fedfog":
            return self.cfg.sort_ms_per_nlogn * n * jnp.log2(float(max(n, 2))) + (
                self.cfg.dispatch_ms * k
            )
        # fogfaas-style: redeploy everything, poll everything pairwise
        return jnp.asarray(self.cfg.deploy_ms * n + self.cfg.poll_ms * n * n)

    def times_ms(
        self,
        profiles,
        selected: Array,  # (N,) bool
        warm: Array,  # (N,) bool
        workload_flops: Array | float,
        upload_bytes: Array | float,
        download_bytes: Array | float,
        policy: str = "fedfog",
    ) -> tuple[Array, Array, Array]:
        """Returns (per_client_ms (N,), round_ms (), orchestration_ms ()).

        ``per_client_ms`` is fully masked: unselected clients report 0,
        selected clients include their amortized orchestration share.
        """
        n = selected.shape[0]
        k = jnp.sum(selected.astype(jnp.float32))
        t_compute = workload_flops / profiles.mips * 1e3
        t_net = (
            upload_bytes / profiles.bw_up + download_bytes / profiles.bw_down
        ) * 1e3 + profiles.rtt_ms
        delta = jnp.where(
            warm, self.cfg.cold_start.delta_warm_ms, self.cfg.cold_start.delta_cold_ms
        )
        orch = self.orchestration_ms(n, k, policy)
        per_client = (
            delta + t_compute + t_net + orch / jnp.maximum(k, 1.0)
        ) * selected
        round_ms = jnp.max(jnp.where(selected, per_client, 0.0))
        return per_client, round_ms, orch

    def energy_j(
        self,
        selected: Array,  # (N,) bool
        warm: Array,  # (N,) bool
        workload_flops: Array | float,
        upload_bytes: Array | float,
    ) -> Array:
        """(N,) Joules for the round: compute + uplink + cold-start (§IV.F)."""
        cpu_cycles = workload_flops  # 1 cycle ≈ 1 flop in sim units
        e = (
            self.cfg.energy.c_cpu * cpu_cycles
            + self.cfg.energy.c_tx * upload_bytes
            + (~warm) * self.cfg.energy.cold_start_energy_j
        )
        return e * selected

    # ------------------------------------------------------------------ #
    # Serving accounting (§IV.F applied to inference traffic).
    #
    # The continuous-batching engine (repro.serve) drives its virtual
    # clock host-side, so these return plain floats from the SAME §IV.F
    # constants the round accounting above consumes — energy-per-token
    # and cold-start numbers cannot drift between the FL engines and the
    # serving engine because both read one FaasSimConfig.
    # ------------------------------------------------------------------ #
    def invocation_delay_ms(self, warm: bool) -> float:
        """Eq. 4 container delay for ONE serving invocation (a prefill)."""
        cs = self.cfg.cold_start
        return float(cs.delta_warm_ms if warm else cs.delta_cold_ms)

    def token_energy_j(self, flops: float, tx_bytes: float = 0.0) -> float:
        """§IV.F energy for ``flops`` of decode compute + ``tx_bytes``
        streamed out (the E_i = C_cpu·CPU + C_tx·TX formula, per token)."""
        e = self.cfg.energy
        return float(e.c_cpu * flops + e.c_tx * tx_bytes)

    def cold_start_energy_j(self) -> float:
        """e_c in §IV.F — paid by each cold serving prefill."""
        return float(self.cfg.energy.cold_start_energy_j)

    def round_costs(
        self,
        profiles,
        selected: Array,
        warm: Array,
        workload_flops: Array | float,
        upload_bytes: Array | float,
        download_bytes: Array | float,
        policy: str = "fedfog",
    ) -> RoundCosts:
        """One call = the complete DES accounting for one round."""
        per_client, round_ms, orch = self.times_ms(
            profiles, selected, warm, workload_flops, upload_bytes,
            download_bytes, policy,
        )
        energy = self.energy_j(selected, warm, workload_flops, upload_bytes)
        cold = jnp.sum((selected & ~warm).astype(jnp.int32))
        return RoundCosts(
            per_client_ms=per_client,
            round_ms=round_ms,
            orchestration_ms=orch,
            energy_j=energy,
            cold_starts=cold,
        )
