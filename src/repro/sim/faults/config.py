"""Declarative fault plan for both simulation engines.

``FaultConfig`` describes serverless failure modes (cold-start timeout,
mid-update crash, dropped/corrupted payload, transient partitions, fog
outages) and the recovery policies that answer them (per-client retry
with exponential backoff, server round deadline with quorum-degraded
aggregation, fog failover). The split mirrors the sweep layer's
structural/numeric discipline (`repro.sim.sweep`):

  * **rates and scales are numeric** — a fault-rate grid is pure data
    and shares one compiled program per structural signature;
  * **the composite gate, retry cap, deadline None-ness and failover
    flag are structural** — they pick which program is traced. With the
    gate off (`active(fc)` False) the engines take their original code
    paths verbatim, so faults-off is *bitwise* identical to a build
    without this module.

Failure draws use ``uniform(key) < rate`` so a lifted rate of exactly
0.0 with the gate on is value-identical to the gate-off program (a
uniform draw in [0, 1) is never < 0).
"""
from __future__ import annotations

import dataclasses

from repro.core.types import static_any, static_on


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-injection + recovery knobs. All rates are per-invocation
    (or per-fog / per-dispatch where noted) probabilities in [0, 1].

    Failure classes
    ---------------
    timeout_rate:   cold-start timeout — only a COLD invocation (Eq. 4
                    warm=False) can time out, and only on attempt 0
                    (retries hit a now-provisioned container).
    crash_rate:     function crash mid-update; every attempt is exposed.
    drop_rate:      payload lost in transit; every attempt is exposed.
    corrupt_rate:   payload arrives but bit-rotted — the update lands
                    with additive noise of scale ``corrupt_scale``
                    (reuses the `fl/attacks.py` noise machinery but is
                    accounted as a *fault*, not an attack).
    partition_rate: per-dispatch probability of a transient network
                    partition cutting off a random ``partition_frac`` of
                    the admitted cohort (their attempt 0 fails; retries
                    land after the partition heals).
    fog_outage_rate: per-round/per-dispatch probability that each fog
                    node goes dark. Without failover the dark fog's
                    partial Eq. 6 sum is lost (its clients count as
                    fault_lost); with ``fog_failover`` its clients are
                    reassigned to the surviving fogs at a
                    ``failover_latency_ms`` detour cost.

    Recovery policies
    -----------------
    max_retries:     per-client retry cap (structural int — it sets the
                     unrolled attempt count in the sync engine and the
                     event-chain depth in the async engine). 0 = no
                     retries: a failed invocation is terminal.
    backoff_base_ms / backoff_mult: exponential backoff — the wait
                     before retry attempt a (1-based) is
                     ``base * mult**(a-1)``.
    deadline_ms:     server round deadline (None = barrier semantics,
                     wait for everyone — None-ness is structural).
                     Updates arriving after the deadline are lost.
    quorum_frac:     minimum arrived/admitted fraction for the round to
                     aggregate. Below quorum the round is SKIPPED and
                     the model carries over bitwise; at/above quorum the
                     partial cohort aggregates with Eq. 6 reweighting
                     over the arrivals only.
    """

    timeout_rate: float = 0.0
    crash_rate: float = 0.0
    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_scale: float = 0.05
    partition_rate: float = 0.0
    partition_frac: float = 0.25
    fog_outage_rate: float = 0.0
    fog_failover: bool = False
    failover_latency_ms: float = 250.0
    max_retries: int = 0
    backoff_base_ms: float = 100.0
    backoff_mult: float = 2.0
    deadline_ms: float | None = None
    quorum_frac: float = 0.0


# Rate fields whose positivity participates in the composite gate. The
# sweep layer lifts these to data only when the gate is already active
# (see repro.sim.sweep._GATED_POSITIVE semantics for "faults." fields).
RATE_FIELDS = (
    "timeout_rate", "crash_rate", "drop_rate", "corrupt_rate",
    "partition_rate", "fog_outage_rate",
)
# Numeric-but-not-gating knobs: pure data whenever the gate is active.
SCALE_FIELDS = (
    "corrupt_scale", "partition_frac", "failover_latency_ms",
    "backoff_base_ms", "backoff_mult", "quorum_frac",
)


def active(fc: FaultConfig | None) -> bool:
    """The ONE structural gate of the fault layer: True iff any failure
    class can fire or a deadline is set. Tracer-valued rates (lifted by
    the sweep layer) answer True via ``static_any``."""
    if fc is None:
        return False
    if fc.deadline_ms is not None:
        return True
    return static_any(*(getattr(fc, f) for f in RATE_FIELDS))


def validate(fc: FaultConfig) -> None:
    """Host-side sanity check. Tracer-valued numeric fields (a sweep
    lifted them to data) are skipped — only the structural fields
    (retry cap, failover flag, deadline None-ness) and concrete values
    are checkable at trace time."""
    for f in RATE_FIELDS + ("partition_frac", "quorum_frac"):
        v = getattr(fc, f)
        if isinstance(v, (int, float)) and not 0.0 <= float(v) <= 1.0:
            raise ValueError(f"FaultConfig.{f} must be in [0, 1], got {v}")
    if int(fc.max_retries) < 0:
        raise ValueError("FaultConfig.max_retries must be >= 0")
    d = fc.deadline_ms
    if d is not None and isinstance(d, (int, float)) and float(d) <= 0:
        raise ValueError("FaultConfig.deadline_ms must be positive")
    if not static_on(fc.backoff_mult):
        raise ValueError("FaultConfig.backoff_mult must be > 0")


def backoff_ms(fc: FaultConfig, attempt):
    """Backoff delay before (1-based) retry ``attempt``:
    ``base * mult**(attempt-1)``. ``attempt`` may be traced."""
    import jax.numpy as jnp

    a = jnp.asarray(attempt, jnp.float32)
    return jnp.asarray(fc.backoff_base_ms, jnp.float32) * jnp.power(
        jnp.asarray(fc.backoff_mult, jnp.float32), a - 1.0
    )
