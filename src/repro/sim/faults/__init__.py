"""Fault-injection & recovery layer (serverless failure modes, retry/
backoff, round deadlines, quorum degradation) — see config.py for the
fault plan and inject.py for the sync-engine realization; the async
engine realizes the same plan event-by-event in
``repro.sim.events.engine``."""
from repro.sim.faults.config import (
    FaultConfig,
    RATE_FIELDS,
    SCALE_FIELDS,
    active,
    backoff_ms,
    validate,
)
from repro.sim.faults.inject import (
    COUNTER_KEYS,
    RoundFaultPlan,
    plan_round,
    zero_counters,
)

__all__ = [
    "FaultConfig",
    "RATE_FIELDS",
    "SCALE_FIELDS",
    "active",
    "backoff_ms",
    "validate",
    "COUNTER_KEYS",
    "RoundFaultPlan",
    "plan_round",
    "zero_counters",
]
