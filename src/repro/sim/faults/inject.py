"""jit/scan-safe fault realization for the SYNC (scanned) engine.

The async engine realizes faults event-by-event (retry events with
backoff delays, a deadline event that cancels overdue completions —
see ``repro.sim.events.engine``); the sync engine has no event clock,
so a round's whole failure/retry history is emulated here as a chain
of masked attempts whose latency, energy and counters fold into the
§IV.F totals:

  * attempt a of an admitted client fails on cold-start timeout
    (attempt 0 + cold container only), crash, drop, or the round's
    transient partition (attempt 0 only — retries land after the
    partition heals);
  * a failed attempt below the retry cap re-runs after exponential
    backoff; the retried invocation repays the full per-client §IV.F
    latency and energy (the crashed/timed-out function restarts from
    scratch — the deliberate, documented approximation);
  * a fog outage takes its edge clients' arrivals with it (Eq. 6 loses
    that partial sum) unless failover reroutes them to surviving fogs
    at a latency detour;
  * arrivals after the server deadline are lost; below-quorum rounds
    are skipped (the caller carries the model over bitwise).

Everything is drawn from ONE fault key, so a faulted run is exactly
reproducible from its seed (the engines derive the key from the same
per-round chain the other draws use).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sim.faults.config import FaultConfig, backoff_ms

Array = jax.Array

# Counter channels every faulted round emits (and every fault-capable
# engine emits as zeros when the gate is off, so sweep histories keep
# one schema across fault-on/off grid points).
COUNTER_KEYS = (
    "fault_dispatched", "fault_completed", "fault_terminal", "fault_lost",
    "fault_retries", "fault_corrupt", "fog_outages", "fault_failed_over",
    "round_skipped",
)


def zero_counters() -> dict[str, Array]:
    return {k: jnp.zeros((), jnp.int32) for k in COUNTER_KEYS}


class RoundFaultPlan(NamedTuple):
    """Realized faults of one sync round.

    arrived:  (N,) bool — admitted clients whose update reached the
              server (post outage / deadline, pre quorum).
    chain_ms: (N,) f32 — per-client wall latency of the whole attempt
              chain: every attempt's §IV.F latency + backoff waits +
              failover detour. Zero outside ``admitted``.
    attempts: (N,) f32 — invocation attempts launched (energy multiplier
              for the §IV.F energy totals). Zero outside ``admitted``.
    corrupt:  (N,) bool — arrived but bit-rotted (additive-noise payload).
    skip:     () bool — below quorum: the caller must carry the model
              over bitwise and mark the round skipped.
    round_ms: () f32 — server-side round latency: max attempt chain over
              the admitted cohort, clamped to the deadline when set.
    counters: dict of () int32 — the ``COUNTER_KEYS`` channels.
              Conservation: dispatched = completed + terminal + lost.
    """

    arrived: Array
    chain_ms: Array
    attempts: Array
    corrupt: Array
    skip: Array
    round_ms: Array
    counters: dict


def attempt_failures(
    fc: FaultConfig, key: Array, alive: Array, cold: Array,
    part_cut: Array, attempt: int,
) -> Array:
    """(N,) bool — which still-alive invocations fail on this attempt."""
    k_t, k_c, k_d = jax.random.split(key, 3)
    n = alive.shape[0]
    u_t = jax.random.uniform(k_t, (n,))
    u_c = jax.random.uniform(k_c, (n,))
    u_d = jax.random.uniform(k_d, (n,))
    fail = (u_c < jnp.asarray(fc.crash_rate, jnp.float32)) | (
        u_d < jnp.asarray(fc.drop_rate, jnp.float32)
    )
    if attempt == 0:
        fail = fail | (
            cold & (u_t < jnp.asarray(fc.timeout_rate, jnp.float32))
        )
        fail = fail | part_cut
    return alive & fail


def plan_round(
    fc: FaultConfig,
    key: Array,
    admitted: Array,  # (N,) bool — post-scheduler cohort
    cold: Array,  # (N,) bool — invocation hits a cold container
    per_client_ms: Array,  # (N,) f32 — one attempt's §IV.F latency
    fog_nodes: int = 1,
) -> RoundFaultPlan:
    """Realize one round's faults + recovery for the sync engine."""
    n = admitted.shape[0]
    i32 = jnp.int32
    k_att, k_part, k_pfrac, k_fog, k_corrupt = jax.random.split(key, 5)

    # Transient partition: one scalar gate per round × a random subset.
    part_on = jax.random.uniform(k_part, ()) < jnp.asarray(
        fc.partition_rate, jnp.float32
    )
    part_cut = part_on & (
        jax.random.uniform(k_pfrac, (n,))
        < jnp.asarray(fc.partition_frac, jnp.float32)
    )

    # Statically-unrolled retry chain: attempt 0 + max_retries retries.
    retries_cap = int(fc.max_retries)
    att_keys = jax.random.split(k_att, retries_cap + 1)
    alive = admitted
    arrived = jnp.zeros((n,), bool)
    chain = jnp.zeros((n,), jnp.float32)
    attempts = jnp.zeros((n,), jnp.float32)
    n_retries = jnp.zeros((), i32)
    terminal = jnp.zeros((n,), bool)
    for a in range(retries_cap + 1):
        fail = attempt_failures(fc, att_keys[a], alive, cold, part_cut, a)
        chain = chain + jnp.where(alive, per_client_ms, 0.0)
        attempts = attempts + alive.astype(jnp.float32)
        arrived = arrived | (alive & ~fail)
        if a < retries_cap:
            chain = chain + jnp.where(fail, backoff_ms(fc, a + 1), 0.0)
            n_retries = n_retries + jnp.sum(fail).astype(i32)
            alive = fail
        else:
            terminal = fail
            alive = jnp.zeros((n,), bool)

    # Fog outage: each fog node goes dark independently; its edge block
    # (fl.fog.fog_assignment's contiguous slices) loses or reroutes.
    n_outages = jnp.zeros((), i32)
    n_failed_over = jnp.zeros((), i32)
    n_lost = jnp.zeros((), i32)
    fogs = max(int(fog_nodes), 1)
    outage = jax.random.uniform(k_fog, (fogs,)) < jnp.asarray(
        fc.fog_outage_rate, jnp.float32
    )
    if fogs > 1:
        from repro.fl.fog import fog_assignment  # lazy: avoids fl<->sim cycle

        owner = fog_assignment(n, fogs)
    else:
        outage = jnp.zeros((1,), bool)  # a single tier IS the cloud uplink
        owner = jnp.zeros((n,), i32)
    n_outages = jnp.sum(outage).astype(i32)
    dark = outage[owner] & arrived
    if bool(fc.fog_failover):
        # Survivors absorb the dark fog's clients at a latency detour.
        chain = chain + jnp.where(
            dark, jnp.asarray(fc.failover_latency_ms, jnp.float32), 0.0
        )
        n_failed_over = jnp.sum(dark).astype(i32)
    else:
        arrived = arrived & ~dark
        n_lost = n_lost + jnp.sum(dark).astype(i32)

    # Server deadline: arrivals after it are lost; the round itself can
    # never run longer than the deadline.
    round_ms = jnp.max(jnp.where(admitted, chain, 0.0))
    if fc.deadline_ms is not None:
        deadline = jnp.asarray(fc.deadline_ms, jnp.float32)
        late = arrived & (chain > deadline)
        arrived = arrived & ~late
        n_lost = n_lost + jnp.sum(late).astype(i32)
        round_ms = jnp.minimum(round_ms, deadline)

    # Corrupted-but-arrived payloads (noise applied by the caller).
    corrupt = arrived & (
        jax.random.uniform(k_corrupt, (n,))
        < jnp.asarray(fc.corrupt_rate, jnp.float32)
    )

    # Quorum: aggregate the partial cohort iff enough of it arrived.
    # An empty arrival set always skips — Eq. 6 has no denominator.
    n_adm = jnp.sum(admitted).astype(i32)
    n_arr = jnp.sum(arrived).astype(i32)
    quorum = jnp.asarray(fc.quorum_frac, jnp.float32) * n_adm.astype(
        jnp.float32
    )
    skip = (n_arr.astype(jnp.float32) < quorum) | ((n_arr == 0) & (n_adm > 0))

    counters = {
        "fault_dispatched": n_adm,
        "fault_completed": n_arr,
        "fault_terminal": jnp.sum(terminal).astype(i32),
        "fault_lost": n_lost,
        "fault_retries": n_retries,
        "fault_corrupt": jnp.sum(corrupt).astype(i32),
        "fog_outages": n_outages,
        "fault_failed_over": n_failed_over,
        "round_skipped": skip.astype(i32),
    }
    return RoundFaultPlan(
        arrived=arrived,
        chain_ms=jnp.where(admitted, chain, 0.0),
        attempts=attempts,
        corrupt=corrupt,
        skip=skip,
        round_ms=round_ms,
        counters=counters,
    )
