"""Fixed-capacity masked event queue for jit/scan-compiled DES loops.

A priority queue keyed on virtual time, stored as parallel arrays of a
static capacity ``C`` so every operation is shape-static and therefore
legal inside ``jax.lax.scan`` / ``while_loop`` bodies:

    time    (C,) float32 — event firing time (virtual ms); +inf when free
    client  (C,) int32   — client id (-1 for server-side events)
    kind    (C,) int32   — event kind (KIND_DISPATCH / KIND_COMPLETE / ...)
    payload (C,) float32 — one scalar of event data (e.g. dispatch time)
    valid   (C,) bool    — slot occupancy mask
    dropped () int32     — events lost to capacity overflow (should be 0
                           when capacity is sized to the workload)

``push_event`` writes into the first free slot (``argmin(valid)``);
``pop_event`` removes the earliest valid event (``argmin`` over masked
times — ties break on the lowest slot index, so pop order is fully
deterministic). Both are pure: they return a new ``EventQueue``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Event kinds understood by the async engine. Extra kinds are fine — the
# queue itself is agnostic; only the engine's `lax.switch` cares.
KIND_DISPATCH = 0  # server admits a cohort through the scheduler gate
KIND_COMPLETE = 1  # one client's update arrives at the server
KIND_RETRY = 2  # a failed invocation relaunches after backoff (faults)
KIND_DEADLINE = 3  # server round deadline fires; overdue work is shed
KIND_ARRIVE = 4  # a serving request arrives (repro.serve arrival process)


class EventQueue(NamedTuple):
    """Pytree of parallel event arrays (see module docstring)."""

    time: Array  # (C,) f32
    client: Array  # (C,) i32
    kind: Array  # (C,) i32
    payload: Array  # (C,) f32
    valid: Array  # (C,) bool
    dropped: Array  # () i32

    @property
    def capacity(self) -> int:
        return self.time.shape[0]


class Event(NamedTuple):
    """One popped event. ``valid`` is False when the queue was empty —
    the other fields are then meaningless and the caller must no-op."""

    time: Array  # () f32
    client: Array  # () i32
    kind: Array  # () i32
    payload: Array  # () f32
    valid: Array  # () bool


def make_queue(capacity: int) -> EventQueue:
    """An empty queue with ``capacity`` slots."""
    return EventQueue(
        time=jnp.full((capacity,), jnp.inf, jnp.float32),
        client=jnp.full((capacity,), -1, jnp.int32),
        kind=jnp.full((capacity,), -1, jnp.int32),
        payload=jnp.zeros((capacity,), jnp.float32),
        valid=jnp.zeros((capacity,), bool),
        dropped=jnp.zeros((), jnp.int32),
    )


def push_event(
    q: EventQueue,
    time: Array | float,
    client: Array | int,
    kind: Array | int,
    payload: Array | float = 0.0,
    enable: Array | bool = True,
) -> EventQueue:
    """Insert one event (no-op when ``enable`` is False).

    Shape-static: writes the first free slot. A full queue drops the event
    and increments ``dropped`` rather than erroring — capacity should be
    sized so this never fires (the engine asserts on it host-side).
    """
    enable = jnp.asarray(enable, bool)
    free = ~q.valid
    has_free = jnp.any(free)
    slot = jnp.argmin(q.valid)  # first False (free) slot; 0 if full
    do = enable & has_free
    sel = jnp.arange(q.capacity) == slot

    def put(arr, val):
        return jnp.where(sel & do, jnp.asarray(val, arr.dtype), arr)

    return EventQueue(
        time=put(q.time, time),
        client=put(q.client, client),
        kind=put(q.kind, kind),
        payload=put(q.payload, payload),
        valid=q.valid | (sel & do),
        dropped=q.dropped + (enable & ~has_free).astype(jnp.int32),
    )


def push_events(
    q: EventQueue,
    times: Array,  # (N,) f32
    clients: Array,  # (N,) i32
    kinds: Array,  # (N,) i32
    payloads: Array,  # (N,) f32
    mask: Array,  # (N,) bool — which of the N candidates to push
) -> EventQueue:
    """Masked batch push (a ``lax.scan`` of ``push_event`` over N slots)."""

    def body(q, ev):
        t, c, k, p, m = ev
        return push_event(q, t, c, k, p, m), None

    q, _ = jax.lax.scan(
        body,
        q,
        (
            jnp.asarray(times, jnp.float32),
            jnp.asarray(clients, jnp.int32),
            jnp.asarray(kinds, jnp.int32),
            jnp.asarray(payloads, jnp.float32),
            jnp.asarray(mask, bool),
        ),
    )
    return q


def peek_time(q: EventQueue) -> Array:
    """Earliest valid event time; +inf when empty."""
    return jnp.min(jnp.where(q.valid, q.time, jnp.inf))


def pop_event(q: EventQueue) -> tuple[Event, EventQueue]:
    """Remove and return the earliest event (time order, then slot order).

    On an empty queue returns ``Event(valid=False)`` and the queue
    unchanged — scan bodies branch on ``event.valid``.
    """
    keyed = jnp.where(q.valid, q.time, jnp.inf)
    slot = jnp.argmin(keyed)
    has = jnp.any(q.valid)
    ev = Event(
        time=q.time[slot],
        client=q.client[slot],
        kind=q.kind[slot],
        payload=q.payload[slot],
        valid=has,
    )
    sel = (jnp.arange(q.capacity) == slot) & has
    return ev, q._replace(valid=q.valid & ~sel)


def pop_order_rank(q: EventQueue) -> Array:
    """(C,) pop-order rank of every slot under the queue's deterministic
    ordering — ascending ``(time, slot)`` over valid slots only.

    ``rank[i]`` = number of valid events that ``pop_event`` would return
    before slot ``i``. Invalid slots get rank ``C`` (never popped). O(C²)
    pairwise comparison, which is cheap at queue capacities (N + 8) and
    keeps the ordering definition in ONE place next to ``pop_event``.
    """
    c = q.capacity
    idx = jnp.arange(c)
    t_i = jnp.where(q.valid, q.time, jnp.inf)
    lex_before = (t_i[None, :] < t_i[:, None]) | (
        (t_i[None, :] == t_i[:, None]) & (idx[None, :] < idx[:, None])
    )
    rank = jnp.sum(q.valid[None, :] & lex_before, axis=1)
    return jnp.where(q.valid, rank, c)


def pop_batch(
    q: EventQueue, take: Array, rank: Array | None = None
) -> tuple[Array, Array, EventQueue]:
    """Masked batch-pop: remove the first ``take`` events in pop order.

    Returns ``(popped (C,) bool slot mask, t_last (), queue)`` where
    ``t_last`` is the time of the LAST popped event (-inf when ``take``
    selects nothing) — i.e. where the virtual clock lands after popping
    the batch one event at a time. Exactly equivalent to ``take``
    successive ``pop_event`` calls (same slots, same final queue), which
    is what the coalesced engine's bit-for-bit contract relies on.

    ``rank`` may pass a precomputed ``pop_order_rank(q)`` so callers in
    hot loop bodies (the coalesced engine step sits inside a switch
    branch, which XLA cannot CSE against the enclosing computation)
    don't pay the O(C²) ranking twice.
    """
    if rank is None:
        rank = pop_order_rank(q)
    popped = q.valid & (rank < jnp.asarray(take, rank.dtype))
    t_last = jnp.max(jnp.where(popped, q.time, -jnp.inf))
    return popped, t_last, q._replace(valid=q.valid & ~popped)


def cancel_events(q: EventQueue, client_mask: Array, kind: Array | int) -> EventQueue:
    """Invalidate every queued event of ``kind`` whose client is in
    ``client_mask`` (N,-bool over the client registry) — e.g. kill the
    pending COMPLETE of a client that churned out mid-flight."""
    hit = (
        q.valid
        & (q.kind == jnp.asarray(kind, jnp.int32))
        & (q.client >= 0)
        & client_mask[jnp.clip(q.client, 0, client_mask.shape[0] - 1)]
    )
    return q._replace(valid=q.valid & ~hit)
