"""Staleness-discounted aggregation — the async generalization of Eq. 6.

The synchronous round aggregates with Eq. 6 weights
``w_i = m_i·|D_i| / Σ m_j·|D_j|``. Asynchronously-arriving updates were
computed against an older model version; the server discounts them by a
polynomial staleness factor (FedAsync, Xie et al.; FedBuff, Nguyen et al.):

    disc(s) = (1 + s)^(-a)                       a = staleness_exponent ≥ 0

and aggregates a buffer B of updates with model-version staleness s_i as

    agg   = Σ_{i∈B} ŵ_i·Δ_i,   ŵ_i ∝ m_i·|D_i|·disc(s_i)   (relative mix)
    scale = (Σ m_i·|D_i|·disc(s_i) + ε) / (Σ m_i·|D_i| + ε) (global damping)
    w     ← w + η_server · scale · agg

Properties (tested in tests/test_async_engine.py):
  * disc(s) ∈ (0, 1], monotone non-increasing in s, disc(0) = 1;
  * with zero staleness (or a = 0) the whole rule reduces *exactly* to
    ``repro.core.aggregation.fedavg_stacked`` — scale is the bitwise
    constant 1.0 and ŵ equals the Eq. 6 weights — so a buffer holding a
    full synchronous cohort reproduces the sync server step;
  * a single buffered update of staleness s steps the server by
    ``η·disc(s)·Δ`` — the FedAsync mixing rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregation import _EPS, fedavg_stacked

Array = jax.Array


def stale_discount(staleness: Array, exponent: float | Array) -> Array:
    """Polynomial staleness discount ``(1 + s)^(-a)``; s clipped at 0."""
    s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
    return (1.0 + s) ** (-jnp.asarray(exponent, jnp.float32))


def staleness_weights(
    mask: Array, data_sizes: Array, staleness: Array, exponent: float | Array
) -> tuple[Array, Array]:
    """(normalized weights ŵ (N,), global damping scale ()).

    ``ŵ`` sums to ~1 over the buffer (Eq. 6 with discounted sizes);
    ``scale`` is the buffer's effective discount — exactly 1.0 when every
    buffered update has zero staleness.
    """
    disc = stale_discount(staleness, exponent)
    m = mask.astype(jnp.float32)
    sized = m * data_sizes.astype(jnp.float32)
    discounted = sized * disc
    w = discounted / (jnp.sum(discounted) + _EPS)
    scale = (jnp.sum(discounted) + _EPS) / (jnp.sum(sized) + _EPS)
    return w, scale


def async_aggregate(
    updates,
    mask: Array,
    data_sizes: Array,
    staleness: Array,
    exponent: float | Array,
):
    """Staleness-discounted Eq. 6 over a (N, ...)-stacked update pytree.

    Implemented *through* ``fedavg_stacked`` on discounted sizes so the
    zero-staleness case is bit-identical to the synchronous aggregation.
    """
    disc = stale_discount(staleness, exponent)
    agg = fedavg_stacked(updates, mask, data_sizes * disc)
    m = mask.astype(jnp.float32)
    sized = m * data_sizes.astype(jnp.float32)
    scale = (jnp.sum(sized * disc) + _EPS) / (jnp.sum(sized) + _EPS)
    return jax.tree.map(lambda a: a * scale.astype(a.dtype), agg)
