"""Event-driven asynchronous FL engine (virtual-clock DES).

Layering:

    queue.py     — fixed-capacity masked event queue: parallel
                   ``(time, client, kind, payload)`` arrays with argmin-pop
                   and shape-static push, usable inside ``lax.scan``.
    staleness.py — staleness-discounted generalization of the Eq. 6
                   weighted average (FedAsync / FedBuff server rules).
    churn.py     — client arrival/departure + battery-death availability
                   processes layered on ``data/telemetry.py``.
    engine.py    — ``AsyncFedFogSimulator``: the continuous-virtual-clock
                   event loop sharing the sync simulator's client-update,
                   scheduler-gating, and ``RoundCostModel`` code.

Import note: ``engine`` imports ``repro.fl.simulator``; keep this package
out of ``repro.sim.__init__`` so ``repro.fl.simulator → repro.sim.des``
does not become circular.
"""
from repro.sim.events.churn import ChurnConfig, available_mask, step_churn
from repro.sim.events.engine import AsyncConfig, AsyncFedFogSimulator
from repro.sim.events.queue import (
    KIND_ARRIVE,
    KIND_COMPLETE,
    KIND_DEADLINE,
    KIND_DISPATCH,
    KIND_RETRY,
    EventQueue,
    cancel_events,
    make_queue,
    pop_batch,
    pop_event,
    pop_order_rank,
    push_event,
    push_events,
)
from repro.sim.events.staleness import (
    async_aggregate,
    stale_discount,
    staleness_weights,
)

__all__ = [
    "AsyncConfig",
    "AsyncFedFogSimulator",
    "ChurnConfig",
    "EventQueue",
    "KIND_ARRIVE",
    "KIND_COMPLETE",
    "KIND_DEADLINE",
    "KIND_DISPATCH",
    "KIND_RETRY",
    "async_aggregate",
    "available_mask",
    "cancel_events",
    "make_queue",
    "pop_batch",
    "pop_event",
    "pop_order_rank",
    "push_event",
    "push_events",
    "stale_discount",
    "staleness_weights",
    "step_churn",
]
