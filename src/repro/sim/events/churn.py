"""Client churn & availability processes for the async engine.

Layered on ``data/telemetry.py``: telemetry supplies the battery signal,
this module supplies the *presence* signal. Each client is an independent
two-state continuous-time Markov process (online/offline) with exponential
holding times, stepped lazily at event times:

    P(depart in dt | online)  = 1 - exp(-departure_rate · dt)
    P(arrive in dt | offline) = 1 - exp(-arrival_rate  · dt)

with dt in virtual seconds. A client is *available* for dispatch when it
is online AND its battery is above the death threshold — matching the
sync engine's "everyone alive" rule (``batt > 0.05``). Clients that
become unavailable while an update is in flight are stragglers that never
report: the engine cancels their COMPLETE events.

Rates of 0 (the default) disable churn entirely — ``step_churn`` is then
the identity, which is what the async-vs-sync equivalence tests rely on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import static_zero

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    arrival_rate: float = 0.0  # offline→online events per virtual second
    departure_rate: float = 0.0  # online→offline events per virtual second
    death_batt: float = 0.05  # battery level below which a client is dead
    initial_online_frac: float = 1.0  # fraction online at t=0


def init_online(cfg: ChurnConfig, num_clients: int, key: Array) -> Array:
    """(N,) bool initial presence mask."""
    if cfg.initial_online_frac >= 1.0:
        return jnp.ones((num_clients,), bool)
    return jax.random.uniform(key, (num_clients,)) < cfg.initial_online_frac


def step_churn(cfg: ChurnConfig, online: Array, dt_ms: Array, key: Array) -> Array:
    """Advance the presence process by ``dt_ms`` virtual milliseconds.

    Rates may be traced scalars (sweep-lifted config data); the identity
    shortcut then stays off, and the math path is itself an exact
    identity at zero rates (``u >= 0`` / ``u < 0`` on uniform draws).
    """
    if static_zero(cfg.arrival_rate) and static_zero(cfg.departure_rate):
        return online
    dt_s = jnp.maximum(jnp.asarray(dt_ms, jnp.float32), 0.0) * 1e-3
    p_depart = 1.0 - jnp.exp(-cfg.departure_rate * dt_s)
    p_arrive = 1.0 - jnp.exp(-cfg.arrival_rate * dt_s)
    u = jax.random.uniform(key, online.shape)
    return jnp.where(online, u >= p_depart, u < p_arrive)


def available_mask(cfg: ChurnConfig, online: Array, batt: Array) -> Array:
    """Online AND battery above the death threshold."""
    return online & (batt > cfg.death_batt)
