"""``AsyncFedFogSimulator`` — event-driven asynchronous FL on a virtual clock.

Where ``FedFogSimulator`` runs synchronous rounds (the straggler defines
the round via ``max(per_client_ms)`` and nothing ever arrives late), this
engine advances a continuous virtual clock through a fixed-capacity event
queue (``queue.py``):

  * DISPATCH events admit clients through the *same* ``schedule_round``
    gating + policy participation as the sync engine, compute their local
    updates against the current global model (shared
    ``FedFogSimulator._local_deltas``), and schedule one COMPLETE event
    per admitted client at a per-client arrival time drawn from the
    shared ``RoundCostModel.times_ms`` plus an optional lognormal
    straggler tail.
  * COMPLETE events move the client's update into the server buffer. The
    server flushes the buffer — the staleness-discounted Eq. 6
    generalization in ``staleness.py`` — either when it holds
    ``buffer_k`` updates (FedBuff) / every update (``buffer_k=1``,
    FedAsync), or when nothing is left in flight.
  * Churn (``churn.py``): clients arrive/depart and die on battery
    between events; a client that becomes unavailable mid-flight never
    reports (its COMPLETE event is cancelled).

The loop executes with **coalesced stepping** (``AsyncConfig.coalesce``,
default on): each step pops EITHER one DISPATCH or the whole run of
COMPLETE events that precede the next DISPATCH in pop order (capped at
the ``buffer_k`` count-flush boundary so no flush could have fired
mid-run), processes the completions as one masked buffer-fill, and runs
inside a ``lax.while_loop`` that exits as soon as the queue drains.
This matters because the loop is vmapped over seeds
(``repro.sim.sweep.run_sweep(engine="async")``) and batched
``lax.switch``/``cond`` execute ALL branches — one-pop-per-step pays the
full dispatch+flush computation ``D·(N+1)`` times; coalesced stepping
pays it ~``2·D`` times. ``coalesce=False`` keeps the original
one-pop-per-step ``lax.scan``/``lax.switch`` engine, which the
equivalence tests use as the oracle: trajectories agree **bit-for-bit**
(the batch-pop frees exactly the slots the sequential pops would, so
even same-timestamp tie-breaks and push-slot assignment are preserved).

Sync recovery: with ``dispatch_mode="on_flush"``, no churn, no straggler
tail, ``buffer_k=None`` (flush when the cohort drains) and
``staleness_exponent=0``, every dispatch behaves exactly like one
synchronous round — the accuracy trajectory matches ``run_scanned()`` to
float tolerance (tests/test_async_engine.py). The async machinery is a
strict generalization, not a parallel implementation.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg_mod
from repro.core.scheduler import account_energy, schedule_round
from repro.core.types import static_on
from repro.data.telemetry import step_telemetry
from repro.core.types import SchedulerState
from repro.fl import fog as fog_mod
from repro.fl.fuse import (
    fuse_clients,
    fuse_vector,
    fused_gaussian_noise,
    leaf_sizes,
)
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.kernels.delta_pipeline import delta_pipeline_apply
from repro.obs.history import (
    assemble_async_history,
    finalize_history,
    summary_metrics,
)
from repro.sim.events.churn import (
    ChurnConfig,
    available_mask,
    init_online,
    step_churn,
)
from repro.sim.events.queue import (
    KIND_COMPLETE,
    KIND_DEADLINE,
    KIND_DISPATCH,
    KIND_RETRY,
    cancel_events,
    make_queue,
    pop_batch,
    pop_event,
    pop_order_rank,
    push_event,
    push_events,
)
from repro.sim.events.staleness import async_aggregate
from repro.sim.faults import config as faults_config

Array = jax.Array

_FLUSH_METRICS = (
    "t_ms", "accuracy", "num_aggregated", "mean_staleness", "energy_j",
    "update_latency_ms", "cold_starts",
)
_DISPATCH_METRICS = ("t_ms", "num_admitted", "num_available", "cold_starts")


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Event-engine knobs, orthogonal to the shared ``SimulatorConfig``.

    ``buffer_k``: server buffer size K. ``1`` aggregates every arriving
    update immediately (FedAsync); ``K>1`` waits for K updates (FedBuff);
    ``None`` disables count-triggered flushes — combined with
    ``flush_on_idle`` that means "flush when the cohort drains", the
    synchronous-equivalent configuration.

    ``dispatch_mode``: ``"on_flush"`` schedules the next DISPATCH when a
    flush happens (sequential cohorts, sync-like); ``"interval"``
    dispatches on a fixed virtual cadence so cohorts overlap and
    staleness actually accrues.
    """

    max_dispatches: int | None = None  # default: SimulatorConfig.rounds
    dispatch_mode: str = "on_flush"  # "on_flush" | "interval"
    dispatch_interval_ms: float = 5000.0
    buffer_k: int | None = None  # 1=FedAsync, K>1=FedBuff, None=cohort
    flush_on_idle: bool = True  # flush leftovers when nothing is in flight
    staleness_exponent: float = 0.5  # a in (1+s)^-a; 0 = no discount
    straggler_sigma: float = 0.0  # lognormal tail on per-client latency
    horizon_ms: float | None = None  # stop dispatching past this time
    churn: ChurnConfig = dataclasses.field(default_factory=ChurnConfig)
    queue_capacity: int | None = None  # default: num_clients + 8
    max_events: int | None = None  # default: max_dispatches*(N+1)+2
    coalesce: bool = True  # batched event stepping (False = one pop/step)

    @classmethod
    def fedasync(cls, **kw) -> "AsyncConfig":
        """Immediate staleness-weighted application of every update."""
        kw.setdefault("buffer_k", 1)
        kw.setdefault("dispatch_mode", "interval")
        return cls(**kw)

    @classmethod
    def fedbuff(cls, k: int = 8, **kw) -> "AsyncConfig":
        """Buffered aggregation: flush every ``k`` arrived updates."""
        kw.setdefault("buffer_k", k)
        kw.setdefault("dispatch_mode", "interval")
        return cls(**kw)


class AsyncState(NamedTuple):
    """Full event-loop carry — a pytree, so the loop scans and vmaps."""

    queue: Any
    t_ms: Array  # () virtual clock
    key: Array  # dispatch-round key chain (mirrors the sync engine)
    env: Any  # profiles / data_sizes / malicious / data_seed
    params: Any
    sched: Any  # SchedulerState
    tel: Any  # ClientTelemetry
    online: Array  # (N,) churn presence
    version: Array  # () global model version (increments per flush)
    dispatch_idx: Array  # () dispatches so far
    flush_idx: Array  # () flushes so far
    completions: Array  # () updates arrived so far
    lost_inflight: Array  # () in-flight updates killed by churn
    busy: Array  # (N,) update in flight
    buf: Array  # (N,) completed, awaiting aggregation
    pending: Array  # (N, P) FUSED delta buffer stored at dispatch time
    pend_version: Array  # (N,) model version the delta was computed at
    pend_energy: Array  # (N,) Joules of the in-flight update
    pend_t: Array  # (N,) dispatch time of the in-flight update
    last_disp_t: Array  # () time of the latest dispatch
    last_cold: Array  # () cold starts accrued since the last flush
    k_dp: Array  # keys captured at the latest dispatch, consumed at flush
    k_tel: Array
    k_eval: Array
    key_uses: Array  # () flushes that already consumed the stored keys
    m_flush: Any  # dict of (max_flushes,) metric arrays
    m_dispatch: Any  # dict of (max_dispatches,) metric arrays
    # Population mode (SimulatorConfig.population > num_clients): the N
    # event slots are leased to virtual clients. ``owner[i]`` is the
    # population id whose in-flight/buffered update occupies slot i, and
    # ``pend_sizes[i]`` its |D| weight, captured at admission so the
    # flush never gathers from the (M,) registry at aggregate time. In
    # dense mode both are inert (owner = arange, sizes = registry rows).
    owner: Array  # (N,) int32 population id leasing each slot
    pend_sizes: Array  # (N,) f32 |D| of the slot's in-flight update
    # Fault layer (repro.sim.faults) — inert zeros when the fault gate
    # is off; the event mechanics below only touch them under the gate.
    pend_ms: Array  # (N,) f32 one attempt's latency (retries repay it)
    pend_fkey: Array  # (N, 2) u32 per-client fault key chain
    pend_attempts: Array  # (N,) f32 attempts launched (energy multiplier)
    last_admitted: Array  # () f32 admitted count of the latest dispatch
    fault_failures: Array  # () i32 failed invocation attempts
    fault_retries: Array  # () i32 retry relaunches
    fault_terminal: Array  # () i32 clients that exhausted the retry cap
    fault_lost_deadline: Array  # () i32 in-flight work shed by a deadline
    fault_corrupt: Array  # () i32 corrupted-but-arrived payloads
    fault_skipped: Array  # () i32 below-quorum rounds skipped
    fog_outages: Array  # () i32 fog-node dark windows


class AsyncFedFogSimulator:
    """Event-driven engine wrapping (and sharing code with) the sync one.

    Composition: ``self.sim`` is a ``FedFogSimulator(defer_state=True)``
    providing ``init_state`` / ``_histograms`` / ``_participation`` /
    ``_local_deltas`` / ``_eval_accuracy`` and the shared
    ``RoundCostModel`` — the async engine adds only the event mechanics.
    """

    def __init__(
        self,
        cfg: SimulatorConfig,
        async_cfg: AsyncConfig | None = None,
        *,
        tap=None,
    ):
        """``tap`` (``repro.obs.MetricTap``): stream every k-th server
        flush's metrics out of the compiled event loop via an ordered
        ``io_callback`` (decimated on the flush index). ``None`` keeps
        the traced program bitwise identical to the untapped engine —
        same structural-gate contract as ``FedFogSimulator``."""
        self.cfg = cfg
        self.acfg = async_cfg or AsyncConfig()
        self.tap = tap if (tap is not None and tap.enabled) else None
        if self.acfg.dispatch_mode not in ("on_flush", "interval"):
            raise ValueError(f"unknown dispatch_mode {self.acfg.dispatch_mode!r}")
        self.sim = FedFogSimulator(cfg, defer_state=True)
        n = cfg.num_clients
        self.max_dispatches = int(self.acfg.max_dispatches or cfg.rounds)
        # Fault layer gate — shared with the embedded sync simulator so
        # the two engines agree on when the plan is live. The async
        # engine realizes faults event-by-event (KIND_RETRY relaunches
        # with backoff, KIND_DEADLINE sheds overdue work); the sync
        # emulation in sim/faults/inject.py never runs here.
        self._faults_on = self.sim._faults_on
        deadline_on = (
            self._faults_on and cfg.faults.deadline_ms is not None
        )
        if self._faults_on:
            retries = int(cfg.faults.max_retries)
            # Outstanding events: ≤ 1 per client (its next COMPLETE or
            # RETRY) + 1 DISPATCH + a backlog of ≤ D un-fired deadline
            # events (stale ones linger until their time comes up).
            default_cap = n + 8 + (self.max_dispatches if deadline_on else 0)
            # Pops per dispatch: 1 dispatch + ≤ N·(retries+1) attempt
            # events + 1 deadline (+ slack).
            default_events = (
                self.max_dispatches * (n * (retries + 2) + 2) + 2
            )
        else:
            default_cap = n + 8
            # One dispatch pops 1 event and enqueues ≤ N completions;
            # flushes are inline (not events). So D·(N+1)+2 pops always
            # drain the run.
            default_events = self.max_dispatches * (n + 1) + 2
        self.capacity = int(self.acfg.queue_capacity or default_cap)
        self.max_events = int(self.acfg.max_events or default_events)
        self.max_flushes = self.max_events  # flushes ≤ dispatches+completions
        # The AsyncState argument IS the event loop's scan carry — donate
        # it so the runtime reuses its buffers for the result instead of
        # holding both alive. CPU does not implement donation and would
        # warn on every call, so gate on the backend.
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._scan_jit = jax.jit(self._scan_events, donate_argnums=donate)

    # ------------------------------------------------------------------ #
    def init_state(self, seed) -> AsyncState:
        """Functional, seed-traceable initial state (vmappable)."""
        cfg, n = self.cfg, self.cfg.num_clients
        # init_state_fast: population-mode (M,) registries init through a
        # shared jitted program (inlines when this is itself traced);
        # dense mode stays on the eager path verbatim.
        env, params, sched, tel = self.sim.init_state_fast(seed)
        key = jax.random.PRNGKey(jnp.asarray(seed, jnp.int32) + 100)
        online = init_online(
            self.acfg.churn, n, jax.random.fold_in(key, 2718)
        )
        queue = push_event(make_queue(self.capacity), 0.0, -1, KIND_DISPATCH)
        # The in-flight delta stash is carried FUSED as one (N, P) f32
        # buffer rather than a (N, ...)-stacked pytree: one carry leaf
        # instead of one per parameter tensor (a real trace/compile-time
        # cut on the event loop, whose carry dominates the jaxpr), one
        # masked `where` per dispatch, and the flush feeds it straight
        # to async_aggregate / the Pallas delta-pipeline kernel.
        pending = jnp.zeros((n, sum(leaf_sizes(params))), jnp.float32)
        zero = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return AsyncState(
            queue=queue,
            t_ms=zero,
            key=key,
            env=env,
            params=params,
            sched=sched,
            tel=tel,
            online=online,
            version=zi,
            dispatch_idx=zi,
            flush_idx=zi,
            completions=zi,
            lost_inflight=zi,
            busy=jnp.zeros((n,), bool),
            buf=jnp.zeros((n,), bool),
            pending=pending,
            pend_version=jnp.zeros((n,), jnp.int32),
            pend_energy=jnp.zeros((n,), jnp.float32),
            pend_t=jnp.zeros((n,), jnp.float32),
            last_disp_t=zero,
            last_cold=zi,
            k_dp=key,
            k_tel=key,
            k_eval=key,
            key_uses=zi,
            m_flush={
                k: jnp.zeros((self.max_flushes,), jnp.float32)
                for k in _FLUSH_METRICS + ("valid",)
            },
            m_dispatch={
                k: jnp.zeros((self.max_dispatches,), jnp.float32)
                for k in _DISPATCH_METRICS
            },
            owner=jnp.arange(n, dtype=jnp.int32),
            pend_sizes=env["data_sizes"][jnp.arange(n)].astype(jnp.float32),
            pend_ms=jnp.zeros((n,), jnp.float32),
            pend_fkey=jnp.zeros((n, 2), jnp.uint32),
            pend_attempts=jnp.zeros((n,), jnp.float32),
            last_admitted=zero,
            fault_failures=zi,
            fault_retries=zi,
            fault_terminal=zi,
            fault_lost_deadline=zi,
            fault_corrupt=zi,
            fault_skipped=zi,
            fog_outages=zi,
        )

    # ------------------------------------------------------------------ #
    def _data_cfg(self, state):
        return dataclasses.replace(
            self.sim.data_cfg, seed=state.env["data_seed"]
        )

    def _more_dispatches(self, state, t_next):
        """Whether another DISPATCH may be scheduled at ``t_next``."""
        more = state.dispatch_idx < self.max_dispatches
        if self.acfg.horizon_ms is not None:
            more = more & (t_next <= self.acfg.horizon_ms)
        return more

    def _flush(self, state: AsyncState) -> AsyncState:
        """Aggregate the buffer into the global model (one server step).

        Mirrors the tail of the sync round: staleness-generalized Eq. 6,
        optional DP noise, server step, Eq. 10 energy accounting,
        telemetry step, eval — consuming the keys captured at the latest
        dispatch so the cohort configuration reproduces ``_round``.
        """
        cfg, acfg = self.cfg, self.acfg
        buf = state.buf
        staleness = (state.version - state.pend_version).astype(jnp.float32)
        # The first flush after a dispatch consumes that dispatch's keys
        # verbatim (this is what makes cohort mode reproduce the sync
        # round); repeat flushes before the next dispatch fold in the use
        # count so DP noise / telemetry / eval draws stay independent.
        uses = state.key_uses

        def fresh(k):
            return jnp.where(uses == 0, k, jax.random.fold_in(k, uses))

        # ``pending`` is already the fused (N, P) buffer; the server step
        # runs on flat vectors and unfuses once for eval/telemetry. The
        # DP noise vector uses the reference per-leaf key recipe
        # (core.privacy.gaussian_mechanism draws), so fusing does not
        # change the noise stream.
        base_flat, unfuse_vec = fuse_vector(state.params)
        noise = None
        if static_on(cfg.dp_sigma):
            noise = fused_gaussian_noise(
                fresh(state.k_dp),
                cfg.dp_sigma * (cfg.clip_norm or 1.0),
                leaf_sizes(state.params),
                [x.shape for x in jax.tree.leaves(state.params)],
            )
        # Population mode aggregates with the |D| weights captured at
        # admission (the slot's lease), so the flush never touches the
        # (M,) registry for model-sized math.
        pop_mode = self.sim._pop_mode
        sizes_vec = state.pend_sizes if pop_mode else state.env["data_sizes"]
        # Robust aggregators are unweighted medians/means over the live
        # buffer — staleness discounting does not compose with them, so
        # they ignore it on both paths (same as the sync round).
        robust = cfg.aggregator in ("median", "trimmed")
        if cfg.use_pallas_agg:
            # Fused delta-pipeline kernel: staleness-discounted Eq. 6
            # weighting + reduction (or the in-kernel median / trimmed
            # selection) + DP noise + apply in ONE pass over the (N, P)
            # buffer. With a fog tier the same pass runs per fog block
            # and the cloud combines the partials (fl/fog.py).
            if cfg.fog_nodes > 1:
                new_flat = fog_mod.fog_pipeline_apply(
                    state.pending, base_flat, buf, sizes_vec,
                    lr=cfg.server_lr,
                    staleness=staleness,
                    staleness_exponent=acfg.staleness_exponent,
                    dp_noise=noise,
                    fog_nodes=cfg.fog_nodes,
                )
            else:
                new_flat = delta_pipeline_apply(
                    state.pending, base_flat, buf, sizes_vec,
                    lr=cfg.server_lr,
                    staleness=None if robust else staleness,
                    staleness_exponent=acfg.staleness_exponent,
                    dp_noise=noise,
                    trim_fraction=cfg.trim_fraction,
                    aggregator=cfg.aggregator,
                )
        else:
            if cfg.aggregator == "median":
                agg = agg_mod.median_aggregate(state.pending, buf)
            elif cfg.aggregator == "trimmed":
                agg = agg_mod.trimmed_mean_aggregate(
                    state.pending, buf, cfg.trim_fraction
                )
            elif cfg.fog_nodes > 1:
                agg = fog_mod.fog_aggregate(
                    state.pending, buf, sizes_vec, cfg.fog_nodes,
                    staleness, acfg.staleness_exponent,
                )
            else:
                agg = async_aggregate(
                    state.pending, buf, sizes_vec, staleness,
                    acfg.staleness_exponent,
                )
            if noise is not None:
                agg = agg + noise
            new_flat = base_flat + cfg.server_lr * agg
        params = unfuse_vec(new_flat)
        energy = state.pend_energy * buf
        if self._faults_on:
            # Every launched attempt repays the invocation's energy (the
            # crashed/timed-out function restarts from scratch). Energy
            # lands when the update flushes — terminal/churned clients'
            # attempts follow the engine's existing convention of not
            # being accounted (their updates never reach a flush).
            energy = energy * state.pend_attempts
        if pop_mode:
            # Gather the owners' registry rows, advance only the flushed
            # slots' rows, scatter back. Duplicate owners across slots
            # (possible when a later candidate draw collides with a slot
            # still leased from an earlier dispatch) resolve
            # last-writer-wins — a documented approximation; collisions
            # are O(N/M) rare at population scale.
            owner = state.owner
            n = cfg.num_clients
            prof_rows = fog_mod.gather_rows(state.env["profiles"], owner)
            srows = SchedulerState(
                prev_hist=jnp.zeros((n, 1), jnp.float32),  # not consumed
                theta_e=state.sched.theta_e[owner],
                warm=state.sched.warm[owner],
                last_used=state.sched.last_used[owner],
                energy_spent=state.sched.energy_spent[owner],
                round_index=state.sched.round_index,
            )
            srows2 = account_energy(srows, energy, cfg.scheduler)
            sched = dataclasses.replace(
                state.sched,
                theta_e=state.sched.theta_e.at[owner].set(
                    jnp.where(buf, srows2.theta_e, srows.theta_e)
                ),
                energy_spent=state.sched.energy_spent.at[owner].set(
                    jnp.where(buf, srows2.energy_spent, srows.energy_spent)
                ),
            )
            tel_rows = fog_mod.gather_rows(state.tel, owner)
            stepped = step_telemetry(
                self.sim._tel_cfg_cohort, tel_rows, buf, energy, prof_rows,
                fresh(state.k_tel),
            )
            stepped = jax.tree.map(
                lambda new, old: jnp.where(buf, new, old), stepped, tel_rows
            )
            tel = fog_mod.scatter_rows(state.tel, owner, stepped)
        else:
            sched = account_energy(state.sched, energy, cfg.scheduler)
            tel = step_telemetry(
                self.sim.tel_cfg, state.tel, buf, energy,
                state.env["profiles"], fresh(state.k_tel),
            )
        acc = self.sim._eval_accuracy(
            self._data_cfg(state), params, fresh(state.k_eval)
        )

        count = jnp.sum(buf.astype(jnp.float32))
        f = state.flush_idx
        vals = {
            "t_ms": state.t_ms,
            "accuracy": acc,
            "num_aggregated": count,
            "mean_staleness": jnp.sum(staleness * buf) / jnp.maximum(count, 1.0),
            "energy_j": jnp.sum(energy),
            "update_latency_ms": jnp.max(
                jnp.where(buf, state.t_ms - state.pend_t, 0.0)
            ),
            "cold_starts": state.last_cold.astype(jnp.float32),
            "valid": jnp.ones((), jnp.float32),
        }
        m_flush = {
            k: v.at[f].set(jnp.asarray(vals[k], jnp.float32), mode="drop")
            for k, v in state.m_flush.items()
        }
        if self.tap is not None:
            # Per-flush streaming tap, decimated on the flush index —
            # ordered io_callback, legal inside the cond/while_loop the
            # flush runs under. Side effect only: flush values and the
            # carried state are untouched.
            self.tap.emit(
                {k: v for k, v in vals.items() if k != "valid"}, f
            )
        queue = state.queue
        if acfg.dispatch_mode == "on_flush":
            # Next cohort starts when this one is aggregated — unless a
            # DISPATCH is already queued (possible under buffer_k flushes).
            queued = jnp.any(
                queue.valid & (queue.kind == KIND_DISPATCH)
            )
            queue = push_event(
                queue, state.t_ms, -1, KIND_DISPATCH,
                enable=self._more_dispatches(state, state.t_ms) & ~queued,
            )
        return state._replace(
            queue=queue,
            params=params,
            sched=sched,
            tel=tel,
            version=state.version + 1,
            flush_idx=f + 1,
            key_uses=uses + 1,
            buf=jnp.zeros_like(buf),
            # Cold starts are consumed by the flush that reports them, so
            # repeat flushes between dispatches (FedAsync) cannot re-count
            # the same dispatch's cold starts: Σ flush == Σ dispatch.
            last_cold=jnp.zeros_like(state.last_cold),
            m_flush=m_flush,
        )

    # ------------------------------------------------------------------ #
    def _on_dispatch(self, state: AsyncState, ev) -> AsyncState:
        """Dispatch handler for the single-pop oracle engine: the core
        plus the (possible) empty-cohort flush applied in place."""
        state, want_flush = self._dispatch_core(state, ev)
        if self.acfg.dispatch_mode == "interval":
            return state  # want_flush is statically never set
        return jax.lax.cond(want_flush, self._flush, lambda s: s, state)

    def _dispatch_core(self, state: AsyncState, ev):
        """The dispatch mechanics WITHOUT the trailing flush ``cond`` —
        returns ``(state, want_flush)`` so the coalesced step can apply
        ONE shared flush conditional after the event switch instead of
        tracing the whole flush graph (aggregation + server step + eval)
        once per branch. The single-pop oracle wraps it back into
        ``_on_dispatch`` — values are identical either way."""
        cfg, acfg = self.cfg, self.acfg
        n = cfg.num_clients
        d = state.dispatch_idx

        # Key chain mirrors the sync engine exactly: the same six per-round
        # subkeys, with engine-only keys derived via fold_in so they do not
        # perturb the shared streams.
        key, k = jax.random.split(state.key)
        k_sel, k_data, k_attack, k_dp, k_tel, k_eval = jax.random.split(k, 6)
        k_churn = jax.random.fold_in(k, 101)
        k_strag = jax.random.fold_in(k, 102)

        # --- population mode: lease the N slots to virtual clients ----- #
        # A fresh candidate cohort is drawn per dispatch (fold_in key 103,
        # disjoint from the shared streams); slots still holding an
        # in-flight or buffered update keep their current owner, free
        # slots take the candidate's registry rows. All scheduling /
        # training / cost math below then runs on the slot-level rows —
        # the flat path binds the same names to the dense (N,) state and
        # stays verbatim.
        pop_mode = self.sim._pop_mode
        if pop_mode:
            cand = fog_mod.stratified_cohort(
                jax.random.fold_in(k, 103), self.sim.population, n
            )
            slot_owner = jnp.where(state.busy | state.buf, state.owner, cand)
            tel_view = fog_mod.gather_rows(state.tel, slot_owner)
            prof_view = fog_mod.gather_rows(state.env["profiles"], slot_owner)
            mal_view = state.env["malicious"][slot_owner]
            cids = slot_owner
        else:
            slot_owner = state.owner
            tel_view = state.tel
            prof_view = state.env["profiles"]
            mal_view = state.env["malicious"]
            cids = None

        # --- churn & availability (between-events process) ------------- #
        # Churn is a slot-level process in population mode (a departed
        # slot kills whichever virtual client leases it) — an
        # approximation that keeps the event mechanics population-free.
        online = step_churn(
            acfg.churn, state.online, state.t_ms - state.last_disp_t, k_churn
        )
        avail = available_mask(acfg.churn, online, tel_view.batt)
        lost = state.busy & ~avail  # stragglers that will never report
        queue = cancel_events(state.queue, lost, KIND_COMPLETE)
        if self._faults_on:
            # A churned client's pending retry chain dies with it.
            queue = cancel_events(queue, lost, KIND_RETRY)
        busy = state.busy & ~lost

        # --- scheduler gating + policy participation (shared code) ----- #
        data_cfg = self._data_cfg(state)
        hist = self.sim._histograms(data_cfg, d, cids=cids)
        if pop_mode:
            sched_view = fog_mod.gather_cohort_sched(
                state.sched, slot_owner,
                lambda c, r: self.sim._histograms(data_cfg, r, cids=c),
            )
        else:
            sched_view = state.sched
        decision = schedule_round(sched_view, tel_view, hist, cfg.scheduler)
        mask = self.sim._participation(decision, tel_view, k_sel)
        admitted = mask & avail & ~busy & ~state.buf
        deltas, admitted = self.sim._local_deltas(
            data_cfg, state.params, d, admitted, mal_view,
            k_data, k_attack, cids=cids,
        )

        # --- per-client arrival times (shared cost model + tail) ------- #
        workload, up_bytes, down_bytes = self.sim._round_workload()
        warm = sched_view.warm
        if cfg.policy in ("fogfaas",):
            warm = jnp.zeros_like(warm)
        costs = self.sim.cost_model.round_costs(
            prof_view, admitted, warm, workload, up_bytes,
            down_bytes,
            policy="fedfog" if cfg.policy in ("fedfog", "rcs", "vanilla")
            else "fogfaas",
        )
        per_client_ms = costs.per_client_ms
        if static_on(acfg.straggler_sigma):
            per_client_ms = per_client_ms * jnp.exp(
                acfg.straggler_sigma * jax.random.normal(k_strag, (n,))
            )

        # --- fault plan: attempt-0 outcomes + per-client retry chains -- #
        # Engine-only key fold_in(k, 104) — disjoint from the shared
        # 6-way split and the 101/102/103 engine keys, so a faulted run
        # replays exactly from the seed and fault draws never perturb
        # the sync-shared streams.
        fail0 = jnp.zeros((n,), bool)
        corrupt0 = jnp.zeros((n,), bool)
        fkeys = state.pend_fkey
        if self._faults_on:
            fc = cfg.faults
            k_fault = jax.random.fold_in(k, 104)
            (
                k_draw, k_part, k_pfrac, k_cmask, k_cnoise, k_fog, k_client,
            ) = jax.random.split(k_fault, 7)
            part_on = jax.random.uniform(k_part, ()) < jnp.asarray(
                fc.partition_rate, jnp.float32
            )
            part_cut = part_on & (
                jax.random.uniform(k_pfrac, (n,))
                < jnp.asarray(fc.partition_frac, jnp.float32)
            )
            from repro.sim.faults.inject import attempt_failures

            fail0 = attempt_failures(
                fc, k_draw, admitted, ~warm, part_cut, 0
            )
            # Fog outage window for this dispatch: a dark fog loses its
            # edge clients' uplinks. With failover the survivors absorb
            # them at a latency detour; without it the attempt fails
            # (the retry lands in the next, possibly healed, window).
            if cfg.fog_nodes > 1:
                outage = jax.random.uniform(
                    k_fog, (cfg.fog_nodes,)
                ) < jnp.asarray(fc.fog_outage_rate, jnp.float32)
                dark = outage[fog_mod.fog_assignment(n, cfg.fog_nodes)]
                if bool(fc.fog_failover):
                    per_client_ms = per_client_ms + jnp.where(
                        dark & admitted,
                        jnp.asarray(fc.failover_latency_ms, jnp.float32),
                        0.0,
                    )
                else:
                    fail0 = fail0 | (admitted & dark)
                state = state._replace(
                    fog_outages=state.fog_outages
                    + jnp.sum(outage).astype(jnp.int32)
                )
            corrupt0 = (
                admitted
                & ~fail0
                & (
                    jax.random.uniform(k_cmask, (n,))
                    < jnp.asarray(fc.corrupt_rate, jnp.float32)
                )
            )
            fkeys = jnp.where(
                admitted[:, None],
                jax.vmap(lambda i: jax.random.fold_in(k_client, i))(
                    jnp.arange(n)
                ),
                state.pend_fkey,
            )
            # Failed attempts re-enqueue as KIND_RETRY carrying the next
            # attempt index; the retry cap is enforced when that event
            # pops (attempt > cap → terminal), so cap=0 failures travel
            # the same path with zero backoff.
            delay1 = (
                faults_config.backoff_ms(fc, 1.0)
                if int(fc.max_retries) >= 1
                else jnp.zeros((), jnp.float32)
            )
            ev_kinds = jnp.where(fail0, KIND_RETRY, KIND_COMPLETE)
            ev_times = (
                state.t_ms
                + per_client_ms
                + jnp.where(fail0, delay1, 0.0)
            )
            ev_payloads = jnp.where(
                fail0, 1.0, jnp.full((n,), state.t_ms)
            )
            state = state._replace(
                fault_failures=state.fault_failures
                + jnp.sum(fail0).astype(jnp.int32),
                fault_corrupt=state.fault_corrupt
                + jnp.sum(corrupt0).astype(jnp.int32),
            )
        else:
            ev_kinds = jnp.full((n,), KIND_COMPLETE)
            ev_times = state.t_ms + per_client_ms
            ev_payloads = jnp.full((n,), state.t_ms)
        queue = push_events(
            queue,
            ev_times,
            jnp.arange(n),
            ev_kinds,
            ev_payloads,
            admitted,
        )
        if self._faults_on and cfg.faults.deadline_ms is not None:
            # One deadline event per dispatch. on_flush mode tags it with
            # the dispatch index (stale once a newer cohort started);
            # interval mode tags the dispatch time (it sheds only work
            # dispatched at or before it).
            tag = (
                d.astype(jnp.float32)
                if acfg.dispatch_mode == "on_flush"
                else state.t_ms
            )
            queue = push_event(
                queue,
                state.t_ms + jnp.asarray(cfg.faults.deadline_ms, jnp.float32),
                -1,
                KIND_DEADLINE,
                tag,
                enable=jnp.any(admitted),
            )

        # --- stash in-flight work (fused (N, P) buffer, one `where`) --- #
        deltas_cat, _ = fuse_clients(deltas)
        pending = jnp.where(admitted[:, None], deltas_cat, state.pending)
        if self._faults_on:
            # Attempt-0 payload corruption lands in the stash now; a
            # corrupted RETRY arrival adds its noise in _retry_core.
            noise0 = (
                jax.random.normal(k_cnoise, pending.shape)
                * jnp.asarray(cfg.faults.corrupt_scale, jnp.float32)
            )
            pending = pending + jnp.where(corrupt0[:, None], noise0, 0.0)
        if pop_mode:
            # Scatter the advanced cohort rows back into the (M,)
            # registry: warm/LRU from the cold-start cache update,
            # last_hist_round = this dispatch's histogram observation.
            # theta_e / energy_spent pass through schedule_round
            # untouched and advance at flush time instead.
            new_sched = dataclasses.replace(
                state.sched,
                warm=state.sched.warm.at[slot_owner].set(
                    decision.new_state.warm
                ),
                last_used=state.sched.last_used.at[slot_owner].set(
                    decision.new_state.last_used
                ),
                last_hist_round=state.sched.last_hist_round.at[
                    slot_owner
                ].set(jnp.broadcast_to(d, (n,))),
                round_index=decision.new_state.round_index,
            )
            new_owner = jnp.where(admitted, slot_owner, state.owner)
            new_pend_sizes = jnp.where(
                admitted,
                state.env["data_sizes"][slot_owner].astype(jnp.float32),
                state.pend_sizes,
            )
        else:
            new_sched = decision.new_state
            new_owner = state.owner
            new_pend_sizes = state.pend_sizes
        state = state._replace(
            queue=queue,
            key=key,
            sched=new_sched,
            owner=new_owner,
            pend_sizes=new_pend_sizes,
            online=online,
            busy=busy | admitted,
            pending=pending,
            pend_version=jnp.where(admitted, state.version, state.pend_version),
            pend_energy=jnp.where(admitted, costs.energy_j, state.pend_energy),
            pend_t=jnp.where(admitted, state.t_ms, state.pend_t),
            pend_ms=jnp.where(admitted, per_client_ms, state.pend_ms),
            pend_fkey=fkeys,
            pend_attempts=jnp.where(admitted, 1.0, state.pend_attempts),
            last_admitted=jnp.sum(admitted.astype(jnp.float32)),
            lost_inflight=state.lost_inflight
            + jnp.sum(lost.astype(jnp.int32)),
            last_disp_t=state.t_ms,
            last_cold=state.last_cold + costs.cold_starts,
            dispatch_idx=d + 1,
            k_dp=k_dp,
            k_tel=k_tel,
            k_eval=k_eval,
            key_uses=jnp.zeros((), jnp.int32),
        )

        n_admitted = jnp.sum(admitted.astype(jnp.float32))
        vals = {
            "t_ms": state.t_ms,
            "num_admitted": n_admitted,
            "num_available": jnp.sum(avail.astype(jnp.float32)),
            "cold_starts": costs.cold_starts.astype(jnp.float32),
        }
        state = state._replace(
            m_dispatch={
                k: v.at[d].set(vals[k], mode="drop")
                for k, v in state.m_dispatch.items()
            }
        )

        if acfg.dispatch_mode == "interval":
            t_next = state.t_ms + acfg.dispatch_interval_ms
            state = state._replace(
                queue=push_event(
                    state.queue, t_next, -1, KIND_DISPATCH,
                    enable=self._more_dispatches(state, t_next),
                )
            )
            want_flush = jnp.zeros((), bool)
        else:
            # Empty cohort: nothing will ever complete, so the round's
            # server step (eval / telemetry / DP — exactly what the sync
            # round does with an empty mask) happens right after this
            # dispatch, and it schedules the next dispatch.
            want_flush = n_admitted == 0
        return state, want_flush

    def _flush_rule(self, busy: Array, buf: Array) -> Array:
        """Whether the server flushes after absorbing completions — THE
        single definition of the count-trigger (``buffer_k``) and
        idle-trigger (``flush_on_idle``) rules. Shared by the single-pop
        handler and the coalesced batch step: keeping it in one place is
        what guarantees the two engines apply identical flush decisions
        (their bit-for-bit equivalence contract)."""
        acfg = self.acfg
        count = jnp.sum(buf.astype(jnp.int32))
        flush_now = jnp.zeros((), bool)
        if acfg.buffer_k is not None:
            flush_now = flush_now | (count >= acfg.buffer_k)
        if acfg.flush_on_idle:
            flush_now = flush_now | (~jnp.any(busy) & (count > 0))
        return flush_now

    def _on_complete(self, state: AsyncState, ev) -> AsyncState:
        c = jnp.clip(ev.client, 0, self.cfg.num_clients - 1)
        is_c = jnp.arange(self.cfg.num_clients) == c
        arrived = state.busy[c]  # stale events were cancelled, but be safe
        busy = state.busy & ~(is_c & arrived)
        buf = state.buf | (is_c & arrived)
        state = state._replace(
            busy=busy,
            buf=buf,
            completions=state.completions + arrived.astype(jnp.int32),
        )
        return jax.lax.cond(
            self._flush_rule(busy, buf), self._flush, lambda s: s, state
        )

    # ------------------------------------------------------------------ #
    def _retry_core(self, state: AsyncState, ev):
        """KIND_RETRY: relaunch one client's failed invocation.

        ``ev.payload`` carries the (1-based) attempt index. Past the
        retry cap the failure is terminal — the slot frees and the
        client never reports (conservation: admitted = completions +
        terminal + churn-lost + deadline-lost). Otherwise the attempt's
        outcome is drawn from the client's fault-key chain
        (``fold_in(pend_fkey[c], attempt)`` — deterministic in the seed,
        independent of event interleaving): success pushes the COMPLETE
        at ``t + pend_ms`` (the restarted function repays the full
        attempt latency; the container is warm now, so no timeout),
        failure re-enqueues the next retry after exponential backoff.

        A terminal failure participates in the flush decision exactly
        like an arrival (``_flush_rule``): freeing the last in-flight
        slot must fire the idle trigger, and a cohort that resolved
        ENTIRELY in terminal failures (empty buffer) still flushes so
        the server round advances — the empty-mask server step, same as
        an empty-cohort dispatch. Otherwise the engine would stall with
        an empty queue and the scan would no-op to the horizon.
        """
        fc = self.cfg.faults
        n = self.cfg.num_clients
        c = jnp.clip(ev.client, 0, n - 1)
        is_c = jnp.arange(n) == c
        attempt = jnp.maximum(ev.payload.astype(jnp.int32), 1)
        cap = jnp.asarray(int(fc.max_retries), jnp.int32)
        active = state.busy[c]  # churn/deadline-cancelled chains no-op
        terminal = active & (attempt > cap)
        relaunch = active & (attempt <= cap)

        k_a = jax.random.fold_in(state.pend_fkey[c], attempt)
        k_out, k_noise = jax.random.split(k_a)
        u = jax.random.uniform(k_out, (3,))
        draw_fail = (u[0] < jnp.asarray(fc.crash_rate, jnp.float32)) | (
            u[1] < jnp.asarray(fc.drop_rate, jnp.float32)
        )
        fail = relaunch & draw_fail
        succeed = relaunch & ~draw_fail
        corrupt = succeed & (
            u[2] < jnp.asarray(fc.corrupt_rate, jnp.float32)
        )

        t_arrive = ev.time + state.pend_ms[c]
        next_attempt = attempt + 1
        delay = jnp.where(
            next_attempt <= cap,
            faults_config.backoff_ms(fc, next_attempt),
            0.0,
        )
        queue = push_event(
            state.queue,
            jnp.where(fail, t_arrive + delay, t_arrive),
            c,
            jnp.where(fail, KIND_RETRY, KIND_COMPLETE),
            jnp.where(fail, next_attempt.astype(jnp.float32), state.pend_t[c]),
            enable=relaunch,
        )
        noise = jax.random.normal(
            k_noise, (state.pending.shape[1],)
        ) * jnp.asarray(fc.corrupt_scale, jnp.float32)
        pending = state.pending.at[c].add(jnp.where(corrupt, noise, 0.0))
        i32 = jnp.int32
        busy = state.busy & ~(is_c & terminal)
        state = state._replace(
            queue=queue,
            pending=pending,
            busy=busy,
            pend_attempts=state.pend_attempts
            + jnp.where(is_c & relaunch, 1.0, 0.0),
            fault_retries=state.fault_retries + relaunch.astype(i32),
            fault_failures=state.fault_failures + fail.astype(i32),
            fault_terminal=state.fault_terminal + terminal.astype(i32),
            fault_corrupt=state.fault_corrupt + corrupt.astype(i32),
        )
        idle = ~jnp.any(busy)
        all_terminal = idle & (jnp.sum(state.buf.astype(i32)) == 0)
        want_flush = terminal & (
            self._flush_rule(busy, state.buf) | all_terminal
        )
        return state, want_flush

    def _on_retry(self, state: AsyncState, ev) -> AsyncState:
        state, want_flush = self._retry_core(state, ev)
        return jax.lax.cond(want_flush, self._flush, lambda s: s, state)

    # ------------------------------------------------------------------ #
    def _deadline_core(self, state: AsyncState, ev):
        """KIND_DEADLINE: shed overdue in-flight work, then decide.

        on_flush mode (sequential cohorts): the event is stale once a
        newer cohort started (``dispatch_idx != tag+1``) or the cohort
        already fully resolved. A live deadline cancels the cohort's
        remaining COMPLETE/RETRY events, counts them lost, and applies
        the quorum rule: enough arrivals → flush the partial buffer
        (Eq. 6 reweights over it); below quorum → the round is SKIPPED
        (buffer cleared, model untouched) and the next dispatch is
        scheduled as a flush would have.

        interval mode (overlapping cohorts): sheds only work dispatched
        at or before the tag time, then lets the shared flush rule
        decide — quorum is a per-cohort notion and does not apply.
        """
        n = self.cfg.num_clients
        fc = self.cfg.faults
        on_flush = self.acfg.dispatch_mode == "on_flush"
        if on_flush:
            live = (
                (state.dispatch_idx == ev.payload.astype(jnp.int32) + 1)
                & (jnp.any(state.busy) | jnp.any(state.buf))
            )
            overdue = state.busy & live
        else:
            live = jnp.ones((), bool)
            overdue = state.busy & (state.pend_t <= ev.payload)
        queue = cancel_events(state.queue, overdue, KIND_COMPLETE)
        queue = cancel_events(queue, overdue, KIND_RETRY)
        n_shed = jnp.sum(overdue.astype(jnp.int32))
        state = state._replace(
            queue=queue,
            busy=state.busy & ~overdue,
            fault_lost_deadline=state.fault_lost_deadline + n_shed,
        )
        if not on_flush:
            return state, self._flush_rule(state.busy, state.buf)

        count = jnp.sum(state.buf.astype(jnp.float32))
        meets = (count > 0) & (
            count
            >= jnp.asarray(fc.quorum_frac, jnp.float32) * state.last_admitted
        )
        want_flush = live & meets

        def skip(s):
            queued = jnp.any(
                s.queue.valid & (s.queue.kind == KIND_DISPATCH)
            )
            q2 = push_event(
                s.queue, s.t_ms, -1, KIND_DISPATCH,
                enable=self._more_dispatches(s, s.t_ms) & ~queued,
            )
            return s._replace(
                queue=q2,
                buf=jnp.zeros_like(s.buf),
                last_cold=jnp.zeros_like(s.last_cold),
                fault_skipped=s.fault_skipped + 1,
            )

        state = jax.lax.cond(live & ~meets, skip, lambda s: s, state)
        return state, want_flush

    def _on_deadline(self, state: AsyncState, ev) -> AsyncState:
        state, want_flush = self._deadline_core(state, ev)
        return jax.lax.cond(want_flush, self._flush, lambda s: s, state)

    # ------------------------------------------------------------------ #
    def _coalesced_step(self, state: AsyncState) -> AsyncState:
        """One batched event step — exactly equivalent to a run of
        single pops (see module docstring for the bit-for-bit argument).

        If the earliest event is a DISPATCH: pop and handle just it.
        Otherwise pop the whole run of COMPLETE events preceding the
        first DISPATCH in pop order — capped at the ``buffer_k``
        count-flush boundary, so the single-pop engine could not have
        flushed (or observed an idle buffer) anywhere inside the run —
        fill the server buffer with one masked update, and apply the
        flush rule once at the end of the run.
        """
        acfg, n = self.acfg, self.cfg.num_clients
        q = state.queue
        rank = pop_order_rank(q)
        has = jnp.any(q.valid)
        first_slot = jnp.argmin(rank)
        first_kind = q.kind[first_slot]
        first_is_dispatch = first_kind == KIND_DISPATCH
        # COMPLETEs preceding the first queued barrier event in pop
        # order. Without faults the only barrier kind is DISPATCH (the
        # original engine verbatim); with faults, RETRY and DEADLINE
        # events are barriers too — they mutate busy/pending, so a
        # COMPLETE run may not absorb past them.
        if self._faults_on:
            is_d = q.valid & (q.kind != KIND_COMPLETE)
        else:
            is_d = q.valid & (q.kind == KIND_DISPATCH)
        n_before = jnp.min(jnp.where(is_d, rank, q.capacity))
        if acfg.buffer_k is not None:
            # Count-flush boundary: the single-pop engine flushes as soon
            # as the buffer reaches K, so a batch may only absorb the
            # room that is left (≥ 1 keeps the loop making progress).
            room = jnp.maximum(
                jnp.asarray(acfg.buffer_k, jnp.int32)
                - jnp.sum(state.buf.astype(jnp.int32)),
                1,
            )
            n_take = jnp.minimum(n_before, room)
        else:
            n_take = n_before

        def do_dispatch(state):
            ev, q2 = pop_event(state.queue)
            state = state._replace(
                queue=q2, t_ms=jnp.maximum(ev.time, state.t_ms)
            )
            return self._dispatch_core(state, ev)

        def do_completes(state):
            popped, t_last, q2 = pop_batch(state.queue, n_take, rank)
            cids = jnp.clip(state.queue.client, 0, n - 1)
            arrived = jnp.zeros((n,), bool).at[cids].max(popped)
            arrived = arrived & state.busy  # mirror _on_complete's guard
            state = state._replace(
                queue=q2,
                t_ms=jnp.maximum(state.t_ms, t_last),
                busy=state.busy & ~arrived,
                buf=state.buf | arrived,
                completions=state.completions
                + jnp.sum(arrived.astype(jnp.int32)),
            )
            return state, self._flush_rule(state.busy, state.buf)

        def noop(state):
            return state, jnp.zeros((), bool)

        # ONE shared flush conditional after the switch: the branches
        # only compute *whether* to flush, so the flush graph (staleness
        # aggregation + server step + telemetry + eval — the bulk of the
        # loop body's jaxpr) is traced once per step instead of once per
        # branch. Values are identical to flushing inside each branch,
        # since nothing runs between the branch tail and the cond.
        if self._faults_on:

            def do_retry(state):
                ev, q2 = pop_event(state.queue)
                state = state._replace(
                    queue=q2, t_ms=jnp.maximum(ev.time, state.t_ms)
                )
                return self._retry_core(state, ev)

            def do_deadline(state):
                ev, q2 = pop_event(state.queue)
                state = state._replace(
                    queue=q2, t_ms=jnp.maximum(ev.time, state.t_ms)
                )
                return self._deadline_core(state, ev)

            branch = jnp.where(
                has,
                jnp.where(
                    first_is_dispatch,
                    1,
                    jnp.where(
                        first_kind == KIND_COMPLETE,
                        2,
                        jnp.where(first_kind == KIND_RETRY, 3, 4),
                    ),
                ),
                0,
            )
            state, want_flush = jax.lax.switch(
                branch,
                [noop, do_dispatch, do_completes, do_retry, do_deadline],
                state,
            )
        else:
            branch = jnp.where(has, jnp.where(first_is_dispatch, 1, 2), 0)
            state, want_flush = jax.lax.switch(
                branch, [noop, do_dispatch, do_completes], state
            )
        return jax.lax.cond(want_flush, self._flush, lambda s: s, state)

    def _scan_events(self, state: AsyncState) -> AsyncState:
        """The whole experiment in one compiled loop.

        Coalesced (default): a ``lax.while_loop`` over batched steps that
        exits as soon as the queue drains (``max_events`` stays a safety
        bound). Single-pop (``coalesce=False``): the original
        ``lax.scan`` of ``max_events`` one-event pops — kept as the
        bit-for-bit oracle for the coalesced path.
        """
        if self.acfg.coalesce:

            def cond(carry):
                state, i = carry
                return jnp.any(state.queue.valid) & (i < self.max_events)

            def body(carry):
                state, i = carry
                return self._coalesced_step(state), i + 1

            state, _ = jax.lax.while_loop(
                cond, body, (state, jnp.zeros((), jnp.int32))
            )
            return state

        def step(state, _):
            ev, q = pop_event(state.queue)
            state = state._replace(
                queue=q,
                t_ms=jnp.where(
                    ev.valid, jnp.maximum(ev.time, state.t_ms), state.t_ms
                ),
            )
            if self._faults_on:
                # kinds are 0..3 → branch 1..4; invalid pops take 0.
                branch = jnp.where(
                    ev.valid, 1 + jnp.clip(ev.kind, 0, 3), 0
                )
                handlers = [
                    lambda s, e: s,
                    self._on_dispatch,
                    self._on_complete,
                    self._on_retry,
                    self._on_deadline,
                ]
            else:
                branch = jnp.where(
                    ev.valid,
                    jnp.where(ev.kind == KIND_DISPATCH, 1, 2),
                    0,
                )
                handlers = [
                    lambda s, e: s, self._on_dispatch, self._on_complete
                ]
            state = jax.lax.switch(branch, handlers, state, ev)
            return state, None

        state, _ = jax.lax.scan(step, state, None, length=self.max_events)
        return state

    def metrics_for_seed(self, seed):
        """Traceable seed → stacked flush-metric arrays (the sweep hook).

        Alongside the per-flush arrays, every engine-health and fault
        counter rides along as a first-class scalar channel — sweeps and
        tests assert on ``lost_inflight`` / ``queue_dropped`` / fault
        conservation straight off the history, no tracker required.
        (``run_sweep`` still raises on overflow, reading
        ``queue_dropped`` from the same channel.)
        """
        if self.tap is not None:
            raise RuntimeError(
                "metric taps are not supported on the vmapped sweep path "
                "(ordered io_callback cannot batch over seeds) — use "
                "run(), or run_sweep(tracker=...) for per-group events"
            )
        final = self._scan_events(self.init_state(seed))
        return {
            **final.m_flush,
            "queue_dropped": final.queue.dropped,
            "lost_inflight": final.lost_inflight,
            "completions": final.completions,
            "dispatched_total": jnp.sum(final.m_dispatch["num_admitted"]),
            **self._fault_counters(final),
        }

    @staticmethod
    def _fault_counters(state: AsyncState) -> dict[str, Array]:
        """The fault-layer counter channels (zeros when faults are off) —
        one schema for ``run()`` histories and sweep channels."""
        return {
            "fault_failures": state.fault_failures,
            "fault_retries": state.fault_retries,
            "fault_terminal": state.fault_terminal,
            "fault_lost_deadline": state.fault_lost_deadline,
            "fault_corrupt": state.fault_corrupt,
            "fault_skipped": state.fault_skipped,
            "fog_outages": state.fog_outages,
        }

    # ------------------------------------------------------------------ #
    def run(self, seed: int | None = None) -> dict[str, Any]:
        """Execute one async experiment; returns a history dict.

        Per-flush metric lists (trimmed to the actual flush count) plus
        per-dispatch lists (``dispatch_*``) and summary scalars.
        """
        state = self.init_state(self.cfg.seed if seed is None else seed)
        final = self._scan_jit(state)
        host = jax.device_get(
            (final.m_flush, final.m_dispatch,
             final.flush_idx, final.dispatch_idx, final.t_ms,
             final.completions, final.lost_inflight, final.queue.dropped)
        )
        m_flush, m_disp, n_f, n_d, t_ms, n_c, n_lost, dropped = host
        n_f, n_d = int(n_f), int(n_d)
        if int(dropped):
            # Overflow corrupts the flush history — still fatal, but
            # surfaced through the tracker first so a streamed log of a
            # crashed run ends with the reason.
            msg = (
                f"event queue overflowed ({int(dropped)} dropped); raise "
                f"AsyncConfig.queue_capacity above {self.capacity}"
            )
            self._warn("queue_overflow", msg, queue_dropped=int(dropped))
            raise RuntimeError(msg)
        history = assemble_async_history(m_flush, m_disp, n_f, n_d)
        history["num_dispatches"] = n_d
        history["num_flushes"] = n_f
        history["num_completions"] = int(n_c)
        history["lost_inflight"] = int(n_lost)
        history["virtual_time_ms"] = float(t_ms)
        for k, v in jax.device_get(self._fault_counters(final)).items():
            history[k] = int(v)
        if int(n_lost) > 0:
            # In-flight updates killed by churn are a modeled phenomenon,
            # but losing them silently in a returned dict entry hid real
            # misconfigurations (e.g. a straggler tail longer than the
            # churn dwell time starves every flush). Explicit warning:
            # through the tracker when one is attached, else a plain
            # warnings.warn.
            self._warn(
                "lost_inflight",
                f"{int(n_lost)} in-flight update(s) never reported "
                f"(client churned out mid-flight) across {n_d} "
                f"dispatches — check churn rates vs straggler tail",
                lost_inflight=int(n_lost),
                num_dispatches=n_d,
            )
        finalize_history(history)
        if self.tap is not None:
            self.tap.tracker.log_summary(
                {**self.tap.const, **summary_metrics(history)}
            )
        return history

    def _warn(self, kind: str, message: str, **data) -> None:
        """Engine-health warning: tracker event when one is attached
        (so streamed logs carry it), plain ``warnings.warn`` fallback."""
        if self.tap is not None:
            self.tap.tracker.log(
                {"event": "warning", "kind": kind, "message": message,
                 **self.tap.const, **data}
            )
        else:
            warnings.warn(f"[async engine] {message}", RuntimeWarning,
                          stacklevel=3)


def _smoke(argv=None) -> None:
    """CLI smoke: a short virtual-horizon async run (used by scripts/ci.sh)."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--horizon-ms", type=float, default=2000.0)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--buffer-k", type=int, default=4)
    ap.add_argument("--interval-ms", type=float, default=250.0)
    args = ap.parse_args(argv)

    sim = AsyncFedFogSimulator(
        SimulatorConfig(
            task="emnist", num_clients=args.clients, rounds=64, top_k=8,
            hidden=(32,), seed=0,
        ),
        AsyncConfig.fedbuff(
            args.buffer_k,
            dispatch_interval_ms=args.interval_ms,
            horizon_ms=args.horizon_ms,
            straggler_sigma=0.3,
            churn=ChurnConfig(arrival_rate=0.05, departure_rate=0.05),
        ),
    )
    h = sim.run()
    print(
        f"async smoke: horizon={args.horizon_ms:.0f}ms "
        f"dispatches={h['num_dispatches']} flushes={h['num_flushes']} "
        f"completions={h['num_completions']} lost={h['lost_inflight']} "
        f"final_acc={h['final_accuracy']:.3f} "
        f"virtual_t={h['virtual_time_ms']:.0f}ms"
    )
    assert h["num_flushes"] > 0 and h["num_dispatches"] > 0


if __name__ == "__main__":
    _smoke()
