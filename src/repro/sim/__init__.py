"""Simulation stack: shared DES cost model, FaaS façade, sweep subsystem.

Layering (see each module's docstring):

    des.py   — ``RoundCostModel``: the single §IV.F latency/energy/cold-
               start model consumed by BOTH engines (paper-scale simulator
               and pod-scale ``make_round_fn``).
    faas.py  — legacy function-style façade over the cost model.
    sweep.py — ``run_sweep``: vmap-over-seeds / grid-over-configs driver
               for the scan-compiled simulator engine and the event-driven
               async engine (``engine="async"``).
    events/  — event-driven asynchronous FL engine (virtual-clock queue,
               staleness-aware buffered aggregation, churn). Imported as
               ``repro.sim.events`` — intentionally NOT re-exported here,
               because its engine imports ``repro.fl.simulator`` which in
               turn imports ``repro.sim.des`` (import-cycle hygiene).
"""
from repro.sim.des import FaasSimConfig, RoundCostModel, RoundCosts
from repro.sim.faas import round_energy_j, round_times_ms
from repro.sim.sweep import (
    SweepResult,
    clear_compile_cache,
    compile_cache_size,
    run_sweep,
)

__all__ = [
    "FaasSimConfig",
    "RoundCostModel",
    "RoundCosts",
    "round_energy_j",
    "round_times_ms",
    "SweepResult",
    "clear_compile_cache",
    "compile_cache_size",
    "run_sweep",
]
