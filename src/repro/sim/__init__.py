"""Simulation stack: shared DES cost model, FaaS façade, sweep subsystem.

Layering (see each module's docstring):

    des.py   — ``RoundCostModel``: the single §IV.F latency/energy/cold-
               start model consumed by BOTH engines (paper-scale simulator
               and pod-scale ``make_round_fn``).
    faas.py  — legacy function-style façade over the cost model.
    sweep.py — ``run_sweep``: vmap-over-seeds / grid-over-configs driver
               for the scan-compiled simulator engine.
"""
from repro.sim.des import FaasSimConfig, RoundCostModel, RoundCosts
from repro.sim.faas import round_energy_j, round_times_ms
from repro.sim.sweep import SweepResult, run_sweep

__all__ = [
    "FaasSimConfig",
    "RoundCostModel",
    "RoundCosts",
    "round_energy_j",
    "round_times_ms",
    "SweepResult",
    "run_sweep",
]
