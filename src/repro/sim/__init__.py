from repro.sim.faas import FaasSimConfig, round_energy_j, round_times_ms

__all__ = ["FaasSimConfig", "round_energy_j", "round_times_ms"]
