"""Vmapped sweep subsystem: seed batches × config grids as XLA programs.

The paper's headline tables are all multi-seed, multi-config sweeps. The
seed repo ran them as nested Python loops — one jit dispatch per round per
seed per config, with a host sync per metric. This module runs them
sweep-natively:

  * **seeds** are vmapped: ``FedFogSimulator.init_state`` is traceable
    over the seed, so an S-seed × R-round experiment compiles ONCE and
    executes as a single XLA program (vmap over seeds of the scan-compiled
    engine — ``lax.scan`` over rounds inside).
  * **configs** (grid ``axes`` or explicit ``cases``) change trace
    structure (policies branch in Python, client counts change shapes),
    so each grid point is its own compiled program — still one program
    per grid point instead of S × R dispatches.

Typical use::

    from repro.sim import run_sweep
    res = run_sweep(
        SimulatorConfig(num_clients=64, rounds=50),
        seeds=range(8),
        axes={"policy": ["fedfog", "rcs"], "top_k": [8, 16, 24]},
    )
    mean, ci = res.mean_ci("accuracy")      # (G, R) curves
    finals = res.final("accuracy")          # (G, S)
    stats = res.stats(0)                    # per-seed run() summary dict

``history`` arrays are shaped ``(G, S, R)`` — grid point × seed × round.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.simulator import FedFogSimulator, SimulatorConfig


def _grid(
    axes: Mapping[str, Sequence[Any]] | None,
    cases: Sequence[Mapping[str, Any]] | None,
) -> list[dict[str, Any]]:
    """Grid points as config-override dicts.

    ``cases`` (an explicit list of override dicts) wins over ``axes``
    (a cartesian product of per-field value lists). Both empty → one
    unmodified grid point.
    """
    if cases:
        return [dict(c) for c in cases]
    if not axes:
        return [{}]
    names = list(axes)
    return [
        dict(zip(names, combo))
        for combo in itertools.product(*(axes[n] for n in names))
    ]


@dataclasses.dataclass
class SweepResult:
    """Stacked histories of a config-grid × seed-batch sweep."""

    configs: list[dict[str, Any]]  # G override dicts (grid points)
    seeds: np.ndarray  # (S,)
    rounds: int
    history: dict[str, np.ndarray]  # each (G, S, R)

    # -- raw access ---------------------------------------------------- #
    def metric(self, name: str) -> np.ndarray:
        """(G, S, R) round-by-round history of one metric."""
        return self.history[name]

    def final(self, name: str) -> np.ndarray:
        """(G, S) last-round value of a metric.

        Async-engine histories are padded to a static flush capacity and
        carry a ``valid`` 0/1 channel; when present, "last" means the
        last *valid* flush per run, not the padded tail.
        """
        h = self.history[name]
        if "valid" in self.history:
            v = self.history["valid"] > 0
            idx = np.where(
                v.any(axis=-1),
                v.shape[-1] - 1 - np.argmax(v[..., ::-1], axis=-1),
                0,
            )
            return np.take_along_axis(h, idx[..., None], axis=-1)[..., 0]
        return h[..., -1]

    # -- reductions ---------------------------------------------------- #
    def mean_ci(self, name: str, z: float = 1.96) -> tuple[np.ndarray, np.ndarray]:
        """Across-seed mean and z·SEM half-width, each (G, R).

        SEM uses the sample std (ddof=1); with a single seed there is no
        uncertainty estimate and the half-width is NaN rather than a
        misleading ±0.

        Only meaningful for round-aligned (sync-engine) histories: async
        flush histories are padded and per-seed flush times differ, so
        reduce those with ``final()`` / the ``valid`` mask instead.
        """
        h = self.history[name]
        mean = h.mean(axis=1)
        s = h.shape[1]
        if s < 2:
            return mean, np.full_like(mean, np.nan)
        sem = h.std(axis=1, ddof=1) / np.sqrt(s)
        return mean, z * sem

    def mean_std(self, name: str, reduce: str = "final") -> tuple[np.ndarray, np.ndarray]:
        """Across-seed mean/std of a per-run scalar, each (G,).

        ``reduce``: 'final' (last round), 'sum', 'mean', or 'max' over
        the round axis.
        """
        h = self.history[name]
        per_run = {
            "final": h[..., -1],
            "sum": h.sum(axis=-1),
            "mean": h.mean(axis=-1),
            "max": h.max(axis=-1),
        }[reduce]
        return per_run.mean(axis=1), per_run.std(axis=1)

    def stats(self, g: int = 0) -> dict[str, np.ndarray]:
        """Per-seed summary of grid point ``g`` — the same derived fields
        ``FedFogSimulator.run()`` appends, each shaped (S,)."""
        h = {k: v[g] for k, v in self.history.items()}
        return {
            "final_accuracy": self.final("accuracy")[g],
            "peak_accuracy": h["accuracy"].max(axis=-1),
            "total_energy_j": h["energy_j"].sum(axis=-1),
            "mean_latency_ms": h["round_latency_ms"].mean(axis=-1),
            "total_cold_starts": h["cold_starts"].sum(axis=-1),
        }


def run_sweep(
    cfg: SimulatorConfig,
    seeds: Iterable[int],
    axes: Mapping[str, Sequence[Any]] | None = None,
    cases: Sequence[Mapping[str, Any]] | None = None,
    rounds: int | None = None,
    devices: int | Sequence[Any] | None = None,
    engine: str = "scan",
    async_cfg: Any | None = None,
) -> SweepResult:
    """Run a (config grid) × (seed batch) × (rounds) sweep.

    Per grid point: one jit compile; all seeds execute inside it as a
    ``vmap`` over functional ``init_state(seed)`` + the scan-compiled
    round loop, with a single device→host transfer of the stacked
    ``(S, R)`` metric histories. Seed s of any grid point reproduces
    ``FedFogSimulator(replace(cfg, seed=s)).run_scanned()`` exactly.

    Args:
      cfg: base configuration; ``cfg.seed`` is ignored in favor of
        ``seeds``.
      seeds: the seed batch (vmapped axis).
      axes: cartesian-product grid, e.g. ``{"policy": [...], "top_k": [...]}``.
      cases: explicit list of override dicts (non-product grids); wins
        over ``axes``. With ``engine="async"``, override keys naming
        ``AsyncConfig`` fields (e.g. ``buffer_k``, ``dispatch_mode``)
        are routed to the async config instead of ``SimulatorConfig``.
      rounds: override ``cfg.rounds`` (for ``engine="async"``: the
        dispatch budget, ``AsyncConfig.max_dispatches``).
      devices: shard the vmapped seed batch across local devices — an int
        (first N of ``jax.devices()``) or an explicit device sequence.
        Each device then runs |seeds|/N independent simulations of every
        grid point in parallel (seeds are padded to a multiple of N and
        the pad rows dropped). Per-seed results are unchanged. None/0/1
        keeps the single-device layout.
      engine: ``"scan"`` (synchronous scan-compiled rounds) or
        ``"async"`` (event-driven ``AsyncFedFogSimulator``; histories are
        then per-*flush* arrays padded to the engine's static flush
        capacity, with a ``valid`` 0/1 channel marking real entries).
      async_cfg: base ``AsyncConfig`` for ``engine="async"``.

    Returns:
      SweepResult with ``(G, S, R)`` histories.
    """
    rounds_arg = rounds
    rounds = int(rounds or cfg.rounds)
    seeds_arr = jnp.asarray(list(seeds), jnp.int32)
    if seeds_arr.ndim != 1 or seeds_arr.shape[0] == 0:
        raise ValueError("seeds must be a non-empty 1-D collection of ints")
    if engine not in ("scan", "async"):
        raise ValueError(f"unknown engine {engine!r}")
    grid = _grid(axes, cases)

    n_seeds = int(seeds_arr.shape[0])
    seed_sharding = None
    seeds_in = seeds_arr
    if devices:
        devs = (
            list(jax.devices())[: int(devices)]
            if isinstance(devices, int)
            else list(devices)
        )
        if len(devs) > 1:
            mesh = jax.sharding.Mesh(np.asarray(devs), ("seed",))
            seed_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("seed")
            )
            pad = (-n_seeds) % len(devs)
            if pad:  # cycle seeds to a full multiple; pad rows dropped below
                seeds_in = jnp.resize(seeds_arr, (n_seeds + pad,))

    stacked_per_g = []
    for overrides in grid:
        if engine == "async":
            # Lazy import: events.engine imports repro.fl.simulator, which
            # itself imports repro.sim.des — keep that cycle out of the
            # repro.sim package import.
            from repro.sim.events.engine import AsyncConfig, AsyncFedFogSimulator

            a_fields = {f.name for f in dataclasses.fields(AsyncConfig)}
            sim_ov = {k: v for k, v in overrides.items() if k not in a_fields}
            a_ov = {k: v for k, v in overrides.items() if k in a_fields}
            # Dispatch budget precedence: explicit rounds= argument, else
            # the async_cfg's own max_dispatches, else cfg.rounds.
            base_a = async_cfg or AsyncConfig()
            budget = (
                int(rounds_arg) if rounds_arg
                else int(base_a.max_dispatches or cfg.rounds)
            )
            asim = AsyncFedFogSimulator(
                dataclasses.replace(cfg, **sim_ov),
                dataclasses.replace(
                    base_a, **{"max_dispatches": budget, **a_ov}
                ),
            )
            fn = jax.vmap(asim.metrics_for_seed)
        else:
            # defer_state: per-seed state is built inside the compiled
            # program, so the eager default-seed init would be dead work.
            sim = FedFogSimulator(
                dataclasses.replace(cfg, **overrides), defer_state=True
            )

            def per_seed(seed, sim=sim):
                env, params, sched, tel = sim.init_state(seed)
                key = jax.random.PRNGKey(seed + 100)
                _, _, _, stacked = sim._scan_rounds(
                    env, params, sched, tel, key, rounds=rounds
                )
                return stacked

            fn = jax.vmap(per_seed)
        jitted = (
            jax.jit(fn, in_shardings=(seed_sharding,))
            if seed_sharding is not None
            else jax.jit(fn)
        )
        stacked = jitted(seeds_in)
        if seeds_in.shape[0] != n_seeds:
            stacked = jax.tree.map(lambda x: x[:n_seeds], stacked)
        stacked_per_g.append(jax.device_get(stacked))  # one transfer / point

    if engine == "async":
        # Surface queue overflow the same way AsyncFedFogSimulator.run()
        # does — silent drops would corrupt the flush histories.
        for overrides, h in zip(grid, stacked_per_g):
            dropped = np.asarray(h.pop("queue_dropped"))
            if dropped.any():
                raise RuntimeError(
                    f"async event queue overflowed for grid point "
                    f"{overrides} (max {int(dropped.max())} dropped); "
                    f"raise AsyncConfig.queue_capacity"
                )

    history = {
        name: np.stack([np.asarray(h[name], np.float64) for h in stacked_per_g])
        for name in stacked_per_g[0]
    }
    return SweepResult(
        configs=grid,
        seeds=np.asarray(seeds_arr),
        rounds=rounds,
        history=history,
    )
