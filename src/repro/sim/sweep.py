"""Vmapped sweep subsystem: compile-once config grids × seed batches.

The paper's headline tables are all multi-seed, multi-config sweeps. The
seed repo ran them as nested Python loops — one jit dispatch per round per
seed per config, with a host sync per metric. This module runs them
sweep-natively, and — the part that actually pays on a benchmark box,
where XLA compilation dominates a quick-scale run — it compiles each
sweep **once per structural signature**, not once per grid point:

  * **seeds** are vmapped: ``FedFogSimulator.init_state`` is traceable
    over the seed, so an S-seed × R-round experiment executes as a single
    XLA program (vmap over seeds of the scan-compiled engine).
  * **configs** are split into *structural* fields (task, policy, client
    count, shapes, flags — they change the trace) and *numeric* fields
    (lrs, thresholds, ``top_k``, staleness exponents, straggler sigma,
    churn rates — pure data). Grid points sharing a structural signature
    are grouped; their numeric overrides are stacked into an "env array"
    pytree and the whole group runs as ONE compiled program vmapped over
    ``(G_numeric, S)``. A process-wide compile cache keyed on the
    structural signature means repeated sweeps (benchmark suites, CI)
    reuse compiled executables outright — and with
    ``REPRO_COMPILE_CACHE_DIR`` set, serialized executables persist on
    disk so a SECOND process running the same sweep warm-starts with
    zero traces and zero compiles (``n_compiles=0``).

Branch-gating numeric fields (``dp_sigma``, ``straggler_sigma``,
``top_k``/``buffer_k`` None-ness) are only lifted to data when their gate
is active, and the gate's truthiness is part of the structural signature
— so a group never mixes points that would trace different programs (see
``repro.core.types.static_on``).

Typical use::

    from repro.sim import run_sweep
    res = run_sweep(
        SimulatorConfig(num_clients=64, rounds=50),
        seeds=range(8),
        axes={"policy": ["fedfog", "rcs"], "lr": [0.01, 0.05, 0.1]},
    )  # 6 grid points, TWO compiles (one per policy), lr vmapped as data
    mean, ci = res.mean_ci("accuracy")      # (G, R) curves
    finals = res.final("accuracy")          # (G, S)
    stats = res.stats(0)                    # per-seed run() summary dict

``history`` arrays are shaped ``(G, S, R)`` — grid point × seed × round.
``group=False`` restores one-compile-per-grid-point execution — the
oracle the grouped path is tested bit-for-bit against.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import pickle
import time
from typing import Any, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import SchedulerConfig
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim.faults import config as faults_config


def _grid(
    axes: Mapping[str, Sequence[Any]] | None,
    cases: Sequence[Mapping[str, Any]] | None,
) -> list[dict[str, Any]]:
    """Grid points as config-override dicts.

    ``cases`` (an explicit list of override dicts) wins over ``axes``
    (a cartesian product of per-field value lists). Both empty → one
    unmodified grid point.
    """
    if cases:
        return [dict(c) for c in cases]
    if not axes:
        return [{}]
    names = list(axes)
    return [
        dict(zip(names, combo))
        for combo in itertools.product(*(axes[n] for n in names))
    ]


# --------------------------------------------------------------------- #
# structural / numeric config factoring
# --------------------------------------------------------------------- #
# Scalar config fields that are pure data inside the trace. Fields whose
# zero/None value gates a Python branch are conditionally liftable: they
# become data only when the gate is active (see _liftable), so a lifted
# tracer never reaches a `bool()` (static_on handles the active case).
_SIM_NUMERIC = (
    "lr", "server_lr", "top_k", "dp_sigma",
    "attack_noise_scale", "attack_replacement_scale",
    # trim_fraction rides the delta-pipeline kernel as traced data (the
    # (1, 2) [num_sel, k_trim] input), so sweeping it never recompiles.
    # `aggregator` and `use_pallas_agg` stay OUT of this tuple on
    # purpose: they pick the kernel / selection-network structure and
    # must remain part of the structural compile-cache signature.
    "trim_fraction",
)
_SCHED_NUMERIC = ("theta_h", "theta_e", "theta_d")
_ASYNC_NUMERIC = (
    "staleness_exponent", "dispatch_interval_ms", "straggler_sigma",
    "buffer_k", "horizon_ms",
)
_INT_NUMERIC = frozenset({"top_k", "buffer_k"})
_GATED_POSITIVE = frozenset({"dp_sigma", "straggler_sigma"})
# Placeholder written into the structural remainder for lifted fields —
# never reaches a trace (the stacked env array supplies the real value);
# it only makes "lifted" distinct from any concrete value in the
# structural signature.
_LIFTED = "<lifted>"


def _liftable(name: str, value: Any) -> bool:
    if value is None or isinstance(value, bool):
        return False  # None-ness / flags are structural
    if not isinstance(value, (int, float)):
        return False
    if name in _GATED_POSITIVE and value <= 0:
        return False  # gate off → the branch compiles out; keep concrete
    return True


def _factor_sim(cfg: SimulatorConfig):
    """Split a full config into (structural remainder, numeric data).

    Numeric keys are flat field names plus dotted ``scheduler.<field>``
    entries for the Eq. 3 thresholds. The remainder is hashable and equal
    for any two configs that differ only in lifted numeric values — it IS
    the compile-cache signature contribution of this config.
    """
    num: dict[str, float] = {}
    repl: dict[str, Any] = {}
    for f in _SIM_NUMERIC:
        v = getattr(cfg, f)
        if _liftable(f, v):
            num[f] = v
            repl[f] = _LIFTED
    sched = cfg.scheduler
    for f in _SCHED_NUMERIC:
        num[f"scheduler.{f}"] = float(getattr(sched, f))
    repl["scheduler"] = dataclasses.replace(
        sched, **{f: _LIFTED for f in _SCHED_NUMERIC}
    )
    fc = cfg.faults
    if fc is not None and faults_config.active(fc):
        # Only an ACTIVE fault layer lifts: the composite gate itself is
        # structural (a faults-off point keeps its verbatim program), but
        # once the gate is on every rate/scale — including exact zeros —
        # is pure data, so a fault-rate grid shares one program.
        fc_repl: dict[str, Any] = {}
        for f in faults_config.RATE_FIELDS + faults_config.SCALE_FIELDS:
            v = getattr(fc, f)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                num[f"faults.{f}"] = float(v)
                fc_repl[f] = _LIFTED
        d = fc.deadline_ms
        if d is not None and isinstance(d, (int, float)):
            num["faults.deadline_ms"] = float(d)  # None-ness is structural
            fc_repl["deadline_ms"] = _LIFTED
        repl["faults"] = dataclasses.replace(fc, **fc_repl)
    return dataclasses.replace(cfg, **repl), num


def _factor_async(acfg):
    num: dict[str, float] = {}
    repl: dict[str, Any] = {}
    for f in _ASYNC_NUMERIC:
        v = getattr(acfg, f)
        if _liftable(f, v):
            num[f"async.{f}"] = v
            repl[f] = _LIFTED
    churn = acfg.churn
    ch_repl = {}
    for f in ("arrival_rate", "departure_rate", "death_batt"):
        v = getattr(churn, f)
        # zero churn rates take the identity shortcut — structural
        if f != "death_batt" and v == 0.0:
            continue
        if _liftable(f, v):
            num[f"churn.{f}"] = v
            ch_repl[f] = _LIFTED
    if ch_repl:
        repl["churn"] = dataclasses.replace(churn, **ch_repl)
    return dataclasses.replace(acfg, **repl), num


def _apply_numeric(cfg: SimulatorConfig, num: Mapping[str, Any]) -> SimulatorConfig:
    """Re-inject (possibly traced) numeric values into a structural cfg."""
    plain = {k: v for k, v in num.items() if "." not in k}
    sched_over = {
        k.split(".", 1)[1]: v for k, v in num.items()
        if k.startswith("scheduler.")
    }
    if sched_over:
        plain["scheduler"] = dataclasses.replace(cfg.scheduler, **sched_over)
    faults_over = {
        k.split(".", 1)[1]: v for k, v in num.items()
        if k.startswith("faults.")
    }
    if faults_over:
        plain["faults"] = dataclasses.replace(cfg.faults, **faults_over)
    return dataclasses.replace(cfg, **plain)


def _apply_async_numeric(acfg, num: Mapping[str, Any]):
    plain = {
        k.split(".", 1)[1]: v for k, v in num.items()
        if k.startswith("async.")
    }
    churn_over = {
        k.split(".", 1)[1]: v for k, v in num.items()
        if k.startswith("churn.")
    }
    if churn_over:
        plain["churn"] = dataclasses.replace(acfg.churn, **churn_over)
    return dataclasses.replace(acfg, **plain) if plain else acfg


def _stack_numeric(points: Sequence[Mapping[str, Any]]) -> dict[str, jax.Array]:
    """Stack per-point numeric dicts (same key set) into (Gn,) arrays."""
    if not points:
        return {}
    names = points[0].keys()
    out = {}
    for name in names:
        leaf = name.rsplit(".", 1)[-1]
        dtype = jnp.int32 if leaf in _INT_NUMERIC else jnp.float32
        out[name] = jnp.asarray([p[name] for p in points], dtype)
    return out


# --------------------------------------------------------------------- #
# compile cache
# --------------------------------------------------------------------- #
# structural signature (+ array shapes) -> AOT-compiled executable. The
# contract: two grid points map to the same entry iff their structural
# remainders (hash of every non-lifted field, including gate truthiness
# of conditionally-lifted ones), numeric key sets, round counts, engines,
# and batch shapes all agree — in which case replaying the cached
# executable on their stacked numeric data is exact. Bounded FIFO so a
# long-lived process sweeping many signatures cannot accumulate compiled
# executables (and the memory their buffers pin) without limit.
_PROGRAM_CACHE: dict[Any, Any] = {}
_PROGRAM_CACHE_MAX = 64

# ------------------------------------------------------------------ #
# persistent warm-start cache (second-process reuse)
# ------------------------------------------------------------------ #
# The in-process cache above dies with the process — yet on a quick-
# scale CPU box trace+compile dominate a cold run (BENCH_simulator.json:
# the async engine pays ~32s trace+compile for ~3s of execute). With
# ``REPRO_COMPILE_CACHE_DIR`` set, every freshly compiled sweep
# executable is ALSO serialized to disk (``jax.experimental.
# serialize_executable``) keyed on a stable hash of the structural
# signature; a later process running the same sweep deserializes it and
# skips BOTH tracing and XLA compilation (``n_compiles=0``,
# ``events_per_sec_wall`` → ``events_per_sec_exec``). Replaying a
# deserialized executable on new numeric data is exact — it is the same
# compiled program the first process ran.
#
# Keys are content-hashes of the in-process cache key (frozen-dataclass
# reprs are deterministic) plus the jax version, backend and device
# count — a mismatch in any of those lands on a different file. Loads
# that fail for ANY reason (version skew, corrupt/truncated file) fall
# back to a fresh compile that overwrites the entry.
_DISK_CACHE_ENV = "REPRO_COMPILE_CACHE_DIR"
_DISK_CACHE_VERSION = 1
_XLA_CACHE_ENABLED = False


def _disk_cache_dir() -> str | None:
    return os.environ.get(_DISK_CACHE_ENV) or None


def _maybe_enable_xla_cache(path: str) -> None:
    """Opportunistically point jax's own persistent compilation cache at
    the same directory — it cannot skip tracing like the executable
    serialization below, but it warms every OTHER jit in the process
    (per-round loops, benchmark harness jits) where supported."""
    global _XLA_CACHE_ENABLED
    if _XLA_CACHE_ENABLED:
        return
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        _XLA_CACHE_ENABLED = True
    except Exception:  # unsupported backend/version: purely best-effort
        _XLA_CACHE_ENABLED = True  # don't retry every call


def disable_xla_cache() -> None:
    """Undo ``_maybe_enable_xla_cache`` — for callers (the benchmark
    harness) that pointed the cache at a temp directory they are about
    to delete and must not leak the global config to later workloads."""
    global _XLA_CACHE_ENABLED
    try:
        jax.config.update("jax_compilation_cache_dir", None)
    except Exception:
        pass
    _XLA_CACHE_ENABLED = False


def _disk_cache_path(cache_key) -> str | None:
    base = _disk_cache_dir()
    if base is None:
        return None
    tag = repr((
        _DISK_CACHE_VERSION, cache_key, jax.__version__,
        jax.default_backend(), jax.device_count(),
    ))
    h = hashlib.sha256(tag.encode()).hexdigest()[:32]
    return os.path.join(base, f"sweep-{h}.jaxexe")


def _disk_load(path: str):
    """Deserialize a cached executable; None on any failure."""
    from jax.experimental.serialize_executable import deserialize_and_load

    try:
        with open(path, "rb") as f:
            payload, in_tree, out_tree = pickle.load(f)
        return deserialize_and_load(payload, in_tree, out_tree)
    except Exception:
        return None


def _disk_store(path: str, compiled) -> None:
    """Serialize an executable to ``path`` (atomic rename; best-effort)."""
    from jax.experimental.serialize_executable import serialize

    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload, in_tree, out_tree = serialize(compiled)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump((payload, in_tree, out_tree), f)
        os.replace(tmp, path)
    except Exception:
        pass  # disk cache is an optimization, never a failure mode


def clear_compile_cache() -> None:
    """Drop all cached sweep executables (mostly for tests).

    Only clears the in-process cache; the on-disk warm-start cache (if
    ``REPRO_COMPILE_CACHE_DIR`` is set) survives — delete the directory
    to invalidate it."""
    _PROGRAM_CACHE.clear()


def compile_cache_size() -> int:
    return len(_PROGRAM_CACHE)


def _cache_put(key, compiled) -> None:
    if len(_PROGRAM_CACHE) >= _PROGRAM_CACHE_MAX:
        _PROGRAM_CACHE.pop(next(iter(_PROGRAM_CACHE)))  # evict oldest
    _PROGRAM_CACHE[key] = compiled


def _scan_metrics(sim: FedFogSimulator, seed, rounds: int):
    """One seed's stacked metric histories on the scan-compiled engine —
    the per-point execution recipe shared VERBATIM by the grouped program
    and the ``group=False`` oracle (the two paths must only differ in
    whether numeric config fields are tracers or constants)."""
    env, params, sched, tel = sim.init_state(seed)
    key = jax.random.PRNGKey(seed + 100)
    _, _, _, stacked = sim._scan_rounds(
        env, params, sched, tel, key, rounds=rounds
    )
    return stacked


def _build_group_fn(struct_cfg, struct_acfg, num_names, rounds, engine):
    """The one compiled program of a structural group:
    ``(numeric env stack (Gn,), seeds (S,)) -> (Gn, S, R) histories``."""

    def one_point(num):
        cfg_p = _apply_numeric(struct_cfg, num)
        if engine == "async":
            from repro.sim.events.engine import AsyncFedFogSimulator

            asim = AsyncFedFogSimulator(
                cfg_p, _apply_async_numeric(struct_acfg, num)
            )
            return jax.vmap(asim.metrics_for_seed)

        sim = FedFogSimulator(cfg_p, defer_state=True)
        return jax.vmap(lambda s: _scan_metrics(sim, s, rounds))

    def group_fn(num_stack, seeds):
        if num_names:
            return jax.vmap(lambda num: one_point(num)(seeds))(num_stack)
        # No numeric data: every point in the group is the identical
        # config, so run it once with a (Gn=1,) axis — the host side
        # replays the single row for each member. (Unreachable while
        # _factor_sim lifts the scheduler thetas unconditionally, but
        # kept correct in case that ever becomes conditional.)
        return jax.tree.map(lambda x: x[None], one_point({})(seeds))

    return group_fn


@dataclasses.dataclass
class SweepResult:
    """Stacked histories of a config-grid × seed-batch sweep."""

    configs: list[dict[str, Any]]  # G override dicts (grid points)
    seeds: np.ndarray  # (S,)
    rounds: int
    history: dict[str, np.ndarray]  # each (G, S, R)

    # -- raw access ---------------------------------------------------- #
    def metric(self, name: str) -> np.ndarray:
        """(G, S, R) round-by-round history of one metric."""
        return self.history[name]

    def final(self, name: str) -> np.ndarray:
        """(G, S) last-round value of a metric.

        Async-engine histories are padded to a static flush capacity and
        carry a ``valid`` 0/1 channel; when present, "last" means the
        last *valid* flush per run, not the padded tail.
        """
        h = self.history[name]
        if "valid" in self.history:
            v = self.history["valid"] > 0
            idx = np.where(
                v.any(axis=-1),
                v.shape[-1] - 1 - np.argmax(v[..., ::-1], axis=-1),
                0,
            )
            return np.take_along_axis(h, idx[..., None], axis=-1)[..., 0]
        return h[..., -1]

    # -- reductions ---------------------------------------------------- #
    def mean_ci(self, name: str, z: float = 1.96) -> tuple[np.ndarray, np.ndarray]:
        """Across-seed mean and z·SEM half-width, each (G, R).

        SEM uses the sample std (ddof=1); with a single seed there is no
        uncertainty estimate and the half-width is NaN rather than a
        misleading ±0.

        Only meaningful for round-aligned (sync-engine) histories: async
        flush histories are padded and per-seed flush times differ, so
        reduce those with ``final()`` / the ``valid`` mask instead.
        """
        h = self.history[name]
        mean = h.mean(axis=1)
        s = h.shape[1]
        if s < 2:
            return mean, np.full_like(mean, np.nan)
        sem = h.std(axis=1, ddof=1) / np.sqrt(s)
        return mean, z * sem

    def mean_std(self, name: str, reduce: str = "final") -> tuple[np.ndarray, np.ndarray]:
        """Across-seed mean/std of a per-run scalar, each (G,).

        ``reduce``: 'final' (last round), 'sum', 'mean', or 'max' over
        the round axis.
        """
        h = self.history[name]
        per_run = {
            "final": h[..., -1],
            "sum": h.sum(axis=-1),
            "mean": h.mean(axis=-1),
            "max": h.max(axis=-1),
        }[reduce]
        return per_run.mean(axis=1), per_run.std(axis=1)

    def stats(self, g: int = 0) -> dict[str, np.ndarray]:
        """Per-seed summary of grid point ``g`` — the same derived fields
        ``FedFogSimulator.run()`` appends, each shaped (S,)."""
        h = {k: v[g] for k, v in self.history.items()}
        return {
            "final_accuracy": self.final("accuracy")[g],
            "peak_accuracy": h["accuracy"].max(axis=-1),
            "total_energy_j": h["energy_j"].sum(axis=-1),
            "mean_latency_ms": h["round_latency_ms"].mean(axis=-1),
            "total_cold_starts": h["cold_starts"].sum(axis=-1),
        }


def run_sweep(
    cfg: SimulatorConfig,
    seeds: Iterable[int],
    axes: Mapping[str, Sequence[Any]] | None = None,
    cases: Sequence[Mapping[str, Any]] | None = None,
    rounds: int | None = None,
    devices: int | Sequence[Any] | None = None,
    engine: str = "scan",
    async_cfg: Any | None = None,
    group: bool = True,
    cache: bool = True,
    timings: dict | None = None,
    tracker: Any | None = None,
) -> SweepResult:
    """Run a (config grid) × (seed batch) × (rounds) sweep.

    Per structural group (``group=True``, the default): ONE jit compile;
    the group's numeric overrides are stacked into a ``(Gn,)`` env-array
    pytree and every (numeric point, seed) executes inside the compiled
    program as a ``vmap`` over ``(G_numeric, S)`` of functional
    ``init_state(seed)`` + the scan-compiled round loop, with a single
    device→host transfer of the stacked histories per group. Seed s of
    any grid point reproduces
    ``FedFogSimulator(replace(cfg, seed=s)).run_scanned()`` exactly.

    Args:
      cfg: base configuration; ``cfg.seed`` is ignored in favor of
        ``seeds``.
      seeds: the seed batch (vmapped axis).
      axes: cartesian-product grid, e.g. ``{"policy": [...], "top_k": [...]}``.
      cases: explicit list of override dicts (non-product grids); wins
        over ``axes``. With ``engine="async"``, override keys naming
        ``AsyncConfig`` fields (e.g. ``buffer_k``, ``dispatch_mode``)
        are routed to the async config instead of ``SimulatorConfig``.
      rounds: override ``cfg.rounds`` (for ``engine="async"``: the
        dispatch budget, ``AsyncConfig.max_dispatches``).
      devices: shard the vmapped seed batch across local devices — an int
        (first N of ``jax.devices()``) or an explicit device sequence.
        Each device then runs |seeds|/N independent simulations of every
        grid point in parallel (seeds are padded to a multiple of N and
        the pad rows dropped). Per-seed results are unchanged. None/0/1
        keeps the single-device layout.
      engine: ``"scan"`` (synchronous scan-compiled rounds) or
        ``"async"`` (event-driven ``AsyncFedFogSimulator``; histories are
        then per-*flush* arrays padded to the engine's static flush
        capacity, with a ``valid`` 0/1 channel marking real entries).
      async_cfg: base ``AsyncConfig`` for ``engine="async"``.
      group: group grid points by structural signature and compile once
        per group (numeric overrides become vmapped data). ``False``
        compiles every grid point separately — the bit-for-bit oracle.
      cache: reuse compiled executables across ``run_sweep`` calls via
        the process-wide structural-signature cache (grouped mode only).
        With the ``REPRO_COMPILE_CACHE_DIR`` environment variable set,
        fresh compiles are additionally serialized to that directory and
        later PROCESSES warm-start from it (deserializing skips trace
        and compile entirely; such loads count as ``cache_hits`` +
        ``disk_hits`` with ``n_compiles`` staying 0).
      timings: optional dict; if given, wall-clock attribution is
        accumulated into it — ``trace_s`` / ``compile_s`` / ``exec_s``
        (via the AOT ``jit(...).lower(...).compile()`` split) and
        ``load_s`` (disk-cache deserialization), plus ``n_compiles``,
        ``cache_hits``, ``disk_hits`` and ``n_groups``.
      tracker: optional ``repro.obs.Tracker``; each structural group
        logs one ``event="sweep_group"`` row with its per-group
        trace/compile/exec/load seconds and cache/disk-hit flags as it
        finishes (so a long sweep streams progress), and the sweep ends
        with a ``log_summary`` of the totals. The vmapped seed programs
        themselves stay tap-free (ordered io_callbacks cannot batch);
        this is host-side bookkeeping only and never affects the trace.

    Returns:
      SweepResult with ``(G, S, R)`` histories.
    """
    rounds_arg = rounds
    rounds = int(rounds or cfg.rounds)
    seeds_arr = jnp.asarray(list(seeds), jnp.int32)
    if seeds_arr.ndim != 1 or seeds_arr.shape[0] == 0:
        raise ValueError("seeds must be a non-empty 1-D collection of ints")
    if engine not in ("scan", "async"):
        raise ValueError(f"unknown engine {engine!r}")
    grid = _grid(axes, cases)
    if tracker is not None and timings is None:
        timings = {}  # local collection so the summary row has totals
    if timings is not None:
        for k in ("trace_s", "compile_s", "exec_s", "load_s"):
            timings.setdefault(k, 0.0)
        for k in ("n_compiles", "cache_hits", "disk_hits", "n_groups"):
            timings.setdefault(k, 0)
    if _disk_cache_dir() is not None:
        _maybe_enable_xla_cache(_disk_cache_dir())

    n_seeds = int(seeds_arr.shape[0])
    seed_sharding = None
    num_sharding = None
    seeds_in = seeds_arr
    devices_key: Any = None
    if devices:
        devs = (
            list(jax.devices())[: int(devices)]
            if isinstance(devices, int)
            else list(devices)
        )
        if len(devs) > 1:
            mesh = jax.sharding.Mesh(np.asarray(devs), ("seed",))
            seed_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("seed")
            )
            # numeric env arrays are replicated — every device runs every
            # grid point on its seed shard
            num_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            )
            devices_key = tuple(str(d) for d in devs)
            pad = (-n_seeds) % len(devs)
            if pad:  # cycle seeds to a full multiple; pad rows dropped below
                seeds_in = jnp.resize(seeds_arr, (n_seeds + pad,))

    # ---- canonicalize every grid point to a full (cfg, acfg) pair ----- #
    base_a = None
    a_fields: set[str] = set()
    if engine == "async":
        # Lazy import: events.engine imports repro.fl.simulator, which
        # itself imports repro.sim.des — keep that cycle out of the
        # repro.sim package import.
        from repro.sim.events.engine import AsyncConfig

        a_fields = {f.name for f in dataclasses.fields(AsyncConfig)}
        base_a = async_cfg or AsyncConfig()

    def canonical(overrides):
        sim_ov = {k: v for k, v in overrides.items() if k not in a_fields}
        cfg_i = dataclasses.replace(cfg, **sim_ov)
        if engine != "async":
            return cfg_i, None
        a_ov = {k: v for k, v in overrides.items() if k in a_fields}
        # Dispatch budget precedence: explicit rounds= argument, else
        # the async_cfg's own max_dispatches, else cfg.rounds.
        budget = (
            int(rounds_arg) if rounds_arg
            else int(base_a.max_dispatches or cfg.rounds)
        )
        return cfg_i, dataclasses.replace(
            base_a, **{"max_dispatches": budget, **a_ov}
        )

    stacked_per_g: list[Any] = [None] * len(grid)

    if group:
        # ---- group by structural signature, one compile per group ----- #
        groups: dict[Any, dict[str, Any]] = {}
        for g, overrides in enumerate(grid):
            cfg_i, acfg_i = canonical(overrides)
            struct_cfg, num = _factor_sim(cfg_i)
            struct_acfg = None
            if engine == "async":
                struct_acfg, a_num = _factor_async(acfg_i)
                num.update(a_num)
            sig = (
                struct_cfg, struct_acfg, tuple(sorted(num)), rounds, engine,
            )
            entry = groups.setdefault(
                sig, {"points": [], "members": [],
                      "struct": (struct_cfg, struct_acfg)}
            )
            entry["points"].append(num)
            entry["members"].append(g)

        for gi, (sig, entry) in enumerate(groups.items()):
            struct_cfg, struct_acfg = entry["struct"]
            num_names = sig[2]
            num_stack = _stack_numeric(entry["points"])
            shapes_key = tuple(
                (k, str(num_stack[k].dtype), num_stack[k].shape)
                for k in sorted(num_stack)
            )
            cache_key = (sig, shapes_key, int(seeds_in.shape[0]), devices_key)
            disk_path = _disk_cache_path(cache_key) if cache else None
            g_trace = g_compile = g_load = 0.0
            cache_hit = disk_hit = False
            compiled = _PROGRAM_CACHE.get(cache_key) if cache else None
            if compiled is not None:
                cache_hit = True
                if timings is not None:
                    timings["cache_hits"] += 1
            else:
                if disk_path is not None:
                    # Warm start: a previous PROCESS compiled this
                    # signature — deserializing skips trace AND compile.
                    t0 = time.perf_counter()
                    compiled = _disk_load(disk_path)
                    if compiled is not None:
                        g_load = time.perf_counter() - t0
                        cache_hit = disk_hit = True
                        if timings is not None:
                            timings["load_s"] += g_load
                            timings["cache_hits"] += 1
                            timings["disk_hits"] += 1
                        if cache:
                            _cache_put(cache_key, compiled)
            if compiled is None:
                fn = _build_group_fn(
                    struct_cfg, struct_acfg, num_names, rounds, engine
                )
                jitted = (
                    jax.jit(fn, in_shardings=(num_sharding, seed_sharding))
                    if seed_sharding is not None
                    else jax.jit(fn)
                )
                t0 = time.perf_counter()
                lowered = jitted.lower(num_stack, seeds_in)
                t1 = time.perf_counter()
                compiled = lowered.compile()
                t2 = time.perf_counter()
                g_trace, g_compile = t1 - t0, t2 - t1
                if timings is not None:
                    timings["trace_s"] += g_trace
                    timings["compile_s"] += g_compile
                    timings["n_compiles"] += 1
                if cache:
                    _cache_put(cache_key, compiled)
                if disk_path is not None:
                    _disk_store(disk_path, compiled)
            t0 = time.perf_counter()
            stacked = jax.block_until_ready(compiled(num_stack, seeds_in))
            g_exec = time.perf_counter() - t0
            if timings is not None:
                timings["exec_s"] += g_exec
            if tracker is not None:
                tracker.log(
                    {
                        "event": "sweep_group",
                        "engine": engine,
                        "n_members": len(entry["members"]),
                        "n_seeds": n_seeds,
                        "rounds": rounds,
                        "cache_hit": cache_hit,
                        "disk_hit": disk_hit,
                        "trace_s": g_trace,
                        "compile_s": g_compile,
                        "load_s": g_load,
                        "exec_s": g_exec,
                    },
                    step=gi,
                )
            if seeds_in.shape[0] != n_seeds:
                stacked = jax.tree.map(lambda x: x[:, :n_seeds], stacked)
            host = jax.device_get(stacked)  # one transfer / group
            for j, g in enumerate(entry["members"]):
                # an empty-numeric group computes one row for its
                # identical members (see _build_group_fn)
                idx = j if num_names else 0
                stacked_per_g[g] = {k: v[idx] for k, v in host.items()}
        if timings is not None:
            timings["n_groups"] += len(groups)
    else:
        # ---- oracle path: one compile per grid point ------------------ #
        # Deliberately constructs each simulator from the CONCRETE config
        # (no numeric lifting) — it is the reference execution strategy
        # the grouped path is tested bitwise against. The per-seed recipe
        # itself is the shared _scan_metrics, so only the
        # constants-vs-tracers distinction differs between the paths.
        for g, overrides in enumerate(grid):
            cfg_i, acfg_i = canonical(overrides)
            if engine == "async":
                from repro.sim.events.engine import AsyncFedFogSimulator

                asim = AsyncFedFogSimulator(cfg_i, acfg_i)
                fn = jax.vmap(asim.metrics_for_seed)
            else:
                # defer_state: per-seed state is built inside the compiled
                # program, so the eager default-seed init would be dead
                # work.
                sim = FedFogSimulator(cfg_i, defer_state=True)
                fn = jax.vmap(
                    lambda seed, sim=sim: _scan_metrics(sim, seed, rounds)
                )
            jitted = (
                jax.jit(fn, in_shardings=(seed_sharding,))
                if seed_sharding is not None
                else jax.jit(fn)
            )
            t0 = time.perf_counter()
            stacked = jax.block_until_ready(jitted(seeds_in))
            if tracker is not None:
                tracker.log(
                    {
                        "event": "sweep_point",
                        "engine": engine,
                        "overrides": repr(overrides),
                        "n_seeds": n_seeds,
                        "rounds": rounds,
                        "wall_s": time.perf_counter() - t0,
                    },
                    step=g,
                )
            if seeds_in.shape[0] != n_seeds:
                stacked = jax.tree.map(lambda x: x[:n_seeds], stacked)
            stacked_per_g[g] = jax.device_get(stacked)  # one transfer / point

    if engine == "async":
        # Surface queue overflow the same way AsyncFedFogSimulator.run()
        # does — silent drops would corrupt the flush histories. The
        # channel stays IN the history (alongside lost_inflight and the
        # fault counters) so engine health is a first-class sweep output.
        for overrides, h in zip(grid, stacked_per_g):
            dropped = np.asarray(h["queue_dropped"])
            if dropped.any():
                raise RuntimeError(
                    f"async event queue overflowed for grid point "
                    f"{overrides} (max {int(dropped.max())} dropped); "
                    f"raise AsyncConfig.queue_capacity"
                )

    history = {
        name: np.stack([np.asarray(h[name], np.float64) for h in stacked_per_g])
        for name in stacked_per_g[0]
    }
    if tracker is not None:
        tracker.log_summary(
            {
                "event": "sweep",
                "engine": engine,
                "n_points": len(grid),
                "n_seeds": n_seeds,
                "rounds": rounds,
                "grouped": group,
                **{k: v for k, v in (timings or {}).items()},
            }
        )
    return SweepResult(
        configs=grid,
        seeds=np.asarray(seeds_arr),
        rounds=rounds,
        history=history,
    )
