"""seamless-m4t-medium — encoder-decoder multimodal backbone.
[arXiv:2308.11596; hf]

12L encoder + 12L decoder, d_model=1024 16H (kv=16, head_dim 64)
d_ff=4096 vocab=256206. The speech/text frontend is a STUB per the brief:
input_specs supplies precomputed frame embeddings (B, S_src, d_model).
long_500k skipped (enc-dec translation family; see DESIGN.md §5).
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "seamless-m4t-medium"
SKIP_SHAPES = {"long_500k": "enc-dec translation family (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.ENCDEC,
        num_layers=12,
        num_encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        embed_frontend_fraction=1.0,
    )
