"""qwen2.5-14b — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]

48L d_model=5120 40H (GQA kv=8, head_dim 128) d_ff=13824 vocab=152064.
Full causal attention (no windowing) -> long_500k cell is skipped.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "qwen2.5-14b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.DENSE,
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta_global=1_000_000.0,
    )
