"""llama3.2-1b — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]

16L d_model=2048 32H (GQA kv=8, head_dim 64) d_ff=8192 vocab=128256.
Tied embeddings (as released). Full attention -> long_500k skipped.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "llama3.2-1b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.DENSE,
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta_global=500_000.0,
    )
