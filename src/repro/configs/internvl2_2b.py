"""internvl2-2b — InternViT frontend + InternLM2 backbone.
[arXiv:2404.16821; hf]

24L d_model=2048 16H (GQA kv=8, head_dim 128) d_ff=8192 vocab=92553.
The ViT frontend is a STUB per the brief: input_specs supplies precomputed
patch embeddings occupying 1/8 of each sequence. Full attention ->
long_500k skipped.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "internvl2-2b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.VLM,
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        embed_frontend_fraction=0.125,
        rope_theta_global=1_000_000.0,
    )
