"""Assigned input-shape set + abstract input-spec builders.

Every LM arch is paired with four cells:

    train_4k     seq_len=4096,   global_batch=256   (train_step)
    prefill_32k  seq_len=32768,  global_batch=32    (serve: prefill)
    decode_32k   seq_len=32768,  global_batch=128   (serve: 1 new token,
                                                     KV cache of seq_len)
    long_500k    seq_len=524288, global_batch=1     (long-context decode;
                                                     sub-quadratic archs only)

``input_specs`` returns ShapeDtypeStruct stand-ins only — weak-type
correct, shardable, zero allocation — exactly what ``jit(...).lower``
consumes in the dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models.config import Family, ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Source length for encoder-decoder decode cells (cross-attention KV).
ENCDEC_DECODE_SRC_LEN = 4_096


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def vlm_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(img_len, text_len) for VLM cells — frontend stub supplies img_len
    precomputed patch embeddings."""
    frac = cfg.embed_frontend_fraction or 0.125
    img = max(int(seq_len * frac) // 8 * 8, 8)
    return img, seq_len - img


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract train/prefill batch for one (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    extra = 1 if shape.kind == "train" else 0  # +1 token for target shift
    if cfg.family is Family.ENCDEC:
        src = s // 2 if shape.kind == "train" else s
        tgt = s // 2 if shape.kind == "train" else s
        if shape.kind == "prefill":
            # prefill cell: encoder consumes the full 32k source; a short
            # target prefix is teacher-forced into the self-cache.
            tgt = 128
        return {
            "frames": _sds((b, src, cfg.d_model), cfg.compute_dtype),
            "tokens": _sds((b, tgt + extra), jnp.int32),
        }
    if cfg.family is Family.VLM:
        img, text = vlm_split(cfg, s)
        return {
            "tokens": _sds((b, text + extra), jnp.int32),
            "patch_embeds": _sds((b, img, cfg.d_model), cfg.compute_dtype),
        }
    return {"tokens": _sds((b, s + extra), jnp.int32)}


def cache_specs(model: Model, shape: ShapeSpec) -> dict:
    """Abstract KV/SSM cache for decode cells (via eval_shape: no alloc)."""
    cfg = model.cfg
    kw = {}
    if cfg.family is Family.ENCDEC:
        kw["src_len"] = ENCDEC_DECODE_SRC_LEN
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, **kw)
    )


def decode_token_specs(shape: ShapeSpec) -> jax.ShapeDtypeStruct:
    return _sds((shape.global_batch, 1), jnp.int32)


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, key) -> dict:
    """Materialize a real batch with the given spec (smoke tests/examples)."""
    specs = batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            key, sub = jax.random.split(key)
            out[k] = jax.random.randint(sub, v.shape, 0, cfg.vocab_size)
        else:
            key, sub = jax.random.split(key)
            out[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(v.dtype)
    return out
