"""hymba-1.5b — hybrid parallel attention + Mamba heads per layer.
[arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5, head_dim 64) d_ff=5504 vocab=32001,
ssm_state=16. Per the Hymba paper, full (global) attention only at the
first, middle and last layers; everything else uses a 1024-token sliding
window — with the constant-size SSM state this bounds the decode cache, so
long_500k runs. (Hymba's learnable meta tokens are omitted — noted in
DESIGN.md §5.)
"""
from repro.models.config import GLOBAL, Family, ModelConfig

ARCH_ID = "hymba-1.5b"
SKIP_SHAPES: dict[str, str] = {}

LOCAL_WINDOW = 1024
NUM_LAYERS = 32
_GLOBAL_LAYERS = (0, NUM_LAYERS // 2 - 1, NUM_LAYERS - 1)


def _pattern() -> tuple[int, ...]:
    return tuple(
        GLOBAL if i in _GLOBAL_LAYERS else LOCAL_WINDOW for i in range(NUM_LAYERS)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.HYBRID,
        num_layers=NUM_LAYERS,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        window_pattern=_pattern(),
        rope_theta_global=10_000.0,
    )
