"""mixtral-8x7b — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8, head_dim 128) per-expert d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096 on every layer — bounded decode
cache, so the long_500k cell runs.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "mixtral-8x7b"
SKIP_SHAPES: dict[str, str] = {}

SWA_WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.MOE,
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        experts_per_token=2,
        window_pattern=(SWA_WINDOW,),
        rope_theta_global=1_000_000.0,
    )
