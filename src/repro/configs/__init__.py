"""Architecture registry: the 10 assigned archs + the paper's own FL tasks.

``get_config(arch_id)`` returns the exact assigned ModelConfig;
``get_skips(arch_id)`` the documented shape skips; ``ARCH_IDS`` the roster.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "yi-9b": "repro.configs.yi_9b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "internvl2-2b": "repro.configs.internvl2_2b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_skips(arch_id: str) -> dict[str, str]:
    return dict(getattr(_module(arch_id), "SKIP_SHAPES", {}))


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    return get_config(arch_id).reduced(**overrides)
