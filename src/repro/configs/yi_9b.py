"""yi-9b — llama-architecture dense GQA. [arXiv:2403.04652; hf]

48L d_model=4096 32H (GQA kv=4, head_dim 128) d_ff=11008 vocab=64000.
Full causal attention -> long_500k skipped.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "yi-9b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.DENSE,
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta_global=5_000_000.0,
    )
