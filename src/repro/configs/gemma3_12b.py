"""gemma3-12b — 5:1 local:global attention interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

48L d_model=3840 16H (GQA kv=8, head_dim 256) d_ff=15360 vocab=262144.
Local layers use a 1024-token sliding window with RoPE theta 10k; every
sixth layer is global with theta 1M. QK-norm + sqrt(d) embedding scaling
(gemma house style). Mostly-local pattern -> qualifies for long_500k.
"""
from repro.models.config import GLOBAL, Family, ModelConfig

ARCH_ID = "gemma3-12b"
SKIP_SHAPES: dict[str, str] = {}

LOCAL_WINDOW = 1024


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.DENSE,
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        window_pattern=(LOCAL_WINDOW,) * 5 + (GLOBAL,),
        qk_norm=True,
        scale_embeddings=True,
        act="gelu",
        rope_theta_global=1_000_000.0,
        rope_theta_local=10_000.0,
        tie_embeddings=True,
    )
