"""rwkv6-1.6b ("Finch") — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]

24L d_model=2048 (32 wkv heads of 64) d_ff=7168 vocab=65536. Constant-size
recurrent state -> the flagship long_500k arch.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "rwkv6-1.6b"
SKIP_SHAPES: dict[str, str] = {}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.SSM,
        num_layers=24,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=7168,
        vocab_size=65536,
    )
