"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (kv=16, head_dim 128) per-expert d_ff=1408
vocab=163840, MoE 64e top-6. Full attention -> long_500k skipped.
"""
from repro.models.config import Family, ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §5)"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family=Family.MOE,
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        num_experts=64,
        experts_per_token=6,
        rope_theta_global=50_000.0,
    )
