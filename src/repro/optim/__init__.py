from repro.optim.optimizers import (
    OptState,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    sgdm,
)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "OptState",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "constant",
    "cosine_decay",
    "global_norm",
    "linear_warmup_cosine",
    "sgdm",
]
