"""Minimal pytree optimizers (no external deps).

Both optimizers follow the (init_fn, update_fn) convention:

    init_fn(params)                    -> state
    update_fn(grads, state, params)    -> (updates, state)
    apply_updates(params, updates)     -> params

States are pytrees of fp32 moments (paired with bf16 params this is the
standard mixed-precision setup); under the federated runtime every leaf
carries a leading client-slot axis and the ZeRO/FSDP sharding rules in
dist/sharding.py decide placement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    count: jax.Array
    mu: Any  # first moment (or momentum)
    nu: Any  # second moment (None for sgdm)


def _f32_like(t):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), tree), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def adamw(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    def lr_at(count):
        return learning_rate(count) if callable(learning_rate) else learning_rate

    def init_fn(params):
        return OptState(jnp.zeros((), jnp.int32), _f32_like(params), _f32_like(params))

    def update_fn(grads, state: OptState, params):
        count = state.count + 1
        cf = count.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        mu_hat_scale = 1.0 / (1 - b1**cf)
        nu_hat_scale = 1.0 / (1 - b2**cf)
        lr = lr_at(count)

        def upd(m, v, p):
            step = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, OptState(count, mu, nu)

    return init_fn, update_fn


def sgdm(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    momentum: float = 0.9,
    nesterov: bool = True,
):
    def lr_at(count):
        return learning_rate(count) if callable(learning_rate) else learning_rate

    def init_fn(params):
        return OptState(jnp.zeros((), jnp.int32), _f32_like(params), None)

    def update_fn(grads, state: OptState, params):
        del params
        count = state.count + 1
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
        )
        lr = lr_at(count)
        if nesterov:
            updates = jax.tree.map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), mu, grads
            )
        else:
            updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, OptState(count, mu, None)

    return init_fn, update_fn
