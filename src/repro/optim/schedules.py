"""Learning-rate schedules (callables of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, final_fraction: float = 0.1):
    def fn(count):
        frac = jnp.clip(count.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * (final_fraction + (1 - final_fraction) * cos)

    return fn


def linear_warmup_cosine(
    lr: float, warmup_steps: int, decay_steps: int, final_fraction: float = 0.1
):
    cos = cosine_decay(lr, max(decay_steps - warmup_steps, 1), final_fraction)

    def fn(count):
        c = count.astype(jnp.float32)
        warm = lr * c / max(warmup_steps, 1)
        return jnp.where(c < warmup_steps, warm, cos(count - warmup_steps))

    return fn
