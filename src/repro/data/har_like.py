"""HAR-like synthetic time-series task (paper's Smart Healthcare scenario).

6 activity classes (as in UCI HAR), 9 channels (3×acc/gyro/total), windows
of 128 steps. Each class is a characteristic mixture of sinusoids +
per-client gain/phase idiosyncrasies (device mobility/placement), which is
what makes the federation non-IID.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

WINDOW = 128
CHANNELS = 9
NUM_CLASSES = 6


@dataclasses.dataclass(frozen=True)
class HarLikeConfig:
    dirichlet_alpha: float = 0.5
    drift_period: int = 0
    drift_fraction: float = 0.3
    noise: float = 0.3
    seed: int = 0

    @property
    def num_classes(self) -> int:
        return NUM_CLASSES


def _class_params(cfg: HarLikeConfig):
    """Per-class per-channel (freq, amp, phase)."""
    key = jax.random.PRNGKey(cfg.seed + 20)
    k1, k2, k3 = jax.random.split(key, 3)
    freqs = jax.random.uniform(k1, (NUM_CLASSES, CHANNELS), minval=1.0, maxval=8.0)
    amps = jax.random.uniform(k2, (NUM_CLASSES, CHANNELS), minval=0.3, maxval=1.2)
    phases = jax.random.uniform(k3, (NUM_CLASSES, CHANNELS), maxval=2 * jnp.pi)
    return freqs, amps, phases


def client_label_prior(cfg: HarLikeConfig, client_id: Array, round_idx: Array) -> Array:
    if cfg.drift_period:
        epoch = round_idx // cfg.drift_period
        dk = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 21), epoch)
        drifts = jax.random.bernoulli(
            jax.random.fold_in(dk, client_id), cfg.drift_fraction
        )
        eff = jnp.where(drifts, epoch, 0)
    else:
        eff = jnp.zeros((), jnp.int32)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 22), client_id), eff
    )
    return jax.random.dirichlet(key, jnp.full((NUM_CLASSES,), cfg.dirichlet_alpha))


def client_batch(cfg: HarLikeConfig, client_id: Array, round_idx: Array,
                 key: Array, batch: int):
    """Returns (signals (B, WINDOW*CHANNELS) f32, labels (B,) i32)."""
    prior = client_label_prior(cfg, client_id, round_idx)
    kc = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 23), client_id)
    gain = 1.0 + 0.2 * jax.random.normal(kc, (CHANNELS,))
    phase_ofs = 0.5 * jax.random.normal(jax.random.fold_in(kc, 1), (CHANNELS,))

    k1, k2 = jax.random.split(jax.random.fold_in(key, client_id))
    labels = jax.random.categorical(k1, jnp.log(prior + 1e-9), shape=(batch,))
    freqs, amps, phases = _class_params(cfg)
    t = jnp.linspace(0, 2 * jnp.pi, WINDOW)[None, :, None]  # (1, T, 1)
    f = freqs[labels][:, None, :]  # (B, 1, C)
    a = amps[labels][:, None, :]
    p = phases[labels][:, None, :] + phase_ofs[None, None, :]
    sig = a * jnp.sin(f * t + p) * gain[None, None, :]
    sig = sig + cfg.noise * jax.random.normal(k2, sig.shape)
    return sig.reshape(batch, WINDOW * CHANNELS).astype(jnp.float32), labels.astype(
        jnp.int32
    )


def client_histogram(cfg: HarLikeConfig, client_id: Array, round_idx: Array) -> Array:
    return client_label_prior(cfg, client_id, round_idx)


def eval_batch(cfg: HarLikeConfig, key: Array, batch: int):
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, NUM_CLASSES)
    freqs, amps, phases = _class_params(cfg)
    t = jnp.linspace(0, 2 * jnp.pi, WINDOW)[None, :, None]
    sig = amps[labels][:, None, :] * jnp.sin(
        freqs[labels][:, None, :] * t + phases[labels][:, None, :]
    )
    sig = sig + cfg.noise * jax.random.normal(k2, sig.shape)
    return sig.reshape(batch, WINDOW * CHANNELS).astype(jnp.float32), labels.astype(
        jnp.int32
    )
