from repro.data.synthetic import FedDataConfig
from repro.data.telemetry import TelemetryConfig, init_telemetry, make_profiles

__all__ = ["FedDataConfig", "TelemetryConfig", "init_telemetry", "make_profiles"]
