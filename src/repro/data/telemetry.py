"""Device telemetry simulator: the CPU/MEM/BATT/energy signals that feed
FedFog's health scoring (Eq. 1) and selection (Eq. 3).

AR(1) fluctuations for cpu/mem (load transients), battery that drains with
participation and trickle-charges otherwise, heterogeneous device classes
(wearable / camera / sensor, per the paper's §IV.A testbed description)
with different compute capacity (MIPS) and radio profiles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import ClientTelemetry, _pytree_dataclass

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    num_clients: int = 64
    ar_rho: float = 0.8  # AR(1) persistence for cpu/mem
    ar_noise: float = 0.12
    drain_per_round: float = 0.06  # battery drain when participating
    recharge: float = 0.01
    seed: int = 0


@_pytree_dataclass
class DeviceProfiles:
    """Static heterogeneity: (N,) arrays.

    Registered as a pytree so profiles can ride through jit/vmap/scan as
    explicit arguments of the scan-compiled simulator and the vmapped
    sweep subsystem (rather than leaking in as trace constants).
    """

    mips: Array  # compute capacity, instructions/s (sim units)
    bw_up: Array  # uplink bytes/s
    bw_down: Array  # downlink bytes/s
    rtt_ms: Array
    battery_capacity_j: Array


def make_profiles(cfg: TelemetryConfig) -> DeviceProfiles:
    key = jax.random.PRNGKey(cfg.seed + 30)
    ks = jax.random.split(key, 5)
    n = cfg.num_clients
    # device class mix: 0=wearable, 1=camera, 2=gateway-adjacent sensor
    cls = jax.random.randint(ks[0], (n,), 0, 3)
    mips = jnp.take(jnp.array([500e6, 1200e6, 800e6]), cls) * (
        1.0 + 0.3 * jax.random.normal(ks[1], (n,))
    )
    bw_up = jnp.take(jnp.array([1e6, 5e6, 2e6]), cls) * jnp.exp(
        0.3 * jax.random.normal(ks[2], (n,))
    )
    rtt = jnp.take(jnp.array([40.0, 15.0, 25.0]), cls) * jnp.exp(
        0.2 * jax.random.normal(ks[3], (n,))
    )
    cap = jnp.take(jnp.array([8e3, 40e3, 15e3]), cls)
    return DeviceProfiles(
        mips=jnp.abs(mips) + 1e5,
        bw_up=bw_up,
        bw_down=bw_up * 4,
        rtt_ms=rtt,
        battery_capacity_j=cap,
    )


def init_telemetry(cfg: TelemetryConfig) -> ClientTelemetry:
    key = jax.random.PRNGKey(cfg.seed + 31)
    ks = jax.random.split(key, 4)
    n = cfg.num_clients
    u = lambda k, lo, hi: jax.random.uniform(k, (n,), minval=lo, maxval=hi)
    batt = u(ks[2], 0.4, 1.0)
    return ClientTelemetry(
        cpu=u(ks[0], 0.4, 1.0),
        mem=u(ks[1], 0.4, 1.0),
        batt=batt,
        energy=batt,  # normalized energy level tracks battery
    )


def step_telemetry(
    cfg: TelemetryConfig,
    tel: ClientTelemetry,
    participated: Array,  # (N,) bool
    round_energy_j: Array,  # (N,)
    profiles: DeviceProfiles,
    key: Array,
) -> ClientTelemetry:
    k1, k2 = jax.random.split(key)
    n = cfg.num_clients

    def ar(x, k):
        noise = jax.random.normal(k, (n,)) * cfg.ar_noise
        mean = 0.7
        return jnp.clip(mean + cfg.ar_rho * (x - mean) + noise, 0.05, 1.0)

    batt = jnp.clip(
        tel.batt
        - participated * cfg.drain_per_round
        - round_energy_j / profiles.battery_capacity_j
        + (~participated) * cfg.recharge,
        0.0,
        1.0,
    )
    return ClientTelemetry(
        cpu=ar(tel.cpu, k1), mem=ar(tel.mem, k2), batt=batt, energy=batt
    )
