"""EMNIST-like synthetic vision task (paper's Edge Vision scenario, §IV.A).

Deterministic 28×28 grayscale "characters": each of the 62 classes is a
random smooth template; samples = template + per-sample elastic-ish noise.
Clients get Dirichlet non-IID label priors; drift shifts the prior; label
flip (attack, §IV.D) maps class k -> (K-1)-k. Same pure-function-of-
(seed, client, round) contract as the LM pipeline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

IMG = 28


@dataclasses.dataclass(frozen=True)
class EmnistLikeConfig:
    num_classes: int = 62
    dirichlet_alpha: float = 0.5
    drift_period: int = 0
    drift_fraction: float = 0.3
    noise: float = 0.35
    seed: int = 0


def _templates(cfg: EmnistLikeConfig) -> Array:
    """(K, 28, 28) smooth class templates."""
    key = jax.random.PRNGKey(cfg.seed + 10)
    coarse = jax.random.normal(key, (cfg.num_classes, 7, 7))
    up = jax.image.resize(coarse, (cfg.num_classes, IMG, IMG), "bilinear")
    return jnp.tanh(up * 2.0)


def client_label_prior(cfg: EmnistLikeConfig, client_id: Array,
                       round_idx: Array) -> Array:
    if cfg.drift_period:
        epoch = round_idx // cfg.drift_period
        dk = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 11), epoch)
        drifts = jax.random.bernoulli(
            jax.random.fold_in(dk, client_id), cfg.drift_fraction
        )
        eff = jnp.where(drifts, epoch, 0)
    else:
        eff = jnp.zeros((), jnp.int32)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 12), client_id), eff
    )
    return jax.random.dirichlet(
        key, jnp.full((cfg.num_classes,), cfg.dirichlet_alpha)
    )


def _drift_epoch(cfg: EmnistLikeConfig, client_id: Array, round_idx: Array):
    """Effective drift epoch for a client (0 = undrifted)."""
    if not cfg.drift_period:
        return jnp.zeros((), jnp.int32)
    epoch = round_idx // cfg.drift_period
    dk = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 11), epoch)
    drifts = jax.random.bernoulli(
        jax.random.fold_in(dk, client_id), cfg.drift_fraction
    )
    return jnp.where(drifts, epoch, 0).astype(jnp.int32)


def client_batch(
    cfg: EmnistLikeConfig, client_id: Array, round_idx: Array, key: Array,
    batch: int,
) -> tuple[Array, Array]:
    """Returns (images (B, 784) f32, labels (B,) i32).

    Drifted clients experience CONCEPT drift (§IV.A "drift engine"): their
    label semantics are permuted by a per-epoch permutation, so their
    updates genuinely degrade the global model until FedFog's Eq. 2 gate
    excludes them — the dynamic Table IV measures."""
    prior = client_label_prior(cfg, client_id, round_idx)
    k1, k2 = jax.random.split(jax.random.fold_in(key, client_id))
    labels = jax.random.categorical(k1, jnp.log(prior + 1e-9), shape=(batch,))
    temps = _templates(cfg)[labels]  # (B, 28, 28)
    noise = jax.random.normal(k2, temps.shape) * cfg.noise
    imgs = (temps + noise).reshape(batch, IMG * IMG)
    epoch = _drift_epoch(cfg, client_id, round_idx)
    perm = jax.random.permutation(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 13), epoch),
        cfg.num_classes,
    )
    labels = jnp.where(epoch > 0, perm[labels], labels)
    return imgs.astype(jnp.float32), labels.astype(jnp.int32)


def client_histogram(cfg: EmnistLikeConfig, client_id: Array,
                     round_idx: Array) -> Array:
    """Exact OBSERVED label distribution — the Eq. 2 drift signal (reflects
    the concept-drift permutation so the scheduler can detect it)."""
    prior = client_label_prior(cfg, client_id, round_idx)
    epoch = _drift_epoch(cfg, client_id, round_idx)
    perm = jax.random.permutation(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 13), epoch),
        cfg.num_classes,
    )
    permuted = jnp.zeros_like(prior).at[perm].set(prior)
    return jnp.where(epoch > 0, permuted, prior)


def eval_batch(cfg: EmnistLikeConfig, key: Array, batch: int):
    """IID test split (uniform labels)."""
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, cfg.num_classes)
    temps = _templates(cfg)[labels]
    noise = jax.random.normal(k2, temps.shape) * cfg.noise
    return (
        (temps + noise).reshape(batch, IMG * IMG).astype(jnp.float32),
        labels.astype(jnp.int32),
    )
