"""Deterministic synthetic federated data — fully PRNG-derived, no disk.

Non-IID structure follows the standard Dirichlet-partition protocol: each
logical client owns a mixture over K latent *domains*; each domain is a
distinct unigram token distribution (LM tasks) or class prior (vision
tasks). Data drift (paper §IV.A "drift engine") re-draws a client's mixture
at configured rounds, which moves its token/label histogram and therefore
its Eq. 2 KL score — exactly the signal FedFog's scheduler gates on.

Everything is a pure function of (seed, client_id, round) so any client's
round batch can be regenerated anywhere — which is what makes the federated
pipeline trivially elastic and restart-safe.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FedDataConfig:
    vocab_size: int = 256
    num_domains: int = 8
    dirichlet_alpha: float = 0.5  # lower = more non-IID
    drift_period: int = 0  # re-draw mixtures every k rounds (0 = never)
    drift_fraction: float = 0.3  # fraction of clients that drift
    seed: int = 0


def _domain_logits(cfg: FedDataConfig) -> Array:
    """(K, V) unigram logits per latent domain (deterministic)."""
    key = jax.random.PRNGKey(cfg.seed)
    return jax.random.normal(key, (cfg.num_domains, cfg.vocab_size)) * 2.0


def client_mixture(cfg: FedDataConfig, client_id: Array, round_idx: Array) -> Array:
    """(K,) Dirichlet mixture for a client, re-drawn on drift epochs."""
    if cfg.drift_period:
        epoch = round_idx // cfg.drift_period
        # only a fraction of clients drift at each epoch boundary
        drift_key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1), epoch)
        drifts = jax.random.bernoulli(
            jax.random.fold_in(drift_key, client_id), cfg.drift_fraction
        )
        eff_epoch = jnp.where(drifts, epoch, 0)
    else:
        eff_epoch = jnp.zeros((), jnp.int32)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 2), client_id), eff_epoch
    )
    return jax.random.dirichlet(key, jnp.full((cfg.num_domains,), cfg.dirichlet_alpha))


def client_token_logits(cfg: FedDataConfig, client_id: Array, round_idx: Array) -> Array:
    """(V,) unigram logits of one client at one round."""
    mix = client_mixture(cfg, client_id, round_idx)
    probs = jax.nn.softmax(_domain_logits(cfg), axis=-1)  # (K, V)
    return jnp.log(mix @ probs + 1e-9)


def client_tokens(
    cfg: FedDataConfig, client_id: Array, round_idx: Array, key: Array,
    batch: int, seq_len: int,
) -> Array:
    """(batch, seq_len+1) int32 token sequences for a client's round batch.

    A first-order structure is added on top of the unigram prior (tokens
    repeat-shift within a window) so language-model training has signal.
    """
    logits = client_token_logits(cfg, client_id, round_idx)
    k1, k2 = jax.random.split(jax.random.fold_in(key, client_id))
    toks = jax.random.categorical(k1, logits, shape=(batch, seq_len + 1))
    # structured component: with prob 0.5, copy the token 2 positions back
    copy_mask = jax.random.bernoulli(k2, 0.5, toks.shape)
    shifted = jnp.roll(toks, 2, axis=1)
    toks = jnp.where(copy_mask, shifted, toks)
    return toks.astype(jnp.int32)


def client_histogram(
    cfg: FedDataConfig, client_id: Array, round_idx: Array, bins: int
) -> Array:
    """(bins,) expected token histogram — the scheduler's Eq. 2 input.

    Uses the exact mixture distribution (not a sample), folded into bins.
    """
    probs = jnp.exp(client_token_logits(cfg, client_id, round_idx))
    pad = (-cfg.vocab_size) % bins
    if pad:
        probs = jnp.concatenate([probs, jnp.zeros((pad,))])
    return probs.reshape(bins, -1).sum(-1)


def all_client_histograms(cfg: FedDataConfig, num_clients: int,
                          round_idx: Array, bins: int) -> Array:
    return jax.vmap(
        lambda c: client_histogram(cfg, c, round_idx, bins)
    )(jnp.arange(num_clients))


def round_batch(
    cfg: FedDataConfig, slot_client_ids: Array, round_idx: Array, key: Array,
    per_slot_batch: int, seq_len: int,
) -> Array:
    """(num_slots × per_slot_batch, seq_len+1) — slot-major global batch."""
    toks = jax.vmap(
        lambda cid, k: client_tokens(
            cfg, cid, round_idx, k, per_slot_batch, seq_len
        )
    )(slot_client_ids, jax.random.split(key, slot_client_ids.shape[0]))
    return toks.reshape(-1, seq_len + 1)


def client_data_sizes(cfg: FedDataConfig, num_clients: int) -> Array:
    """Static per-client dataset sizes |D_i| (log-normal, deterministic)."""
    key = jax.random.PRNGKey(cfg.seed + 3)
    return jnp.exp(
        jax.random.normal(key, (num_clients,)) * 0.5 + jnp.log(300.0)
    ).astype(jnp.float32)
