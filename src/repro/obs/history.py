"""One history schema for every engine (sync loop / scanned / async).

Before this module, ``FedFogSimulator._finalize`` and
``AsyncFedFogSimulator.run`` each hand-rolled their own summary block —
two places deciding what ``final_accuracy`` means, drifting one key at a
time. Both engines now call :func:`finalize_history`, so a history dict
carries the same derived summary fields no matter which engine produced
it, and downstream consumers (benchmarks, trackers, the examples'
summary tables) read one schema:

  * ``final_accuracy`` / ``peak_accuracy`` — last/best eval accuracy
    (0.0 when the run produced no eval points, e.g. an async run whose
    horizon expired before any flush).
  * ``total_energy_j``      — Σ per-entry ``energy_j`` (Eq. 10 budget).
  * ``total_cold_starts``   — Σ ``cold_starts`` when the key is present.
  * ``mean_latency_ms``     — mean of ``round_latency_ms`` when present
    (sync engines; the async engine's per-flush ``update_latency_ms`` is
    a different quantity and is left to its own column).

:func:`assemble_async_history` is the async engine's companion: it turns
the fixed-capacity on-device metric arrays into the trimmed per-flush /
per-dispatch lists (the inline dict assembly formerly in
``AsyncFedFogSimulator.run``).
"""
from __future__ import annotations

from typing import Any, Mapping


def finalize_history(
    history: dict[str, Any], *, rounds: int | None = None
) -> dict[str, Any]:
    """Append the shared derived-summary fields to ``history`` in place.

    ``rounds`` overrides the latency divisor (the sync engines average
    over the round count even if a caller sliced the history); default
    is the length of the latency list itself.
    """
    acc = history.get("accuracy") or []
    history["final_accuracy"] = acc[-1] if len(acc) else 0.0
    history["peak_accuracy"] = max(acc) if len(acc) else 0.0
    history["total_energy_j"] = sum(history.get("energy_j", []))
    lat = history.get("round_latency_ms")
    if lat is not None:
        n = rounds if rounds else len(lat)
        history["mean_latency_ms"] = sum(lat) / max(n, 1)
    cold = history.get("cold_starts")
    if cold is not None:
        history["total_cold_starts"] = sum(cold)
    # Fault/recovery totals (repro.sim.faults). The sync engines carry
    # per-round counter lists; the async engine already reports run
    # totals as scalars — hence the ``sum`` vs passthrough split. Only
    # emitted when the engine produced the counters at all, so
    # pre-fault histories keep their exact schema.
    for key, total in (
        ("fault_retries", "total_fault_retries"),
        ("fault_terminal", "total_fault_terminal"),
        ("fault_corrupt", "total_fault_corrupt"),
        ("round_skipped", "total_rounds_skipped"),
        ("fault_skipped", "total_rounds_skipped"),
    ):
        v = history.get(key)
        if v is not None:
            history[total] = sum(v) if isinstance(v, (list, tuple)) else v
    return history


def summary_metrics(history: Mapping[str, Any]) -> dict[str, Any]:
    """The summary-field subset of a finalized history — the row a
    ``Tracker.log_summary`` call should carry."""
    keys = (
        "final_accuracy", "peak_accuracy", "total_energy_j",
        "mean_latency_ms", "total_cold_starts",
        "num_dispatches", "num_flushes", "num_completions",
        "lost_inflight", "virtual_time_ms",
        "total_fault_retries", "total_fault_terminal",
        "total_fault_corrupt", "total_rounds_skipped",
        "fault_lost_deadline", "queue_dropped",
    )
    return {k: history[k] for k in keys if k in history}


def assemble_async_history(
    m_flush: Mapping[str, Any],
    m_dispatch: Mapping[str, Any],
    n_flushes: int,
    n_dispatches: int,
) -> dict[str, Any]:
    """Trim the async engine's fixed-capacity metric arrays to the
    realized flush/dispatch counts and name the dispatch channels.

    ``valid`` is the padding marker, not a metric — dropped here."""
    history: dict[str, Any] = {
        k: [float(x) for x in v[:n_flushes]]
        for k, v in m_flush.items()
        if k != "valid"
    }
    for k, v in m_dispatch.items():
        history[f"dispatch_{k}"] = [float(x) for x in v[:n_dispatches]]
    return history
