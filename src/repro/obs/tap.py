"""In-scan metric taps: stream decimated metrics out of compiled loops.

``run_scanned()`` and the async event engine bought their throughput by
giving up streaming — one device→host transfer at the end of the whole
compiled program. A ``MetricTap`` restores visibility without giving the
speed back:

  * **structural gate** — the tap is threaded into the engine at
    construction time; ``tap=None`` (or ``every=0``, via
    ``core.types.static_on``) leaves the traced program byte-for-byte
    identical to the untapped one, so the tracker-off path keeps today's
    trace, compile cache keys stay structural, and flipping a tap on
    never perturbs RNG streams or numerics.
  * **decimation** — inside the loop a ``lax.cond`` on
    ``step % every == 0`` guards the host transfer, so at decimation k
    only every k-th round/flush pays a (tiny) device→host copy of the
    scalar metrics row.
  * **ordered io_callback** — the emitting branch runs
    ``jax.experimental.io_callback(..., ordered=True)``: rows reach the
    tracker in program order while the scan/while_loop is still
    executing, and the callback is an explicit effect XLA may not elide
    or reorder, preserving scan semantics.

Taps hash by identity, so a per-instance jit (both engines jit per
instance) re-traces only when the tap object itself changes — a second
``run_scanned()`` on the same simulator is a jit cache hit
(``n_compiles=0``; regression-tested).

Taps are for the SINGLE-RUN paths (``FedFogSimulator.run_scanned`` /
``AsyncFedFogSimulator.run``): the vmapped sweep paths batch many runs
into one program where ordered host callbacks are unsupported (and rows
from interleaved seeds would be meaningless) — the sweep layer instead
logs per-group compile/execute *events* host-side (``run_sweep(tracker=)``).
"""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.core.types import static_on
from repro.obs.trackers import Tracker


class MetricTap:
    """Bridge from a compiled loop to a host-side :class:`Tracker`.

    Args:
      tracker: the sink receiving decimated rows.
      every: decimation interval k — steps with ``step % k == 0`` emit.
        ``0`` disables the tap structurally (the traced program is then
        identical to ``tap=None``; ``core.types.static_on`` is the gate
        predicate, same as every other structural flag in the repo).
      const: host-side constants merged into every emitted row (e.g.
        ``{"policy": "fedfog"}``) — they never enter the trace.
      channel: row label written as the ``event`` field, naming which
        loop emitted it (``"round"`` for the sync scan, ``"flush"`` for
        the async engine's server flushes).
    """

    def __init__(
        self,
        tracker: Tracker,
        every: int = 10,
        *,
        const: Mapping[str, Any] | None = None,
        channel: str = "round",
    ):
        if every < 0:
            raise ValueError(f"decimation interval must be >= 0, got {every}")
        self.tracker = tracker
        self.every = int(every)
        self.const = dict(const or {})
        self.channel = channel
        self.rows_emitted = 0  # host-side receive counter

    @property
    def enabled(self) -> bool:
        """Structural on/off — False compiles the tap out entirely."""
        return static_on(self.every)

    # ------------------------------------------------------------------ #
    def _receive(self, names: tuple[str, ...], step, *vals) -> None:
        """Host-side receiver (the io_callback target)."""
        self.rows_emitted += 1
        row = {"event": self.channel, **self.const}
        row.update({n: float(v) for n, v in zip(names, vals)})
        self.tracker.log(row, step=int(step))

    # ------------------------------------------------------------------ #
    def emit(self, metrics: Mapping[str, Any], step) -> None:
        """Emit one (decimated) metrics row from inside a traced loop.

        Call unconditionally in the loop body — the decimation ``cond``
        and the structural gate live here. ``metrics`` values must be
        scalars (they are cast to f32 for the transfer); ``step`` is the
        loop's monotone counter and drives the decimation.
        """
        if not self.enabled:
            return
        names = tuple(sorted(metrics))
        step = jnp.asarray(step, jnp.int32)
        vals = tuple(jnp.asarray(metrics[n], jnp.float32) for n in names)

        receive = functools.partial(self._receive, names)

        def _tap(args):
            s, *vs = args
            io_callback(receive, None, s, *vs, ordered=True)

        jax.lax.cond(
            (step % self.every) == 0,
            _tap,
            lambda args: None,
            (step, *vals),
        )

    # ------------------------------------------------------------------ #
    def host_log(self, metrics: Mapping[str, Any], step) -> None:
        """Same row/decimation semantics from host-side (eager) loops —
        the per-round ``run()`` engine streams through this so a tap
        behaves identically on both sync engines."""
        if not self.enabled or int(step) % self.every != 0:
            return
        self.rows_emitted += 1
        row = {"event": self.channel, **self.const}
        row.update({n: float(metrics[n]) for n in sorted(metrics)})
        self.tracker.log(row, step=int(step))
