"""repro.obs — streaming observability for the compiled engines.

Pluggable trackers (``trackers``), in-scan ``io_callback`` metric taps
(``tap``), and the shared history/summary schema (``history``). See
docs/EXPERIMENTS.md §Observability for the event/column ↔ §IV.F metric
map and the CLI surface (``--track jsonl:PATH``).
"""
from repro.obs.history import (
    assemble_async_history,
    finalize_history,
    summary_metrics,
)
from repro.obs.tap import MetricTap
from repro.obs.trackers import (
    CompositeTracker,
    CsvTracker,
    JsonlTracker,
    MemoryTracker,
    NoopTracker,
    Tracker,
    tracker_from_spec,
)

__all__ = [
    "Tracker",
    "NoopTracker",
    "JsonlTracker",
    "CsvTracker",
    "MemoryTracker",
    "CompositeTracker",
    "tracker_from_spec",
    "MetricTap",
    "finalize_history",
    "summary_metrics",
    "assemble_async_history",
]
