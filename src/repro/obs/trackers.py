"""Pluggable streaming-metrics trackers (the observability backend API).

Modeled on levanter's ``Tracker`` abstraction: a deliberately small
surface — ``log(metrics, step=)`` for streaming rows, ``log_summary``
for end-of-run scalars, a context-manager lifecycle — that every sink
implements, so engines and launchers log against the protocol and the
backend is a construction-time choice:

  * ``NoopTracker``      — the default everywhere; logging compiles out.
  * ``JsonlTracker``     — one JSON object per line, flushed per row, so
                           a tail of the file IS the live run (this is
                           the sink the in-scan ``io_callback`` taps
                           stream into — see ``repro.obs.tap``).
  * ``CsvTracker``       — spreadsheet-friendly; columns fixed by the
                           first logged row.
  * ``MemoryTracker``    — in-process row list (tests, benchmarks).
  * ``CompositeTracker`` — fan-out to several sinks.

``tracker_from_spec`` parses the CLI surface (``--track jsonl:PATH``,
``--track csv:PATH``, ``--track noop``, comma-separated for a
composite) shared by ``launch/train.py`` and ``examples/edge_sim.py``.

Values are coerced with ``float()``/``int()`` host-side, so jnp/numpy
scalars coming out of ``io_callback`` taps or ``device_get`` histories
log cleanly. Trackers are host-side objects: never close over them in
traced code directly — that is what ``repro.obs.tap.MetricTap`` is for.
"""
from __future__ import annotations

import json
import numbers
import os
import time
from typing import Any, Mapping, Sequence


def _coerce(v: Any) -> Any:
    """JSON/CSV-safe scalar: numpy/jax scalars → python, rest verbatim."""
    if isinstance(v, bool):
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return _coerce(v.item())
    return v


class Tracker:
    """Protocol/base class: a sink for streamed metrics.

    ``log`` receives one row of scalar metrics (an optional monotone
    ``step`` names its position in the run); ``log_summary`` receives
    end-of-run scalars. Both must be cheap and never raise into the
    training loop. ``finish`` flushes/closes; the context-manager
    lifecycle guarantees it runs.
    """

    name = "tracker"

    def log(self, metrics: Mapping[str, Any], *, step: int | None = None) -> None:
        raise NotImplementedError

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        raise NotImplementedError

    def finish(self) -> None:  # idempotent
        pass

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc) -> bool:
        self.finish()
        return False


class NoopTracker(Tracker):
    """Discard everything (the default backend)."""

    name = "noop"

    def log(self, metrics, *, step=None):
        pass

    def log_summary(self, metrics):
        pass


class MemoryTracker(Tracker):
    """Accumulate rows in-process — tests and benchmark harnesses."""

    name = "memory"

    def __init__(self):
        self.rows: list[dict[str, Any]] = []
        self.summaries: list[dict[str, Any]] = []

    def log(self, metrics, *, step=None):
        row = {k: _coerce(v) for k, v in metrics.items()}
        if step is not None:
            row["step"] = int(step)
        self.rows.append(row)

    def log_summary(self, metrics):
        self.summaries.append({k: _coerce(v) for k, v in metrics.items()})


class JsonlTracker(Tracker):
    """Append-only JSONL sink, one flushed line per row.

    Flushing per row is the point: the in-scan ``io_callback`` taps call
    ``log`` while the compiled program is still executing, and a
    ``tail -f`` of the file (or the CI smoke's row-count assertion) must
    see those rows mid-run, not after the final device→host transfer.
    """

    name = "jsonl"

    def __init__(self, path: str, *, append: bool = True):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a" if append else "w")

    def _write(self, row: dict[str, Any]) -> None:
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()

    def log(self, metrics, *, step=None):
        row = {"ts": round(time.time(), 3)}
        if step is not None:
            row["step"] = int(step)
        row.update({k: _coerce(v) for k, v in metrics.items()})
        self._write(row)

    def log_summary(self, metrics):
        row = {"ts": round(time.time(), 3), "summary": True}
        row.update({k: _coerce(v) for k, v in metrics.items()})
        self._write(row)

    def finish(self):
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class CsvTracker(Tracker):
    """CSV sink; the header is fixed by the first logged row.

    Later rows fill missing columns with '' and drop unseen keys (a
    streaming sink cannot rewrite its header). Summaries land in the
    same file with ``summary=1`` so one file round-trips a whole run.
    """

    name = "csv"

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w", newline="")
        self._writer = None
        self._columns: list[str] | None = None

    def _ensure_writer(self, row: Mapping[str, Any]) -> None:
        if self._writer is None:
            import csv

            self._columns = ["step", "summary"] + [
                k for k in row if k not in ("step", "summary")
            ]
            self._writer = csv.DictWriter(
                self._f, fieldnames=self._columns, restval="",
                extrasaction="ignore",
            )
            self._writer.writeheader()

    def _write(self, row: dict[str, Any]) -> None:
        self._ensure_writer(row)
        self._writer.writerow(row)
        self._f.flush()

    def log(self, metrics, *, step=None):
        row = {k: _coerce(v) for k, v in metrics.items()}
        row["step"] = int(step) if step is not None else ""
        row["summary"] = 0
        self._write(row)

    def log_summary(self, metrics):
        row = {k: _coerce(v) for k, v in metrics.items()}
        row["summary"] = 1
        self._write(row)

    def finish(self):
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class CompositeTracker(Tracker):
    """Fan a single log stream out to several sinks."""

    name = "composite"

    def __init__(self, trackers: Sequence[Tracker]):
        self.trackers = list(trackers)

    def log(self, metrics, *, step=None):
        for t in self.trackers:
            t.log(metrics, step=step)

    def log_summary(self, metrics):
        for t in self.trackers:
            t.log_summary(metrics)

    def finish(self):
        for t in self.trackers:
            t.finish()


def tracker_from_spec(spec: str | None) -> Tracker:
    """Build a tracker from a CLI spec — the ``--track`` flag surface.

    ``None``/``""``/``"noop"`` → ``NoopTracker``; ``jsonl:PATH`` /
    ``csv:PATH`` → file sinks; a comma-separated list composes, e.g.
    ``--track jsonl:run.jsonl,csv:run.csv``.
    """
    if not spec or spec == "noop":
        return NoopTracker()
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if len(parts) > 1:
        return CompositeTracker([tracker_from_spec(p) for p in parts])
    (part,) = parts
    if part == "noop":
        return NoopTracker()
    if ":" not in part:
        raise ValueError(
            f"tracker spec {part!r}: expected 'noop', 'jsonl:PATH' or "
            f"'csv:PATH' (comma-separate to compose)"
        )
    kind, path = part.split(":", 1)
    if kind == "jsonl":
        return JsonlTracker(path)
    if kind == "csv":
        return CsvTracker(path)
    raise ValueError(f"unknown tracker backend {kind!r} in spec {part!r}")
