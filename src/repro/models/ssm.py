"""State-space sequence mixers: Mamba-style selective scan (Hymba's SSM
heads) and the RWKV6 "Finch" recurrence with data-dependent decay.

Both are expressed as two-level checkpointed scans: an outer ``lax.scan``
over time chunks whose body is ``jax.checkpoint``-ed, and an inner
``lax.scan`` over steps. This bounds autodiff memory to
O(T/chunk · state + chunk · state) instead of O(T · state) — the difference
between 34 GB and 0.3 GB of saved carries for rwkv6-1.6b at 4k tokens
(DESIGN.md §6). The Pallas ``kernels/wkv6`` kernel implements the same
chunking natively for TPU; these jnp forms are its oracle and the dry-run
lowering path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


def _chunked_checkpointed_scan(step_fn, carry, xs_tree, seq_len: int, chunk: int):
    """scan(step_fn) over time with chunked jax.checkpoint.

    xs_tree leaves: (T, ...). Returns (final_carry, ys_tree with (T, ...))."""
    chunk = max(1, min(chunk, seq_len))
    n_chunks = -(-seq_len // chunk)
    pad = n_chunks * chunk - seq_len

    def pad_leaf(x):
        if pad:
            x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    xs_c = jax.tree.map(pad_leaf, xs_tree)

    @jax.checkpoint
    def chunk_body(carry, xs_chunk):
        return jax.lax.scan(step_fn, carry, xs_chunk)

    carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(
        lambda y: y.reshape((n_chunks * chunk,) + y.shape[2:])[:seq_len], ys
    )
    return carry, ys


# --------------------------------------------------------------------- #
# Mamba-style selective scan (S6) — used by the Hymba hybrid family.
# --------------------------------------------------------------------- #
def selective_scan(
    x: Array,  # (B, T, di) input sequence (post in-proj/conv/act)
    dt: Array,  # (B, T, di) softplus'd step sizes
    a_log: Array,  # (di, st) log of -A (positive)
    b: Array,  # (B, T, st) input-dependent B
    c: Array,  # (B, T, st) input-dependent C
    d_skip: Array,  # (di,) skip connection
    initial_state: Array | None = None,  # (B, di, st)
    chunk: int = 128,
):
    """Returns (y (B,T,di), final_state (B,di,st)).

    Recurrence per channel i, state j:
        s_t = exp(-exp(a_log)·dt_t) · s_{t-1} + dt_t · b_t · x_t
        y_t = Σ_j c_t[j] · s_t[:, j] + D · x_t
    """
    bsz, t, di = x.shape
    st = a_log.shape[-1]
    neg_a = -jnp.exp(a_log.astype(jnp.float32))  # (di, st)

    s0 = (
        jnp.zeros((bsz, di, st), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, xs):
        x_t, dt_t, b_t, c_t = xs  # (B,di),(B,di),(B,st),(B,st)
        dt32 = dt_t.astype(jnp.float32)
        da = jnp.exp(dt32[..., None] * neg_a[None])  # (B, di, st)
        dbx = (dt32 * x_t.astype(jnp.float32))[..., None] * b_t.astype(jnp.float32)[
            :, None, :
        ]
        s_new = da * s + dbx
        y_t = jnp.einsum("bis,bs->bi", s_new, c_t.astype(jnp.float32))
        return s_new, y_t

    xs = (
        x.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        b.swapaxes(0, 1),
        c.swapaxes(0, 1),
    )
    s_final, ys = _chunked_checkpointed_scan(step, s0, xs, t, chunk)
    y = ys.swapaxes(0, 1) + d_skip.astype(jnp.float32)[None, None] * x.astype(
        jnp.float32
    )
    return y.astype(x.dtype), s_final


def selective_scan_step(
    x_t: Array,  # (B, di)
    dt_t: Array,  # (B, di)
    a_log: Array,
    b_t: Array,  # (B, st)
    c_t: Array,  # (B, st)
    d_skip: Array,
    state: Array,  # (B, di, st)
):
    """Single decode step. Returns (y (B,di), new_state)."""
    neg_a = -jnp.exp(a_log.astype(jnp.float32))
    dt32 = dt_t.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * neg_a[None])
    dbx = (dt32 * x_t.astype(jnp.float32))[..., None] * b_t.astype(jnp.float32)[
        :, None, :
    ]
    s_new = da * state.astype(jnp.float32) + dbx
    y = jnp.einsum("bis,bs->bi", s_new, c_t.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32)[None] * x_t.astype(jnp.float32)
    return y.astype(x_t.dtype), s_new


# --------------------------------------------------------------------- #
# RWKV6 (Finch) WKV recurrence with data-dependent decay.
# --------------------------------------------------------------------- #
def wkv6(
    r: Array,  # (B, T, H, K) receptance
    k: Array,  # (B, T, H, K) key
    v: Array,  # (B, T, H, V) value
    w: Array,  # (B, T, H, K) per-step decay in (0,1): exp(-exp(...))
    u: Array,  # (H, K) bonus for the current token
    initial_state: Array | None = None,  # (B, H, K, V)
    chunk: int = 128,
):
    """Returns (y (B,T,H,V), final_state (B,H,K,V)).

        y_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t)
        S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    """
    bsz, t, h, dk = r.shape
    dv = v.shape[-1]
    s0 = (
        jnp.zeros((bsz, h, dk, dv), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    u32 = u.astype(jnp.float32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = (z.astype(jnp.float32) for z in xs)  # (B,H,K)...
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u32[None] [..., None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, y_t

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), w.swapaxes(0, 1))
    s_final, ys = _chunked_checkpointed_scan(step, s0, xs, t, chunk)
    return ys.swapaxes(0, 1).astype(r.dtype), s_final


def wkv6_step(r_t, k_t, v_t, w_t, u, state):
    """Single decode step. r/k/v/w: (B,H,K|V); state (B,H,K,V)."""
    r32, k32, v32, w32 = (z.astype(jnp.float32) for z in (r_t, k_t, v_t, w_t))
    kv = k32[..., :, None] * v32[..., None, :]
    y = jnp.einsum(
        "bhk,bhkv->bhv", r32, state.astype(jnp.float32) + u.astype(jnp.float32)[None][..., None] * kv
    )
    s_new = w32[..., None] * state.astype(jnp.float32) + kv
    return y.astype(r_t.dtype), s_new
