"""Shared model building blocks: norms, RoPE, attention variants, MLPs.

All functions are pure and shape-polymorphic; sharding is decided by the
caller (dist/sharding.py) via constraints on params/activations, never here.

Attention comes in three interchangeable implementations:

  * ``attention_xla``         — materialized-scores einsum path (short seq).
  * ``attention_xla_chunked`` — online-softmax scan over KV blocks: the
    flash-attention *algorithm* expressed in jnp so XLA fuses it; O(S) memory.
    This is the dry-run/default long-context path.
  * Pallas ``flash_attention`` (kernels/flash_attention) — the TPU-target
    kernel, selected with attn_impl="flash" (validated in interpret mode).

All three share the mask convention: causal + optional sliding window
(window = -1 (GLOBAL) means unbounded), so every arch's local:global layer
pattern runs through one code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import GLOBAL

Array = jax.Array
_NEG_INF = -1e30


# --------------------------------------------------------------------- #
# Norms & MLPs
# --------------------------------------------------------------------- #
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def gated_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array, act: str) -> Array:
    """SwiGLU / GeGLU feed-forward."""
    gate = x @ w_gate
    up = x @ w_up
    if act == "silu":
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        raise ValueError(f"unknown act {act}")
    return h @ w_down


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: Array | float) -> Array:
    """(head_dim//2,) inverse frequencies; theta may be a traced scalar
    (per-layer local/global theta under scan-over-layers)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (jnp.asarray(theta, jnp.float32) ** exponents)


def apply_rope(x: Array, positions: Array, theta: Array | float) -> Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S) or (S,)."""
    inv_freq = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, hd/2)
    # Broadcast over the head axis: (..., S, 1, hd/2)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Masking
# --------------------------------------------------------------------- #
def causal_window_bias(
    q_positions: Array, k_positions: Array, window: Array | int
) -> Array:
    """Additive attention bias implementing causal + sliding-window masking.

    window == GLOBAL (-1) means pure causal. Returns (..., Sq, Sk) float32
    of {0, -inf}. ``window`` may be a traced scalar (per-layer pattern under
    scan-over-layers).
    """
    dq = q_positions[..., :, None]
    dk = k_positions[..., None, :]
    visible = dk <= dq
    w = jnp.asarray(window, jnp.int32)
    in_window = jnp.where(w == GLOBAL, True, (dq - dk) < jnp.maximum(w, 1))
    return jnp.where(visible & in_window, 0.0, _NEG_INF).astype(jnp.float32)


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd) for GQA."""
    if groups == 1:
        return k
    b, s, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, hd))
    return k.reshape(b, s, hkv * groups, hd)


# --------------------------------------------------------------------- #
# Attention implementations
# --------------------------------------------------------------------- #
def attention_xla(
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    k_positions: Array,
    window: Array | int,
    *,
    bidirectional: bool = False,
) -> Array:
    """Materialized-score attention. q: (B,Sq,H,hd), k/v: (B,Sk,Hkv,hd).

    q_positions/k_positions are 1D (Sq,)/(Sk,) — shared across the batch.
    """
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if not bidirectional:
        bias = causal_window_bias(q_positions, k_positions, window)  # (Sq, Sk)
        scores = scores + bias[None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_xla_chunked(
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    k_positions: Array,
    window: Array | int,
    *,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    bidirectional: bool = False,
) -> Array:
    """Online-softmax (flash-algorithm) attention in pure jnp.

    Two-level chunking: an outer ``jax.checkpoint``-ed scan over q chunks
    and an inner scan over kv chunks carrying (m, l, acc). Live memory is
    O(chunk_q·chunk_kv) scores + O(chunk_q·hd) accumulators — in both the
    forward AND the recomputed backward — instead of O(Sq·Sk). This is the
    flash-attention *algorithm* expressed for XLA; the Pallas kernel
    (kernels/flash_attention) is the TPU-native version of the same tiling.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    groups = h // k.shape[2]
    scale = hd**-0.5
    chunk_q = max(1, min(chunk_q, sq))
    chunk_kv = max(1, min(chunk_kv, sk))

    n_kv = -(-sk // chunk_kv)
    pad_kv = n_kv * chunk_kv - sk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        k_positions = jnp.pad(
            k_positions, (0, pad_kv), constant_values=jnp.iinfo(jnp.int32).max
        )
    kc = k.reshape(b, n_kv, chunk_kv, k.shape[2], hd)
    vc = v.reshape(b, n_kv, chunk_kv, v.shape[2], hd)
    kpos_c = k_positions.reshape(n_kv, chunk_kv)

    n_q = -(-sq // chunk_q)
    pad_q = n_q * chunk_q - sq
    qp = q
    q_pos_p = q_positions
    if pad_q:
        qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos_p = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    qcs = qp.reshape(b, n_q, chunk_q, h, hd).swapaxes(0, 1)  # (nq, b, cq, h, hd)
    qpos_cs = q_pos_p.reshape(n_q, chunk_q)

    # Static-window fast path: a q chunk starting at position p only sees
    # keys in [p - window + 1, p + cq), i.e. a FIXED number of kv chunks at
    # a dynamic offset. Slicing them out cuts attention FLOPs/traffic from
    # O(S·S) to O(S·window) — decisive for SWA archs at 32k+ (gemma3,
    # mixtral, hymba). Requires a static (python int) window, which the
    # unrolled-layer paths provide (see transformer.forward_hidden).
    import os as _os

    static_window = (
        isinstance(window, int) and window > 0
        and _os.environ.get("REPRO_NO_STATIC_WIN") != "1"  # baseline knob
    )
    if static_window:
        kw = min(n_kv, (window + chunk_q - 2) // chunk_kv + 2)

    @functools.partial(jax.checkpoint, policy=None)
    def q_chunk_attention(q_c, qp_c, qi):
        """q_c: (b, cq, h, hd); qp_c: (cq,); qi: chunk index (traced)."""
        q32 = (q_c * scale).astype(q_c.dtype)
        if static_window:
            first_q = (sk - sq) + qi * chunk_q
            lo = jnp.clip((first_q - window + 1) // chunk_kv, 0, n_kv - kw)
            kc_l = jax.lax.dynamic_slice_in_dim(kc, lo, kw, axis=1)
            vc_l = jax.lax.dynamic_slice_in_dim(vc, lo, kw, axis=1)
            kpos_l = jax.lax.dynamic_slice_in_dim(kpos_c, lo, kw, axis=0)
        else:
            kc_l, vc_l, kpos_l = kc, vc, kpos_c

        def kv_step(carry, xs):
            m, l, acc = carry
            k_c, v_c, kp_c = xs
            k_c = _repeat_kv(k_c, groups)
            v_c = _repeat_kv(v_c, groups)
            s = jnp.einsum("bqhd,bkhd->bhqk", q32, k_c).astype(jnp.float32)
            if bidirectional:
                bias = jnp.where(kp_c >= 0, 0.0, _NEG_INF)[None, None, None]
            else:
                bias = causal_window_bias(qp_c, kp_c, window)[None, None]
            s = s + bias
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # Keep m finite on fully-masked rows so exp() yields 0, not NaN.
            m_safe = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(m <= _NEG_INF / 2, _NEG_INF, m) - m_safe)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_c.dtype), v_c
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, chunk_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk_q), jnp.float32)
        acc0 = jnp.zeros((b, h, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, acc0),
            (kc_l.swapaxes(0, 1), vc_l.swapaxes(0, 1), kpos_l),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q_c.dtype)  # (b, cq, h, hd)

    _, outs = jax.lax.scan(
        lambda _, xs: (None, q_chunk_attention(xs[0], xs[1], xs[2])),
        None,
        (qcs, qpos_cs, jnp.arange(n_q, dtype=jnp.int32)),
    )
    out = outs.swapaxes(0, 1).reshape(b, n_q * chunk_q, h, hd)
    return out[:, :sq]


def attention_decode(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    q_position: Array,
    window: Array | int,
) -> Array:
    """Single-token decode attention against a (possibly seq-sharded) cache.

    q: (B, 1, H, hd); k/v_cache: (B, S, Hkv, hd); q_position: (B,) int32.
    Entries beyond q_position (or outside the window) are masked. The cache
    sequence axis may be sharded (dist/sharding) — the max/sum reductions
    then lower to small cross-shard all-reduces (flash-decode style).
    """
    b, s, hkv, hd = k_cache.shape
    groups = q.shape[2] // hkv
    k = _repeat_kv(k_cache, groups)
    v = _repeat_kv(v_cache, groups)
    scale = hd**-0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(s, dtype=jnp.int32)
    dq = q_position[:, None]  # (B, 1)
    visible = kpos[None, :] <= dq
    w = jnp.asarray(window, jnp.int32)
    in_window = jnp.where(w == GLOBAL, True, (dq - kpos[None, :]) < jnp.maximum(w, 1))
    bias = jnp.where(visible & in_window, 0.0, _NEG_INF)  # (B, S)
    probs = jax.nn.softmax(scores + bias[:, None, None, :], axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def select_attention(
    impl: str,
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    k_positions: Array,
    window: Array | int,
    *,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    bidirectional: bool = False,
) -> Array:
    """Dispatch on attn_impl; 'auto' = xla below 8k keys, chunked above."""
    if impl == "auto":
        impl = "xla" if k.shape[1] <= 8192 else "xla_chunked"
    if impl == "xla":
        return attention_xla(
            q, k, v, q_positions, k_positions, window, bidirectional=bidirectional
        )
    if impl == "xla_chunked":
        return attention_xla_chunked(
            q,
            k,
            v,
            q_positions,
            k_positions,
            window,
            chunk_q=chunk_q,
            chunk_kv=chunk_kv,
            bidirectional=bidirectional,
        )
    if impl == "flash":
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(
            q, k, v, q_positions, k_positions, window, bidirectional=bidirectional
        )
    raise ValueError(f"unknown attn impl {impl}")
