"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Faithful structure: token-shift mixing with low-rank data-dependent
interpolation (time_maa LoRA), data-dependent per-channel decay
``w = exp(-exp(w0 + lora(x)))``, per-head WKV state recurrence (ssm.wkv6 /
kernels/wkv6), gated output, and squared-ReLU channel-mix. We use RMSNorm
where upstream uses LayerNorm-with-bias (uniform with the rest of the
framework; noted in DESIGN.md).

Head layout: heads = d_model // 64 (hd = 64), as in the released rwkv6-1.6b.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import ParamDecl, axes_tree, init_tree, shape_tree

Array = jax.Array

HEAD_DIM = 64
MAA_RANK = 32
DECAY_RANK = 64
N_MAA = 5  # w, k, v, r, g


def num_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def param_decls(cfg: ModelConfig):
    L, d, ff, V = cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.padded_vocab
    H = num_heads(cfg)
    pd = cfg.param_dtype
    layers = {
        "ln_tm": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "ln_cm": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        # token-shift interpolation vectors + LoRA
        "maa_x": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "maa_wkvrg": ParamDecl((L, N_MAA, d), ("layers", None, "embed"), "zeros", pd),
        "maa_w1": ParamDecl((L, d, N_MAA * MAA_RANK), ("layers", "embed", None), "normal", pd),
        "maa_w2": ParamDecl((L, N_MAA, MAA_RANK, d), ("layers", None, None, "embed"), "normal", pd),
        # decay
        "decay": ParamDecl((L, d), ("layers", "mlp"), "zeros", "float32"),
        "decay_w1": ParamDecl((L, d, DECAY_RANK), ("layers", "embed", None), "normal", pd),
        "decay_w2": ParamDecl((L, DECAY_RANK, d), ("layers", None, "mlp"), "normal", pd),
        "u": ParamDecl((L, H, HEAD_DIM), ("layers", "heads", "head_dim"), "zeros", "float32"),
        # projections (columns sharded = head-sharded for r/k/v; see DESIGN)
        "wr": ParamDecl((L, d, d), ("layers", "embed", "mlp"), "normal", pd),
        "wk": ParamDecl((L, d, d), ("layers", "embed", "mlp"), "normal", pd),
        "wv": ParamDecl((L, d, d), ("layers", "embed", "mlp"), "normal", pd),
        "wg": ParamDecl((L, d, d), ("layers", "embed", "mlp"), "normal", pd),
        "wo": ParamDecl((L, d, d), ("layers", "mlp", "embed"), "normal_out", pd),
        "ln_x": ParamDecl((L, d), ("layers", "mlp"), "zeros", pd),
        # channel-mix
        "cm_maa_k": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "cm_maa_r": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "cm_wk": ParamDecl((L, d, ff), ("layers", "embed", "mlp"), "normal", pd),
        "cm_wv": ParamDecl((L, ff, d), ("layers", "mlp", "embed"), "normal_out", pd),
        "cm_wr": ParamDecl((L, d, d), ("layers", "embed", None), "normal", pd),
    }
    decls = {
        "embed": ParamDecl((V, d), ("vocab", "embed"), "normal", pd),
        "layers": layers,
        "final_norm": ParamDecl((d,), ("embed",), "zeros", pd),
    }
    if not cfg.tie_embeddings:
        decls["lm_head"] = ParamDecl((d, V), ("embed", "vocab"), "normal_out", pd)
    return decls


init_params = lambda cfg, key: init_tree(param_decls(cfg), key)  # noqa: E731
param_shapes = lambda cfg: shape_tree(param_decls(cfg))  # noqa: E731
param_axes = lambda cfg: axes_tree(param_decls(cfg))  # noqa: E731


def _shift(x: Array, prev: Array | None = None) -> Array:
    """Token shift: x_{t-1} along time; first step takes ``prev`` (decode)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _data_dependent_mix(lp, x: Array, xprev: Array):
    """Finch token-shift: five interpolated views of (x, x_{t-1})."""
    dx = xprev - x
    xxx = x + dx * lp["maa_x"]
    r1 = jnp.tanh(xxx @ lp["maa_w1"])  # (B,T,5*rank)
    b, t, _ = r1.shape
    r1 = r1.reshape(b, t, N_MAA, MAA_RANK)
    mods = jnp.einsum("btnr,nrd->btnd", r1, lp["maa_w2"])  # (B,T,5,d)
    views = []
    for i in range(N_MAA):
        mi = lp["maa_wkvrg"][i] + mods[:, :, i]
        views.append(x + dx * mi)
    return views  # xw, xk, xv, xr, xg


def _time_mix(lp, cfg, x, wkv_state=None, x_prev=None, chunk=128):
    """Returns (out, new_wkv_state, last_x). x: (B,T,d)."""
    b, t, d = x.shape
    H = num_heads(cfg)
    xprev = _shift(x, x_prev)
    xw, xk, xv, xr, xg = _data_dependent_mix(lp, x, xprev)
    r = xr @ lp["wr"]
    k = xk @ lp["wk"]
    v = xv @ lp["wv"]
    g = jax.nn.silu(xg @ lp["wg"])
    ww = lp["decay"].astype(jnp.float32) + (
        jnp.tanh(xw @ lp["decay_w1"]) @ lp["decay_w2"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww))  # (B,T,d) in (0,1)

    def heads(z):
        return z.reshape(b, t, H, HEAD_DIM)

    y, s_final = ssm_mod.wkv6(
        heads(r), heads(k), heads(v), heads(w.astype(x.dtype)), lp["u"],
        initial_state=wkv_state, chunk=chunk,
    )
    y = y.reshape(b, t, d)
    y = rms_norm(y, lp["ln_x"], cfg.rms_eps) * g
    return y @ lp["wo"], s_final, x[:, -1]


def _channel_mix(lp, x, x_prev=None):
    xprev = _shift(x, x_prev)
    dx = xprev - x
    xk = x + dx * lp["cm_maa_k"]
    xr = x + dx * lp["cm_maa_r"]
    k = jnp.square(jax.nn.relu(xk @ lp["cm_wk"]))
    kv = k @ lp["cm_wv"]
    return jax.nn.sigmoid(xr @ lp["cm_wr"]) * kv, x[:, -1]


def _layer(lp, x, cfg, state=None, chunk=128):
    """One RWKV block. state: dict with wkv/tm_x/cm_x or None (train)."""
    h = rms_norm(x, lp["ln_tm"], cfg.rms_eps)
    tm_out, wkv_new, tm_x = _time_mix(
        lp, cfg, h,
        None if state is None else state["wkv"],
        None if state is None else state["tm_x"],
        chunk=chunk,
    )
    x = x + tm_out
    h = rms_norm(x, lp["ln_cm"], cfg.rms_eps)
    cm_out, cm_x = _channel_mix(
        lp, h, None if state is None else state["cm_x"]
    )
    x = x + cm_out
    return x, {"wkv": wkv_new, "tm_x": tm_x, "cm_x": cm_x}


def forward_hidden(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                   runtime=None, return_state: bool = False):
    del runtime
    x = params["embed"][tokens] if tokens is not None else embeds
    layer = functools.partial(_layer, cfg=cfg)
    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable
        )

    if cfg.scan_layers:
        def body(carry, lp):
            y, st = layer(lp, x=carry)
            return y, st if return_state else None

        x, states = jax.lax.scan(body, x, params["layers"])
    else:
        states_list = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, st = layer(lp, x=x)
            states_list.append(st)
        states = (
            jax.tree.map(lambda *z: jnp.stack(z), *states_list)
            if return_state
            else None
        )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return (x, states) if return_state else x


def _head_logits(params, cfg, h):
    from repro.models.transformer import _head_logits as _hl

    return _hl(params, cfg, h)


def lm_loss(params, cfg: ModelConfig, *, tokens=None, embeds=None, targets,
            loss_mask=None, runtime=None):
    from repro.models import transformer as tf  # reuse chunked-CE

    h = forward_hidden(params, cfg, tokens=tokens, embeds=embeds)
    tlen = targets.shape[1]
    h = h[:, -tlen:]
    return tf._chunked_ce(params, cfg, h, targets, loss_mask)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    del max_len, dtype  # O(1) state — the whole point of the family
    L, d = cfg.num_layers, cfg.d_model
    H = num_heads(cfg)
    return {
        "wkv": jnp.zeros((L, batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        "tm_x": jnp.zeros((L, batch, d), jnp.dtype(cfg.compute_dtype)),
        "cm_x": jnp.zeros((L, batch, d), jnp.dtype(cfg.compute_dtype)),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            cache_len: int = 0, runtime=None):
    del cache_len, runtime
    h, states = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, return_state=True
    )
    cache = {
        "wkv": states["wkv"],
        "tm_x": states["tm_x"],
        "cm_x": states["cm_x"],
        "pos": jnp.asarray(
            tokens.shape[1] if tokens is not None else embeds.shape[1], jnp.int32
        ),
    }
    return _head_logits(params, cfg, h[:, -1:]), cache


def decode_step(params, cfg: ModelConfig, cache, tokens, runtime=None):
    """tokens: (B,1). Unrolled layers; state updated in place."""
    del runtime
    x = params["embed"][tokens]
    wkv, tm_x, cm_x = cache["wkv"], cache["tm_x"], cache["cm_x"]
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        st = {"wkv": wkv[i], "tm_x": tm_x[i], "cm_x": cm_x[i]}
        x, st_new = _layer(lp, x, cfg, state=st, chunk=1)
        wkv = wkv.at[i].set(st_new["wkv"])
        tm_x = tm_x.at[i].set(st_new["tm_x"])
        cm_x = cm_x.at[i].set(st_new["cm_x"])
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = _head_logits(params, cfg, x)
    return logits, {
        "wkv": wkv, "tm_x": tm_x, "cm_x": cm_x, "pos": cache["pos"] + 1
    }
