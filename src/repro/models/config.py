"""Unified model configuration covering all 10 assigned architectures.

One dataclass describes every LM-family member the framework supports:
dense GQA transformers, MoE, mixed local/global attention, hybrid
attention+SSM (Hymba), attention-free RWKV6, encoder-decoder (Seamless
backbone) and embedding-frontend VLM/audio stubs.

The exact assigned configs live in ``repro/configs/<arch>.py``; reduced
smoke-test variants are derived with ``.reduced()``.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Sequence


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    ENCDEC = "encdec"  # audio: seamless backbone, frontend stubbed
    HYBRID = "hybrid"  # hymba: parallel attn + SSM heads
    SSM = "ssm"  # rwkv6: attention-free
    VLM = "vlm"  # internvl2: LM backbone, ViT frontend stubbed


# Marker for "global attention" entries in layer window patterns.
GLOBAL = -1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family

    # Transformer trunk.
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 for pure-SSM rwkv6)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Attention details.
    qkv_bias: bool = False
    qk_norm: bool = False  # gemma3-style per-head RMSNorm on q/k
    scale_embeddings: bool = False  # gemma-style sqrt(d) embedding scale
    # Per-layer attention window pattern, cycled over layers.
    # GLOBAL means full causal attention; a positive int is an SWA window.
    window_pattern: tuple[int, ...] = (GLOBAL,)
    rope_theta_global: float = 1_000_000.0
    rope_theta_local: float = 10_000.0
    logit_softcap: float = 0.0  # gemma-style final-logit softcapping (0 = off)

    # MoE.
    num_experts: int = 0  # 0 => dense FFN
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid.
    ssm_state: int = 0  # Mamba state size (hymba) or rwkv head state flag
    ssm_conv: int = 4
    ssm_dt_rank: int = 0  # 0 => d_model // 16

    # Encoder-decoder.
    num_encoder_layers: int = 0  # >0 only for ENCDEC

    # Frontend stubs (VLM / audio): fraction of the sequence that arrives as
    # precomputed embeddings rather than token ids.
    embed_frontend_fraction: float = 0.0

    # Norm/act details.
    rms_eps: float = 1e-6
    act: str = "silu"  # "silu" (SwiGLU) or "gelu" (GeGLU)
    tie_embeddings: bool = False

    # Dtypes.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # Runtime/optimization knobs (hillclimb surface; not architecture).
    attn_impl: str = "auto"  # "auto" | "xla" | "xla_chunked" | "flash"
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    remat: bool = True
    remat_policy: str = "nothing"  # "nothing" | "dots" | "none"
    loss_chunk: int = 1024  # sequence chunking for the CE loss (0 = off)
    scan_layers: bool = True
    # Nested remat-scan: checkpoint BLOCKS of this many layers instead of
    # every layer. Bounds autodiff-saved residuals to L/block carries plus
    # one block's transient recompute (0 = flat scan, checkpoint per layer).
    scan_block: int = 0
    # Split local/global KV-cache stacks for mixed-window archs (perf knob;
    # shrinks SWA-layer caches to the window size during decode).
    split_local_global_cache: bool = False

    def __post_init__(self):
        if self.family is not Family.SSM:
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: q heads {self.num_heads} must be a multiple of "
                f"kv heads {self.num_kv_heads}"
            )
        if self.family is Family.MOE:
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family is Family.ENCDEC:
            assert self.num_encoder_layers > 0

    # ------------------------------------------------------------------ #
    # Derived quantities.
    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        """Embedding/LM-head rows padded to a multiple of 128 so the vocab
        dim shards on any model-axis factor (hymba's 32001, internvl's
        92553 and seamless' 256206 are not 16-divisible). Logits over the
        pad are masked to -inf; the architecture's true vocab is
        ``vocab_size`` everywhere else."""
        return -(-self.vocab_size // 128) * 128

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width (hybrid family)."""
        return self.d_model

    def layer_windows(self) -> tuple[int, ...]:
        """Resolved per-layer window sizes, GLOBAL -> -1 sentinel kept."""
        pat = self.window_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def is_subquadratic(self) -> bool:
        """True if decode-state size is bounded (SWA/SSM/linear-attention),
        i.e. the arch qualifies for the long_500k cell (DESIGN.md §5)."""
        if self.family is Family.SSM:
            return True
        if self.family is Family.ENCDEC:
            return False
        windows = [w for w in self.layer_windows()]
        n_global = sum(1 for w in windows if w == GLOBAL)
        # Mostly-local patterns (gemma3 5:1, mixtral all-SWA, hymba) qualify.
        return n_global <= max(1, self.num_layers // 6)

    # ------------------------------------------------------------------ #
    # Parameter / FLOP accounting (roofline §MODEL_FLOPS).
    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def model_flops_per_token(self, train: bool = True) -> float:
        """6·N_active per token (train) or 2·N_active (inference fwd)."""
        n = self.active_param_count() - self.embedding_params()
        mult = 6.0 if train else 2.0
        return mult * n

    def embedding_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n *= 2
        return n

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=16 if self.num_heads else 0,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2)
            if self.num_encoder_layers
            else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_dt_rank=8 if self.ssm_state else 0,
            window_pattern=tuple(
                (w if w == GLOBAL else min(w, 32)) for w in self.window_pattern
            ),
            loss_chunk=0,
            remat=False,
            name=self.name + "-reduced",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Closed-form parameter count (matches init_params; tested)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size

    if cfg.family is Family.SSM:  # RWKV6
        # time-mix: r/k/v/g/o (5 d*d) + decay lora (d*64*2) + maa lora
        # (d*32*5 + 5*32*d) + u (d) + ln params; channel-mix: k (d*ff),
        # v (ff*d), r (d*d).
        tm = 5 * d * d + 2 * 64 * d + 5 * 32 * d * 2 + d + 2 * d + 2 * d
        cm = d * ff + ff * d + d * d
        per_layer = tm + cm + 2 * d  # + two lns
        emb = v * d * (1 if cfg.tie_embeddings else 2)
        return cfg.num_layers * per_layer + emb + d

    attn = d * cfg.attn_dim + 2 * d * cfg.kv_dim + cfg.attn_dim * d
    if cfg.qkv_bias:
        attn += cfg.attn_dim + 2 * cfg.kv_dim
    if cfg.num_experts:
        ffn_total = cfg.num_experts * 3 * d * ff + d * cfg.num_experts
        ffn_active = cfg.experts_per_token * 3 * d * ff + d * cfg.num_experts
    else:
        ffn_total = ffn_active = 3 * d * ff
    norms = 2 * d

    per_layer_total = attn + ffn_total + norms
    per_layer_active = attn + ffn_active + norms

    if cfg.family is Family.HYBRID:
        # SSM branch: in_proj (d -> 2*d_inner), conv, dt/B/C proj, A, D, out.
        di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        ssm = (
            d * 2 * di
            + di * cfg.ssm_conv
            + di * (dtr + 2 * st)
            + dtr * di
            + di * st
            + 2 * di
            + di * d
        )
        per_layer_total += ssm
        per_layer_active += ssm

    emb = v * d * (1 if cfg.tie_embeddings else 2)
    n_layers = cfg.num_layers + cfg.num_encoder_layers
    if cfg.family is Family.ENCDEC:
        # decoder layers add cross-attention
        cross = d * cfg.attn_dim + 2 * d * cfg.kv_dim + cfg.attn_dim * d + d
        extra = cfg.num_layers * cross
    else:
        extra = 0

    total = n_layers * (per_layer_active if active_only else per_layer_total)
    return total + extra + emb + d  # + final norm
