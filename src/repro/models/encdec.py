"""Encoder–decoder backbone (seamless-m4t-medium). The speech/text modality
frontend is a STUB per the assignment brief: ``input_specs()`` supplies
precomputed frame embeddings (B, S_src, d) directly to the encoder.

Encoder: bidirectional self-attention blocks. Decoder: causal self-attention
+ cross-attention to encoder output + gated FFN. Same scan-over-layers and
chunked-CE machinery as the decoder-only trunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import GLOBAL, ModelConfig
from repro.models.layers import apply_rope, gated_mlp, rms_norm, select_attention, attention_decode
from repro.models.params import ParamDecl, axes_tree, init_tree, shape_tree
from repro.models.transformer import Runtime, _chunked_ce, _replicate_small

Array = jax.Array


def _attn_decls(L, d, H, Hkv, hd, pd, prefix=""):
    return {
        prefix + "wq": ParamDecl((L, d, H, hd), ("layers", "embed", "heads", "head_dim"), "normal", pd),
        prefix + "wk": ParamDecl((L, d, Hkv, hd), ("layers", "embed", "kv", "head_dim"), "normal", pd),
        prefix + "wv": ParamDecl((L, d, Hkv, hd), ("layers", "embed", "kv", "head_dim"), "normal", pd),
        prefix + "wo": ParamDecl((L, H, hd, d), ("layers", "heads", "head_dim", "embed"), "normal_out", pd),
    }


def _ffn_decls(L, d, ff, pd):
    return {
        "w_gate": ParamDecl((L, d, ff), ("layers", "embed", "mlp"), "normal", pd),
        "w_up": ParamDecl((L, d, ff), ("layers", "embed", "mlp"), "normal", pd),
        "w_down": ParamDecl((L, ff, d), ("layers", "mlp", "embed"), "normal_out", pd),
    }


def param_decls(cfg: ModelConfig):
    d, H, Hkv, hd, ff, V = (
        cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        cfg.d_ff, cfg.padded_vocab,
    )
    Le, Ld = cfg.num_encoder_layers, cfg.num_layers
    pd = cfg.param_dtype
    enc = {
        "attn_norm": ParamDecl((Le, d), ("layers", "embed"), "zeros", pd),
        "mlp_norm": ParamDecl((Le, d), ("layers", "embed"), "zeros", pd),
        **_attn_decls(Le, d, H, Hkv, hd, pd),
        **_ffn_decls(Le, d, ff, pd),
    }
    dec = {
        "attn_norm": ParamDecl((Ld, d), ("layers", "embed"), "zeros", pd),
        "cross_norm": ParamDecl((Ld, d), ("layers", "embed"), "zeros", pd),
        "mlp_norm": ParamDecl((Ld, d), ("layers", "embed"), "zeros", pd),
        **_attn_decls(Ld, d, H, Hkv, hd, pd),
        **_attn_decls(Ld, d, H, Hkv, hd, pd, prefix="x_"),
        **_ffn_decls(Ld, d, ff, pd),
    }
    return {
        "embed": ParamDecl((V, d), ("vocab", "embed"), "normal", pd),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_final_norm": ParamDecl((d,), ("embed",), "zeros", pd),
        "final_norm": ParamDecl((d,), ("embed",), "zeros", pd),
        "lm_head": ParamDecl((d, V), ("embed", "vocab"), "normal_out", pd),
    }


init_params = lambda cfg, key: init_tree(param_decls(cfg), key)  # noqa: E731
param_shapes = lambda cfg: shape_tree(param_decls(cfg))  # noqa: E731
param_axes = lambda cfg: axes_tree(param_decls(cfg))  # noqa: E731


def _self_attn(lp, cfg, x, positions, *, bidirectional, prefix=""):
    q = jnp.einsum("bsd,dhk->bshk", x, lp[prefix + "wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp[prefix + "wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp[prefix + "wv"])
    q = apply_rope(q, positions, cfg.rope_theta_global)
    k = apply_rope(k, positions, cfg.rope_theta_global)
    out = select_attention(
        cfg.attn_impl, q, k, v, positions, positions, GLOBAL,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        bidirectional=bidirectional,
    )
    return jnp.einsum("bshk,hkd->bsd", out, lp[prefix + "wo"]), (k, v)


def _cross_attn(lp, cfg, x, enc_kv):
    """Cross-attention: q from decoder, k/v precomputed from encoder."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, lp["x_wq"])
    sq, sk = x.shape[1], k.shape[1]
    out = select_attention(
        cfg.attn_impl, q, k, v,
        jnp.arange(sq, dtype=jnp.int32), jnp.arange(sk, dtype=jnp.int32),
        GLOBAL, bidirectional=True,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
    )
    return jnp.einsum("bshk,hkd->bsd", out, lp["x_wo"])


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, S_src, d) precomputed frontend embeddings."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def layer(lp, x):
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        a, _ = _self_attn(lp, cfg, h, positions, bidirectional=True)
        x = x + a
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        return x + gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda c, lp: (layer(lp, c), None), x, params["enc_layers"]
        )
    else:
        for i in range(cfg.num_encoder_layers):
            lp = jax.tree.map(lambda p: p[i], params["enc_layers"])
            x = layer(lp, x)
    return rms_norm(x, params["enc_final_norm"], cfg.rms_eps)


def _enc_cross_kv(params, cfg, enc_h):
    """Precompute per-decoder-layer cross K/V stacks: (L, B, S_src, Hkv, hd)."""
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_h, lp["x_wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_h, lp["x_wv"])
        return k, v

    if cfg.scan_layers:
        _, (ks, vs) = jax.lax.scan(
            lambda c, lp: (c, one(lp)), jnp.zeros(()), params["dec_layers"]
        )
        return ks, vs
    ks, vs = [], []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["dec_layers"])
        k, v = one(lp)
        ks.append(k)
        vs.append(v)
    return jnp.stack(ks), jnp.stack(vs)


def decode_train(params, cfg: ModelConfig, tokens: Array, enc_h: Array) -> Array:
    """Teacher-forced decoder pass. tokens: (B, S_tgt). Returns hidden."""
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    xks, xvs = _enc_cross_kv(params, cfg, enc_h)

    def layer(lp, x, xk, xv):
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        a, _ = _self_attn(lp, cfg, h, positions, bidirectional=False)
        x = x + a
        h = rms_norm(x, lp["cross_norm"], cfg.rms_eps)
        x = x + _cross_attn(lp, cfg, h, (xk, xv))
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        return x + gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda c, xs: (layer(xs[0], c, xs[1], xs[2]), None),
            x,
            (params["dec_layers"], xks, xvs),
        )
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec_layers"])
            x = layer(lp, x, xks[i], xvs[i])
    return rms_norm(x, params["final_norm"], cfg.rms_eps)


def lm_loss(params, cfg: ModelConfig, *, frames, tokens, targets,
            loss_mask=None, runtime=None):
    del runtime
    enc_h = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, enc_h)
    return _chunked_ce(params, cfg, h, targets, loss_mask)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int,
               dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "xk": jnp.zeros((L, batch, src_len, Hkv, hd), dtype),
        "xv": jnp.zeros((L, batch, src_len, Hkv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, *, frames, tokens, cache_len: int,
            runtime=None):
    """Encode source + teacher-force the target prefix into the self-cache."""
    del runtime
    enc_h = encode(params, cfg, frames)
    xk, xv = _enc_cross_kv(params, cfg, enc_h)
    x = params["embed"][tokens]
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    ks, vs = [], []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["dec_layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        a, (k, v) = _self_attn(lp, cfg, h, positions, bidirectional=False)
        x = x + a
        h = rms_norm(x, lp["cross_norm"], cfg.rms_eps)
        x = x + _cross_attn(lp, cfg, h, (xk[i], xv[i]))
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        x = x + gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
        ks.append(k)
        vs.append(v)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    k = jnp.stack(ks)
    v = jnp.stack(vs)
    pad = cache_len - s
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    from repro.models.transformer import _head_logits

    logits = _head_logits(params, cfg, x[:, -1:])
    cache = {"k": k, "v": v, "xk": xk, "xv": xv, "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, tokens, runtime=None):
    """One decoder token against self-cache + cross-cache."""
    pos = cache["pos"]
    x = params["embed"][tokens]
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    k_all, v_all = cache["k"], cache["v"]
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["dec_layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        q = apply_rope(q, positions, cfg.rope_theta_global)
        k = apply_rope(k, positions, cfg.rope_theta_global)
        q = _replicate_small(q, runtime)
        k = _replicate_small(k, runtime)
        v = _replicate_small(v, runtime)
        k_all = jax.lax.dynamic_update_slice(k_all, k[None], (i, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_all, v[None], (i, 0, pos, 0, 0))
        out = attention_decode(
            q, k_all[i], v_all[i], jnp.full((b,), pos, jnp.int32), GLOBAL
        )
        out = _replicate_small(out, runtime)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        h = rms_norm(x, lp["cross_norm"], cfg.rms_eps)
        x = x + _cross_attn(lp, cfg, h, (cache["xk"][i], cache["xv"][i]))
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        x = x + gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
    from repro.models.transformer import _head_logits

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = _head_logits(params, cfg, x)
    new_cache = dict(cache)
    new_cache.update(k=k_all, v=v_all, pos=pos + 1)
    return logits, new_cache
