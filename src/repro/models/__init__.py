"""Model zoo: unified config + per-family implementations."""
from repro.models.api import Model, build_model
from repro.models.config import GLOBAL, Family, ModelConfig
from repro.models.transformer import Runtime

__all__ = ["GLOBAL", "Family", "Model", "ModelConfig", "Runtime", "build_model"]
