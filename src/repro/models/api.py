"""Uniform model interface over all architecture families.

``build_model(cfg)`` returns a ``Model`` whose methods close over the config:

    model.init(key)                      -> params
    model.param_shapes()                 -> ShapeDtypeStruct pytree (dry-run)
    model.param_axes()                   -> logical-axes pytree (sharding)
    model.loss(params, batch, runtime)   -> scalar CE loss
    model.prefill(params, batch, cache_len, runtime) -> (logits, cache)
    model.decode_step(params, cache, tokens, runtime) -> (logits, cache)
    model.init_cache(batch, max_len)     -> cache pytree
    model.param_count() / active_param_count()  -> exact ints (from decls)

``batch`` dict keys by family:
    dense/moe/hybrid/ssm: tokens (B,S+1) — inputs/targets derived here
    vlm:    tokens (B,S_text+1), patch_embeds (B,S_img,d)
    encdec: frames (B,S_src,d), tokens (B,S_tgt+1)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, rwkv6, transformer
from repro.models.config import Family, ModelConfig
from repro.models.params import ParamDecl
from repro.models.transformer import Runtime

Array = jax.Array


def _decls(cfg: ModelConfig):
    if cfg.family is Family.SSM:
        return rwkv6.param_decls(cfg)
    if cfg.family is Family.ENCDEC:
        return encdec.param_decls(cfg)
    return transformer.param_decls(cfg)


def _count(decls, active_expert_fraction: float | None = None) -> int:
    total = 0
    flat, _ = jax.tree.flatten(decls, is_leaf=lambda x: isinstance(x, ParamDecl))
    for d in flat:
        n = math.prod(d.shape)
        if active_expert_fraction is not None and "experts" in d.axes:
            n = int(n * active_expert_fraction)
        total += n
    return total


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ #
    def init(self, key: Array):
        mod = self._mod()
        return mod.init_params(self.cfg, key)

    def param_shapes(self):
        return self._mod().param_shapes(self.cfg)

    def param_axes(self):
        return self._mod().param_axes(self.cfg)

    def param_count(self) -> int:
        return _count(_decls(self.cfg))

    def active_param_count(self) -> int:
        if not self.cfg.num_experts:
            return self.param_count()
        frac = self.cfg.experts_per_token / self.cfg.num_experts
        return _count(_decls(self.cfg), active_expert_fraction=frac)

    def flops_per_token(self, train: bool = True) -> float:
        """MODEL_FLOPS basis: 6·N_active (train) / 2·N_active (fwd),
        embeddings excluded."""
        emb = self.cfg.vocab_size * self.cfg.d_model
        if not self.cfg.tie_embeddings:
            emb *= 2
        n = self.active_param_count() - emb
        return (6.0 if train else 2.0) * n

    # ------------------------------------------------------------------ #
    def _mod(self):
        if self.cfg.family is Family.SSM:
            return rwkv6
        if self.cfg.family is Family.ENCDEC:
            return encdec
        return transformer

    def _split_train_batch(self, batch):
        cfg = self.cfg
        if cfg.family is Family.ENCDEC:
            toks = batch["tokens"]
            return dict(
                frames=batch["frames"],
                tokens=toks[:, :-1],
                targets=toks[:, 1:],
                loss_mask=batch.get("loss_mask"),
            )
        if cfg.family is Family.VLM:
            toks = batch["tokens"]
            return dict(
                embeds=batch["patch_embeds"],
                tokens=toks[:, :-1],
                targets=toks[:, 1:],  # loss over text positions only
                loss_mask=batch.get("loss_mask"),
            )
        toks = batch["tokens"]
        return dict(
            tokens=toks[:, :-1],
            targets=toks[:, 1:],
            loss_mask=batch.get("loss_mask"),
        )

    def loss(self, params, batch, runtime: Runtime = Runtime()):
        kw = self._split_train_batch(batch)
        return self._mod().lm_loss(params, self.cfg, runtime=runtime, **kw)

    # ------------------------------------------------------------------ #
    def init_cache(self, batch_size: int, max_len: int, src_len: int = 0):
        if self.cfg.family is Family.ENCDEC:
            return encdec.init_cache(self.cfg, batch_size, max_len, src_len)
        return self._mod().init_cache(self.cfg, batch_size, max_len)

    def prefill(self, params, batch, cache_len: int, runtime: Runtime = Runtime()):
        cfg = self.cfg
        if cfg.family is Family.ENCDEC:
            return encdec.prefill(
                params, cfg, frames=batch["frames"], tokens=batch["tokens"],
                cache_len=cache_len, runtime=runtime,
            )
        if cfg.family is Family.VLM:
            return transformer.prefill(
                params, cfg, tokens=batch["tokens"],
                embeds=batch["patch_embeds"], cache_len=cache_len,
                runtime=runtime,
            )
        return self._mod().prefill(
            params, cfg, tokens=batch["tokens"], cache_len=cache_len,
            runtime=runtime,
        )

    def decode_step(self, params, cache, tokens, runtime: Runtime = Runtime()):
        return self._mod().decode_step(params, self.cfg, cache, tokens, runtime)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
