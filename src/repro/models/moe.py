"""Mixture-of-Experts FFN — three interchangeable implementations.

  * ``moe_ffn_reference`` — every expert processes every token, outputs
    combined by router weights. O(E·t·d·ff) compute: only for tests/smoke
    configs. This is the semantic oracle for the other two.
  * ``moe_ffn_dropless``  — sort-based dropless dispatch with
    ``jax.lax.ragged_dot`` (single-host efficient path used by examples).
  * ``moe_ffn_ep``        — expert-parallel shard_map path for the pod mesh:
    tokens are replicated across the ``expert``×``tp`` axes (standard
    activation layout), each expert shard slices its local experts' capacity
    buffer, computes, and the combine is a masked psum over the expert axis
    (+ psum over tp for the down-projection). The collective structure —
    one (t,d)-sized psum per MoE layer over the expert axis — is what the
    roofline's collective term reads off the dry-run HLO.

Router convention (mixtral/moonlight style): softmax over expert logits,
top-k, renormalize the top-k probabilities to sum to 1.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

Array = jax.Array


def router_topk(xf: Array, w_router: Array, k: int):
    """xf: (t, d) -> (topk_probs (t,k) fp32 renormalized, topk_idx (t,k) i32)."""
    logits = xf.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)
    return topk_p, topk_i.astype(jnp.int32)


def _expert_ffn(h_in: Array, wg: Array, wu: Array, wd: Array, act: str) -> Array:
    """Per-expert gated FFN. h_in: (E, C, d); w*: (E, d, ff)/(E, ff, d)."""
    gate = jnp.einsum("ecd,edf->ecf", h_in, wg)
    up = jnp.einsum("ecd,edf->ecf", h_in, wu)
    fn = jax.nn.silu if act == "silu" else functools.partial(
        jax.nn.gelu, approximate=True
    )
    return jnp.einsum("ecf,efd->ecd", fn(gate) * up, wd)


# --------------------------------------------------------------------- #
# Reference (dense) implementation — the oracle.
# --------------------------------------------------------------------- #
def moe_ffn_reference(
    x: Array, w_router: Array, wg: Array, wu: Array, wd: Array, cfg: ModelConfig
) -> Array:
    """x: (B, S, d). Computes all experts on all tokens, combines by router."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    topk_p, topk_i = router_topk(xf, w_router, cfg.experts_per_token)
    # (t, E) combine weights from the top-k selection.
    combine = jnp.zeros((b * s, cfg.num_experts), jnp.float32)
    combine = combine.at[
        jnp.arange(b * s)[:, None], topk_i
    ].set(topk_p)
    all_out = _expert_ffn(
        jnp.broadcast_to(xf[None], (cfg.num_experts, b * s, d)).swapaxes(0, 0),
        wg,
        wu,
        wd,
        cfg.act,
    )  # (E, t, d) — note h_in here is (E, t, d) with C := t
    y = jnp.einsum("te,etd->td", combine, all_out.astype(jnp.float32))
    return y.reshape(b, s, d).astype(x.dtype)


# --------------------------------------------------------------------- #
# Dropless sort-based implementation (ragged_dot).
# --------------------------------------------------------------------- #
def moe_ffn_dropless(
    x: Array, w_router: Array, wg: Array, wu: Array, wd: Array, cfg: ModelConfig
) -> Array:
    """Sort tokens by expert, run ragged grouped matmuls, scatter back."""
    b, s, d = x.shape
    k = cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)
    topk_p, topk_i = router_topk(xf, w_router, k)

    flat_e = topk_i.reshape(-1)  # (t*k,)
    flat_p = topk_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok_of_slot = order // k
    xs = xf[tok_of_slot]  # (t*k, d) tokens in expert order
    group_sizes = jnp.bincount(flat_e, length=cfg.num_experts)

    gate = jax.lax.ragged_dot(xs, wg, group_sizes)
    up = jax.lax.ragged_dot(xs, wu, group_sizes)
    fn = jax.nn.silu if cfg.act == "silu" else functools.partial(
        jax.nn.gelu, approximate=True
    )
    h = fn(gate) * up
    out = jax.lax.ragged_dot(h, wd, group_sizes)  # (t*k, d)

    out = out.astype(jnp.float32) * flat_p[order][:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok_of_slot].add(out)
    return y.reshape(b, s, d).astype(x.dtype)


# --------------------------------------------------------------------- #
# GShard-style grouped einsum implementation (GSPMD-auto path).
# --------------------------------------------------------------------- #
def moe_ffn_gshard(
    x: Array, w_router: Array, wg: Array, wu: Array, wd: Array,
    cfg: ModelConfig, *, group_size: int = 512,
    mesh=None, expert_axis: str | None = None,
    group_axes: tuple[str, ...] | None = None,
    tp_axis: str | None = None,
) -> Array:
    """Capacity-dispatch MoE as pure einsums — the classic GShard SPMD
    formulation. Tokens are viewed as (G, S_g) groups with per-group
    capacity; the dispatch/combine one-hots are (G, S_g, E, C) products of
    einsums that GSPMD partitions without manual collectives:

      expert_in  = einsum('gsec,gsd->egcd', dispatch, x)   # e-shard local
      h          = expert FFN on (e, g·c, d)               # EP compute
      y          = einsum('gsec,egcd->gsd', combine, out)  # psum over e

    Per-device transient ≈ S_g·E·C·2B per group-shard — bounded by
    group_size, independent of global batch. Used by the pod-scale train
    path (the shard_map EP variant trips an XLA SPMD partitioner CHECK on
    some meshes — see DESIGN.md §4 notes).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g_sz = min(group_size, t)
    assert t % g_sz == 0, (t, g_sz)
    g = t // g_sz
    cap = _capacity(g_sz, k, e, cfg.moe_capacity_factor)

    xg = x.reshape(g, g_sz, d)
    topk_p, topk_i = router_topk(x.reshape(t, d), w_router, k)
    topk_p = topk_p.reshape(g, g_sz, k)
    topk_i = topk_i.reshape(g, g_sz, k)

    # (G, S, E) routing indicator and combine probability.
    oh = jax.nn.one_hot(topk_i, e, dtype=jnp.float32)  # (G, S, k, E)
    route = jnp.sum(oh, axis=2)  # (G, S, E) ∈ {0,1}
    probs = jnp.einsum("gske,gsk->gse", oh, topk_p)
    # Position of each (token, expert) assignment within the expert's
    # per-group capacity buffer: cumsum over the token dim.
    pos = jnp.cumsum(route, axis=1) - 1.0  # (G, S, E)
    keep = (pos < cap) & (route > 0)
    # The (G,S,E,C) one-hots are the layer's largest transients; building
    # them directly in the compute dtype halves that footprint (perf knob —
    # REPRO_MOE_OH_BF16=0 keeps fp32 for the baseline measurements).
    import os as _os

    oh_dtype = (
        jnp.dtype(cfg.compute_dtype)
        if _os.environ.get("REPRO_MOE_OH_BF16", "1") == "1"
        else jnp.float32
    )
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=oh_dtype)
    dispatch = pos_oh * keep[..., None].astype(oh_dtype)  # (G, S, E, C)
    combine = dispatch * probs[..., None].astype(oh_dtype)

    # Explicit constraints: without them GSPMD has been observed to
    # replicate the (E, G, C, d) buffers (44 GB/device for moonshot) —
    # dual expert×group sharding is the whole point of the layout.
    if mesh is not None and expert_axis is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        e_ax = expert_axis if mesh.shape.get(expert_axis, 1) > 1 else None
        g_ax = tuple(a for a in (group_axes or ()) if mesh.shape.get(a, 1) > 1)
        g_ent = (g_ax[0] if len(g_ax) == 1 else g_ax) if g_ax else None
        f_ax = tp_axis if tp_axis and mesh.shape.get(tp_axis, 1) > 1 else None

        def c4(z, spec):
            return jax.lax.with_sharding_constraint(z, NamedSharding(mesh, spec))
    else:
        P = None
        c4 = lambda z, spec: z  # noqa: E731
        e_ax = g_ent = f_ax = None

    from jax.sharding import PartitionSpec as _P

    cd = jnp.dtype(cfg.compute_dtype)
    dispatch_c = c4(dispatch.astype(cd), _P(g_ent, None, None, None))
    combine_c = c4(combine.astype(cd), _P(g_ent, None, None, None))
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch_c, xg)  # (E, G, C, d)
    expert_in = c4(expert_in, _P(e_ax, g_ent, None, None))
    gate = jnp.einsum("egcd,edf->egcf", expert_in, wg)
    up = jnp.einsum("egcd,edf->egcf", expert_in, wu)
    fn = jax.nn.silu if cfg.act == "silu" else functools.partial(
        jax.nn.gelu, approximate=True
    )
    h = c4(fn(gate) * up, _P(e_ax, g_ent, None, f_ax))
    out = jnp.einsum("egcf,efd->egcd", h, wd)
    out = c4(out, _P(e_ax, g_ent, None, None))
    y = jnp.einsum("gsec,egcd->gsd", combine_c, out)
    return y.reshape(b, s, d).astype(x.dtype)


# --------------------------------------------------------------------- #
# Expert-parallel shard_map implementation (pod mesh).
# --------------------------------------------------------------------- #
def _capacity(tokens: int, k: int, num_experts: int, factor: float) -> int:
    c = int(tokens * k / num_experts * factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _local_dispatch(xf: Array, topk_p: Array, topk_i: Array, num_experts: int,
                    capacity: int):
    """Build the (E, C, d) capacity buffer + combine metadata, locally.

    Returns (buffer, slot_expert, slot_pos, slot_weight, slot_token, keep).
    """
    t, k = topk_i.shape
    flat_e = topk_i.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    offsets = jnp.cumsum(counts) - counts  # start of each expert's run
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = pos_in_e < capacity
    tok_of_slot = (order // k).astype(jnp.int32)
    # Dropped slots point one past the buffer; scatter mode="drop" discards
    # them (never colliding with a legitimate slot).
    safe_pos = jnp.where(keep, pos_in_e, capacity).astype(jnp.int32)
    buf = jnp.zeros((num_experts, capacity, xf.shape[-1]), xf.dtype)
    buf = buf.at[sorted_e, safe_pos].set(xf[tok_of_slot], mode="drop")
    # Clamp for the gather on the combine side (weights zero the dropped).
    safe_pos = jnp.minimum(safe_pos, capacity - 1)
    weight = topk_p.reshape(-1)[order] * keep  # (t*k,) fp32
    return buf, sorted_e, safe_pos, weight, tok_of_slot, keep


def moe_ffn_ep(
    x: Array,
    w_router: Array,
    wg: Array,
    wu: Array,
    wd: Array,
    cfg: ModelConfig,
    mesh,
    *,
    batch_axes: tuple[str, ...] = (),
    expert_axis: str,
    tp_axis: str | None,
) -> Array:
    """Expert-parallel MoE under shard_map (manual ONLY over expert/tp).

    The batch/client axes stay in GSPMD-auto mode, so this composes under
    the client-vmap of the federated runtime. Sharding contract:
      x  : replicated over expert×tp (batch axes auto)
      w_router : replicated
      wg/wu : P(expert_axis, None, tp_axis) ; wd : P(expert_axis, tp_axis, None)
    """
    num_experts, k = cfg.num_experts, cfg.experts_per_token
    e_shards = mesh.shape[expert_axis]
    e_local = num_experts // e_shards
    assert e_local * e_shards == num_experts, (
        f"{cfg.name}: {num_experts} experts not divisible by expert axis "
        f"{e_shards}"
    )

    def body(x_l, wr_l, wg_l, wu_l, wd_l):
        b_l, s, d = x_l.shape
        t = b_l * s
        xf = x_l.reshape(t, d)
        topk_p, topk_i = router_topk(xf, wr_l, k)
        cap = _capacity(t, k, num_experts, cfg.moe_capacity_factor)
        buf, sorted_e, safe_pos, weight, tok_of_slot, keep = _local_dispatch(
            xf, topk_p, topk_i, num_experts, cap
        )
        # Slice this shard's experts out of the (replicated-over-expert-axis)
        # capacity buffer — dispatch costs no collective.
        e_idx = jax.lax.axis_index(expert_axis)
        my = jax.lax.dynamic_slice_in_dim(buf, e_idx * e_local, e_local, axis=0)
        out_l = _expert_ffn(my, wg_l, wu_l, wd_l, cfg.act)  # (E_l, C, d_partial)
        if tp_axis is not None:
            out_l = jax.lax.psum(out_l, tp_axis)  # reduce ff-sharded down-proj
        # Write local experts' outputs back into a full (E, C, d) frame and
        # sum across expert shards (the combine collective).
        frame = jnp.zeros((num_experts, cap, d), out_l.dtype)
        frame = jax.lax.dynamic_update_slice_in_dim(frame, out_l, e_idx * e_local, 0)
        frame = jax.lax.psum(frame, expert_axis)
        # Gather back to token order and weight-combine.
        slot_out = frame[sorted_e, safe_pos].astype(jnp.float32)
        slot_out = slot_out * (weight * keep)[:, None]
        y = jnp.zeros((t, d), jnp.float32).at[tok_of_slot].add(slot_out)
        return y.reshape(b_l, s, d).astype(x_l.dtype)

    del batch_axes  # auto axes: never named in the specs
    manual = {expert_axis} | ({tp_axis} if tp_axis else set())
    in_specs = (
        P(None, None, None),
        P(None, None),
        P(expert_axis, None, tp_axis),
        P(expert_axis, None, tp_axis),
        P(expert_axis, tp_axis, None),
    )
    out_specs = P(None, None, None)
    return jax.shard_map(
        body, mesh=mesh, axis_names=manual, in_specs=in_specs,
        out_specs=out_specs, check_vma=False,
    )(x, w_router, wg, wu, wd)
