"""Parameter-table machinery: one declaration produces init, abstract
shapes (for the dry-run) and logical sharding axes (for dist/sharding.py).

Every parameter is declared once as ``ParamDecl(shape, axes, init)`` where
``axes`` is a tuple of *logical* axis names (same length as shape):

  "layers"   — stacked scan dim, never sharded
  "vocab"    — vocabulary (embedding/lm-head rows)
  "embed"    — d_model features
  "heads"    — attention query heads  (sharded attn_tp-way per arch)
  "kv"       — kv heads               (replicated)
  "head_dim" — per-head features      (replicated)
  "mlp"      — FFN hidden             (sharded over the full model factor)
  "experts"  — MoE expert dim         (sharded over the expert factor)
  "expert_mlp" — per-expert FFN hidden (sharded over the tp factor)
  "ssm"      — SSM inner channels     (sharded over the full model factor)
  None       — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # "normal" | "zeros" | "ones" | "normal_out"
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    # For stacked (layers, in, ..., out) weights, fan-in is the product of
    # all dims except the leading "layers" stack and the trailing out dim.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return max(math.prod(shape[:-1]) // (shape[0] if len(shape) > 2 else 1), 1)


def init_param(decl: ParamDecl, key: Array) -> Array:
    dtype = jnp.dtype(decl.dtype)
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    std = 1.0 / math.sqrt(_fan_in(decl.shape))
    if decl.init == "normal_out":  # output-layer init, smaller
        std = std / 2.0
    return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(dtype)


def init_tree(decls, key: Array):
    """Initialize a pytree of ParamDecl into concrete arrays."""
    flat, treedef = jax.tree.flatten(decls, is_leaf=lambda x: isinstance(x, ParamDecl))
    keys = jax.random.split(key, len(flat))
    vals = [init_param(d, k) for d, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_tree(decls):
    """ParamDecl pytree -> ShapeDtypeStruct pytree (dry-run params)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def axes_tree(decls):
    """ParamDecl pytree -> logical-axes pytree (same structure)."""
    return jax.tree.map(
        lambda d: d.axes, decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )
