"""Decoder-only LM trunk covering the dense / MoE / hybrid / VLM families.

Layer-stacked parameters (leading ``num_layers`` dim) + ``lax.scan`` keep the
HLO small and compile times flat in depth (train/prefill). The decode path
unrolls layers instead so KV-cache updates stay in-place-friendly
(scan ys would copy the full cache every layer).

Per-layer attention windows and RoPE thetas ride along the scan as (L,)
arrays, which is how one code path serves full-causal, SWA and gemma-style
local:global interleaves.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import GLOBAL, Family, ModelConfig
from repro.models.layers import (
    attention_decode,
    apply_rope,
    gated_mlp,
    rms_norm,
    select_attention,
)
from repro.models.params import ParamDecl, axes_tree, init_tree, shape_tree

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution context threaded through model apply functions.

    mesh/axis names are needed only by the expert-parallel MoE path; the
    default (None) selects single-host implementations everywhere.
    """

    mesh: Any = None
    batch_axes: tuple[str, ...] = ("data",)
    expert_axis: str | None = None
    tp_axis: str | None = None
    moe_impl: str = "dropless"  # "reference" | "dropless" | "gshard" | "ep"
    # Mesh axes the MoE token-group dim is sharded over *inside* the current
    # calling context (under the client-vmap that's the intra-slot axes).
    moe_group_axes: tuple[str, ...] = ()


# --------------------------------------------------------------------- #
# Parameter declarations
# --------------------------------------------------------------------- #
def param_decls(cfg: ModelConfig):
    L, d, H, Hkv, hd = (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.head_dim,
    )
    ff, V = cfg.d_ff, cfg.padded_vocab
    pd = cfg.param_dtype

    layers: dict[str, ParamDecl] = {
        "attn_norm": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "mlp_norm": ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
        "wq": ParamDecl((L, d, H, hd), ("layers", "embed", "heads", "head_dim"), "normal", pd),
        "wk": ParamDecl((L, d, Hkv, hd), ("layers", "embed", "kv", "head_dim"), "normal", pd),
        "wv": ParamDecl((L, d, Hkv, hd), ("layers", "embed", "kv", "head_dim"), "normal", pd),
        "wo": ParamDecl((L, H, hd, d), ("layers", "heads", "head_dim", "embed"), "normal_out", pd),
    }
    if cfg.qkv_bias:
        layers["bq"] = ParamDecl((L, H, hd), ("layers", "heads", "head_dim"), "zeros", pd)
        layers["bk"] = ParamDecl((L, Hkv, hd), ("layers", "kv", "head_dim"), "zeros", pd)
        layers["bv"] = ParamDecl((L, Hkv, hd), ("layers", "kv", "head_dim"), "zeros", pd)
    if cfg.qk_norm:
        layers["q_norm"] = ParamDecl((L, hd), ("layers", "head_dim"), "zeros", pd)
        layers["k_norm"] = ParamDecl((L, hd), ("layers", "head_dim"), "zeros", pd)

    if cfg.num_experts:
        layers["w_router"] = ParamDecl((L, d, cfg.num_experts), ("layers", "embed", None), "normal", pd)
        layers["we_gate"] = ParamDecl((L, cfg.num_experts, d, ff), ("layers", "experts", "embed", "expert_mlp"), "normal", pd)
        layers["we_up"] = ParamDecl((L, cfg.num_experts, d, ff), ("layers", "experts", "embed", "expert_mlp"), "normal", pd)
        layers["we_down"] = ParamDecl((L, cfg.num_experts, ff, d), ("layers", "experts", "expert_mlp", "embed"), "normal_out", pd)
    else:
        layers["w_gate"] = ParamDecl((L, d, ff), ("layers", "embed", "mlp"), "normal", pd)
        layers["w_up"] = ParamDecl((L, d, ff), ("layers", "embed", "mlp"), "normal", pd)
        layers["w_down"] = ParamDecl((L, ff, d), ("layers", "mlp", "embed"), "normal_out", pd)

    if cfg.family is Family.HYBRID:
        di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        layers.update(
            ssm_norm=ParamDecl((L, d), ("layers", "embed"), "zeros", pd),
            ssm_in=ParamDecl((L, d, 2 * di), ("layers", "embed", "ssm"), "normal", pd),
            ssm_conv=ParamDecl((L, di, cfg.ssm_conv), ("layers", "ssm", None), "normal", pd),
            ssm_xproj=ParamDecl((L, di, dtr + 2 * st), ("layers", "ssm", None), "normal", pd),
            ssm_dtproj=ParamDecl((L, dtr, di), ("layers", None, "ssm"), "normal", pd),
            ssm_a_log=ParamDecl((L, di, st), ("layers", "ssm", None), "zeros", "float32"),
            ssm_d=ParamDecl((L, di), ("layers", "ssm"), "ones", "float32"),
            ssm_dt_bias=ParamDecl((L, di), ("layers", "ssm"), "zeros", "float32"),
            ssm_out=ParamDecl((L, di, d), ("layers", "ssm", "embed"), "normal_out", pd),
        )

    decls = {
        "embed": ParamDecl((V, d), ("vocab", "embed"), "normal", pd),
        "layers": layers,
        "final_norm": ParamDecl((d,), ("embed",), "zeros", pd),
    }
    if not cfg.tie_embeddings:
        decls["lm_head"] = ParamDecl((d, V), ("embed", "vocab"), "normal_out", pd)
    return decls


def init_params(cfg: ModelConfig, key: Array):
    return init_tree(param_decls(cfg), key)


def param_shapes(cfg: ModelConfig):
    return shape_tree(param_decls(cfg))


def param_axes(cfg: ModelConfig):
    return axes_tree(param_decls(cfg))


# --------------------------------------------------------------------- #
# Per-layer metadata (scanned alongside params)
# --------------------------------------------------------------------- #
def static_layer_meta(cfg: ModelConfig, i: int):
    """Python-static (window, rope_theta) for layer i — lets unrolled paths
    trigger the static-window kv-chunk skipping in chunked attention."""
    w = cfg.layer_windows()[i]
    theta = cfg.rope_theta_global if w == GLOBAL else cfg.rope_theta_local
    return int(w), float(theta)


def layer_meta(cfg: ModelConfig):
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)  # (L,), GLOBAL=-1
    thetas = jnp.where(
        windows == GLOBAL,
        jnp.float32(cfg.rope_theta_global),
        jnp.float32(cfg.rope_theta_local),
    )
    return windows, thetas


# --------------------------------------------------------------------- #
# Layer body
# --------------------------------------------------------------------- #
def _attn_block(
    lp, cfg: ModelConfig, x: Array, positions: Array, window, theta,
    kv_override=None,
):
    """Self-attention sub-block. x: (B,S,d) pre-normed input.

    Returns (out (B,S,d), (k, v)) — k/v returned for cache construction.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.rms_eps)
        k = rms_norm(k, lp["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    if kv_override is not None:
        k, v = kv_override
    out = select_attention(
        cfg.attn_impl,
        q,
        k,
        v,
        positions,
        positions,
        window,
        chunk_q=cfg.attn_chunk_q,
        chunk_kv=cfg.attn_chunk_kv,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
    return out, (k, v)


def _ffn_block(lp, cfg: ModelConfig, x: Array, runtime: Runtime):
    if not cfg.num_experts:
        return gated_mlp(x, lp["w_gate"], lp["w_up"], lp["w_down"], cfg.act)
    if runtime.moe_impl == "ep":
        return moe_mod.moe_ffn_ep(
            x, lp["w_router"], lp["we_gate"], lp["we_up"], lp["we_down"], cfg,
            runtime.mesh, batch_axes=runtime.batch_axes,
            expert_axis=runtime.expert_axis, tp_axis=runtime.tp_axis,
        )
    if runtime.moe_impl == "gshard":
        return moe_mod.moe_ffn_gshard(
            x, lp["w_router"], lp["we_gate"], lp["we_up"], lp["we_down"], cfg,
            mesh=runtime.mesh, expert_axis=runtime.expert_axis,
            group_axes=runtime.moe_group_axes, tp_axis=runtime.tp_axis,
        )
    fn = (
        moe_mod.moe_ffn_dropless
        if runtime.moe_impl == "dropless"
        else moe_mod.moe_ffn_reference
    )
    return fn(x, lp["w_router"], lp["we_gate"], lp["we_up"], lp["we_down"], cfg)


def _ssm_branch(lp, cfg: ModelConfig, x: Array, state=None, conv_state=None):
    """Mamba-style branch for the hybrid family (full-sequence form).

    x: (B,S,d) pre-normed. Returns (out (B,S,d), final_state, final_conv).
    """
    b, s, d = x.shape
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x @ lp["ssm_in"]  # (B,S,2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    # Depthwise short conv along time (causal).
    w = lp["ssm_conv"].astype(jnp.float32)  # (di, conv)
    pad = cfg.ssm_conv - 1
    xpad = jnp.pad(xs.astype(jnp.float32), ((0, 0), (pad, 0), (0, 0)))
    if conv_state is not None:
        xpad = jax.lax.dynamic_update_slice(xpad, conv_state, (0, 0, 0))
    cols = [xpad[:, i : i + s, :] * w[:, i] for i in range(cfg.ssm_conv)]
    xc = jax.nn.silu(sum(cols)).astype(x.dtype)
    final_conv = xpad[:, s : s + pad, :] if pad else jnp.zeros((b, 0, di))

    proj = xc @ lp["ssm_xproj"]  # (B,S,dtr+2st)
    dt_r, b_in, c_in = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ lp["ssm_dtproj"] + lp["ssm_dt_bias"])
    y, s_final = ssm_mod.selective_scan(
        xc, dt, lp["ssm_a_log"], b_in, c_in, lp["ssm_d"], initial_state=state
    )
    y = y * jax.nn.silu(z)
    return y @ lp["ssm_out"], s_final, final_conv


def _layer_fwd(
    lp, cfg: ModelConfig, x: Array, positions: Array, window, theta,
    runtime: Runtime,
):
    """One transformer block (train/prefill form). Returns (x', (k, v))."""
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    attn_out, kv = _attn_block(lp, cfg, h, positions, window, theta)
    if cfg.family is Family.HYBRID:
        hs = rms_norm(x, lp["ssm_norm"], cfg.rms_eps)
        ssm_out, _, _ = _ssm_branch(lp, cfg, hs)
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out
    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + _ffn_block(lp, cfg, h, runtime)
    return x, kv


# --------------------------------------------------------------------- #
# Forward / loss
# --------------------------------------------------------------------- #
def embed_inputs(params, cfg: ModelConfig, tokens=None, embeds=None):
    """Token ids and/or precomputed frontend embeddings -> (B, S, d).

    VLM/audio stubs: ``embeds`` (patch/frame embeddings) are prepended to
    the embedded text tokens (DESIGN.md §5)."""
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.dtype(cfg.compute_dtype)))
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def forward_hidden(
    params, cfg: ModelConfig, *, tokens=None, embeds=None, runtime=Runtime(),
    return_kv: bool = False,
):
    """Full-sequence forward. Returns hidden (B,S,d) [, stacked (k, v)]."""
    x = embed_inputs(params, cfg, tokens, embeds)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    windows, thetas = layer_meta(cfg)

    layer = functools.partial(_layer_fwd, cfg=cfg, runtime=runtime)
    use_block = cfg.remat and cfg.scan_block > 1 and not return_kv
    if cfg.remat:
        policy = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            "none": None,
        }[cfg.remat_policy]
        # Per-layer checkpoint stays on in block mode too: during a block's
        # backward recompute it bounds residuals to one layer's carry
        # instead of the layer's full intermediate set.
        layer = jax.checkpoint(layer, policy=policy, static_argnums=())

    if cfg.scan_layers and use_block:
        # Nested remat-scan: outer scan over layer BLOCKS with a
        # checkpointed body, plain inner scan within the block. Saved
        # residuals: L/block block-inputs instead of ~3 stacks of L
        # per-layer carries (see EXPERIMENTS.md §Perf memory iteration).
        blk = cfg.scan_block
        nb = cfg.num_layers // blk
        assert nb * blk == cfg.num_layers, (cfg.num_layers, blk)

        def reshape_xs(z):
            return z.reshape((nb, blk) + z.shape[1:])

        xs_blocked = jax.tree.map(
            reshape_xs, (params["layers"], windows, thetas)
        )

        @jax.checkpoint
        def block_body(carry, xs_blk):
            def inner(c, xs_one):
                lp, window, theta = xs_one
                y, _ = layer(
                    lp, x=c, positions=positions, window=window, theta=theta
                )
                return y, None

            y, _ = jax.lax.scan(inner, carry, xs_blk)
            return y, None

        x, kvs = jax.lax.scan(block_body, x, xs_blocked)
    elif cfg.scan_layers:
        def scan_body(carry, xs):
            lp, window, theta = xs
            y, kv = layer(lp, x=carry, positions=positions, window=window, theta=theta)
            return y, (kv if return_kv else None)

        x, kvs = jax.lax.scan(scan_body, x, (params["layers"], windows, thetas))
    else:
        kvs_list = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            w_i, th_i = static_layer_meta(cfg, i)
            # Bind the python-int window BEFORE jax.checkpoint: a checkpoint
            # wrapper would trace it to a scalar and defeat the
            # static-window kv-chunk skipping in chunked attention.
            layer_i = functools.partial(
                _layer_fwd, cfg=cfg, runtime=runtime, window=w_i, theta=th_i
            )
            if cfg.remat:
                layer_i = jax.checkpoint(
                    layer_i, policy=jax.checkpoint_policies.nothing_saveable
                )
            x, kv = layer_i(lp, x=x, positions=positions)
            kvs_list.append(kv)
        kvs = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *kvs_list)
            if return_kv
            else None
        )

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return (x, kvs) if return_kv else x


def _head_logits(params, cfg: ModelConfig, h: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ w).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:  # mask padded rows to -inf
        pad_bias = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30
        )
        logits = logits + pad_bias
    return logits


def lm_loss(
    params, cfg: ModelConfig, *, tokens=None, embeds=None, targets, loss_mask=None,
    runtime=Runtime(),
):
    """Next-token cross-entropy, sequence-chunked so full (B,S,V) logits are
    never materialized (decisive for the 152k–262k vocab archs)."""
    h = forward_hidden(params, cfg, tokens=tokens, embeds=embeds, runtime=runtime)
    # Align hidden states with targets: targets correspond to the LAST
    # `targets.shape[1]` positions' next-token predictions.
    tlen = targets.shape[1]
    h = h[:, -tlen:]
    return _chunked_ce(params, cfg, h, targets, loss_mask)


def _chunked_ce(params, cfg: ModelConfig, h: Array, targets: Array, loss_mask):
    """Sequence-chunked cross-entropy over (possibly vocab-sharded) logits."""
    tlen = targets.shape[1]
    if loss_mask is None:
        loss_mask = jnp.ones(targets.shape, jnp.float32)

    def ce(h_c, t_c, m_c):
        logits = _head_logits(params, cfg, h_c)  # (B, C, V) fp32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m_c
        return jnp.sum(nll), jnp.sum(m_c)

    chunk = cfg.loss_chunk
    if not chunk or tlen <= chunk:
        total, count = ce(h, targets, loss_mask)
    else:
        n = -(-tlen // chunk)
        pad = n * chunk - tlen

        def prep(a, fill=0):
            if pad:
                cfg_pad = ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)
                a = jnp.pad(a, cfg_pad, constant_values=fill)
            return jnp.moveaxis(
                a.reshape((a.shape[0], n, chunk) + a.shape[2:]), 1, 0
            )

        @jax.checkpoint
        def chunk_step(carry, xs):
            tot, cnt = carry
            h_c, t_c, m_c = xs
            s, c = ce(h_c, t_c, m_c)
            return (tot + s, cnt + c), None

        (total, count), _ = jax.lax.scan(
            chunk_step,
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (prep(h), prep(targets), prep(loss_mask)),
        )
    return total / jnp.maximum(count, 1.0)


# --------------------------------------------------------------------- #
# Serving: prefill + single-token decode
# --------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.family is Family.HYBRID:
        cache["ssm_state"] = jnp.zeros(
            (L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32
        )
        cache["conv_state"] = jnp.zeros(
            (L, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32
        )
    return cache


def prefill(
    params, cfg: ModelConfig, *, tokens=None, embeds=None, cache_len: int,
    runtime=Runtime(),
):
    """Run the full prompt, return (last-position logits, populated cache)."""
    if cfg.family is Family.HYBRID:
        return _prefill_unrolled(
            params, cfg, tokens=tokens, embeds=embeds, cache_len=cache_len,
            runtime=runtime,
        )
    h, (k, v) = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, runtime=runtime,
        return_kv=True,
    )
    s = k.shape[2]
    batch = k.shape[1]
    pad = cache_len - s
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    logits = _head_logits(params, cfg, h[:, -1:])
    cache = {"k": k, "v": v, "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


def _prefill_unrolled(params, cfg, *, tokens, embeds, cache_len, runtime):
    """Hybrid prefill: also materializes SSM/conv states (unrolled layers)."""
    x = embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    windows, thetas = layer_meta(cfg)
    cache = init_cache(cfg, b, cache_len)
    ks, vs, sss, ccs = [], [], [], []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        w_i, th_i = static_layer_meta(cfg, i)
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        attn_out, (k, v) = _attn_block(lp, cfg, h, positions, w_i, th_i)
        hs = rms_norm(x, lp["ssm_norm"], cfg.rms_eps)
        ssm_out, s_state, c_state = _ssm_branch(lp, cfg, hs)
        x = x + 0.5 * (attn_out + ssm_out)
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        x = x + _ffn_block(lp, cfg, h, runtime)
        ks.append(k)
        vs.append(v)
        sss.append(s_state)
        ccs.append(c_state)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    k = jnp.stack(ks)
    v = jnp.stack(vs)
    pad = cache_len - s
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": k,
        "v": v,
        "pos": jnp.asarray(s, jnp.int32),
        "ssm_state": jnp.stack(sss),
        "conv_state": jnp.stack(ccs),
    }
    return _head_logits(params, cfg, x[:, -1:]), cache


def _replicate_small(x, runtime: Runtime):
    """Pin a small per-token tensor to fully-replicated.

    In decode, the new-token q/k/v inherit the HEAD sharding of their
    projections while the KV cache is SEQUENCE-sharded; GSPMD resolves that
    conflict by replicating *the cache* per layer ("involuntary full
    rematerialization", ~600 GB/device at 32k). Replicating the ~1 MB
    per-token tensors instead forces flash-decode semantics: each shard
    scores its cache chunk and the softmax merges via small psums."""
    if runtime is None or runtime.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(runtime.mesh, P())
    )


def decode_step(params, cfg: ModelConfig, cache, tokens, runtime=Runtime()):
    """One-token decode. tokens: (B, 1) int32. Returns (logits, new cache).

    Layers are unrolled (see module docstring); the KV cache sequence axis
    may be sharded — attention_decode's reductions then become the
    flash-decode cross-shard all-reduces."""
    pos = cache["pos"]
    x = embed_inputs(params, cfg, tokens=tokens)
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    windows, thetas = layer_meta(cfg)
    new_cache = dict(cache)
    k_all, v_all = cache["k"], cache["v"]
    ss_all = cache.get("ssm_state")
    cs_all = cache.get("conv_state")

    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.rms_eps)
            k = rms_norm(k, lp["k_norm"], cfg.rms_eps)
        w_i, th_i = static_layer_meta(cfg, i)
        q = apply_rope(q, positions, th_i)
        k = apply_rope(k, positions, th_i)
        q = _replicate_small(q, runtime)
        k = _replicate_small(k, runtime)
        v = _replicate_small(v, runtime)
        k_all = jax.lax.dynamic_update_slice(k_all, k[None], (i, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_all, v[None], (i, 0, pos, 0, 0))
        out = attention_decode(
            q, k_all[i], v_all[i], jnp.full((b,), pos, jnp.int32), w_i
        )
        # Cut backward propagation of wo's head sharding into the cache
        # (see _replicate_small): the (B,1,H,hd) result is tiny.
        out = _replicate_small(out, runtime)
        attn_out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        if cfg.family is Family.HYBRID:
            hs = rms_norm(x, lp["ssm_norm"], cfg.rms_eps)
            ssm_out, ss_new, cs_new = _ssm_decode_step(lp, cfg, hs, ss_all[i], cs_all[i])
            ss_all = ss_all.at[i].set(ss_new)
            cs_all = cs_all.at[i].set(cs_new)
            attn_out = 0.5 * (attn_out + ssm_out)
        x = x + attn_out
        h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
        x = x + _ffn_block(lp, cfg, h, runtime)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = _head_logits(params, cfg, x)
    new_cache["k"], new_cache["v"] = k_all, v_all
    new_cache["pos"] = pos + 1
    if cfg.family is Family.HYBRID:
        new_cache["ssm_state"], new_cache["conv_state"] = ss_all, cs_all
    return logits, new_cache


def _ssm_decode_step(lp, cfg, x, ssm_state, conv_state):
    """Single-step hybrid SSM branch. x: (B, 1, d)."""
    b = x.shape[0]
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x[:, 0] @ lp["ssm_in"]  # (B, 2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    # Roll the conv window: conv_state (B, conv-1, di) holds previous inputs.
    w = lp["ssm_conv"].astype(jnp.float32)  # (di, conv)
    hist = jnp.concatenate(
        [conv_state.astype(jnp.float32), xs.astype(jnp.float32)[:, None, :]], axis=1
    )  # (B, conv, di)
    xc = jax.nn.silu(jnp.einsum("bci,ic->bi", hist, w)).astype(x.dtype)
    new_conv = hist[:, 1:]
    proj = xc @ lp["ssm_xproj"]
    dt_r, b_in, c_in = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ lp["ssm_dtproj"] + lp["ssm_dt_bias"])
    y, s_new = ssm_mod.selective_scan_step(
        xc, dt, lp["ssm_a_log"], b_in, c_in, lp["ssm_d"], ssm_state
    )
    y = y * jax.nn.silu(z)
    return (y @ lp["ssm_out"])[:, None], s_new, new_conv
