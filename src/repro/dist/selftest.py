"""Fake-device sharded-round self-test (run as a SUBPROCESS).

Backs an N-device host mesh with XLA's fake CPU devices, compiles one
FedFog round with the full ShardingRules wiring, verifies via
``analyze_hlo`` that the round body contains exactly ONE inter-client
all-reduce carrying the model-delta payload (the paper's communication
contract), and — with ``--check`` — executes the sharded round next to a
plain single-device round on identical inputs and compares metrics and
updated parameters within float tolerance.

MUST run in its own process: the fake-device flag has to be set before
jax initializes its backend, which is why the integration test
(tests/test_sharded_round.py) and the dryrun-sharding benchmark both
invoke ``python -m repro.dist.selftest --json ...``.
"""
import os
import sys

if __name__ == "__main__":  # set BEFORE any jax import in this process
    _n = "8"
    for _i, _a in enumerate(sys.argv):
        if _a == "--devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time


def run_selftest(
    arch: str = "llama3.2-1b",
    devices: int = 8,
    *,
    check: bool = True,
    seq_len: int = 64,
    batch_per_slot: int = 4,
    rounds: int = 1,
    zero: int | None = None,
    pallas_agg: bool = False,
    gates: str = "legacy",
    fog_nodes: int = 1,
    population: int | None = None,
    faults_check: bool = False,
) -> dict:
    """Compile (and optionally execute + cross-check) one sharded round.

    ``pallas_agg=True`` turns on ``use_pallas_agg`` so the sharded round
    routes through the shard_map'd delta-pipeline kernel; ``gates``
    picks the server-pipeline config: "legacy" = the historical default
    (FedAvgM, nothing else), "plain" = bare FedAvg (every kernel gate
    off), "full" = DP + momentum + compression all on.

    ``fog_nodes > 1`` requests the hierarchical edge → fog → cloud
    reduction: the plan goes multi-pod (the pod axis is the fog tier, so
    ``fog_nodes`` must equal the pod count) and the HLO contract check
    asserts one delta-sized all-reduce PER TIER. ``population`` sizes
    the virtual client registry (cohort-sampled rounds).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.configs.shapes import concrete_batch, ShapeSpec
    from repro.dist.hlo_analysis import (
        analyze_hlo,
        assert_inter_client_contract,
        inter_client_all_reduces,
    )
    from repro.dist.sharding import make_rules
    from repro.fl import FLConfig, init_fl_state, make_round_fn
    from repro.models import Runtime, build_model

    assert len(jax.devices()) >= devices, (
        f"need {devices} devices, have {len(jax.devices())} — run via "
        "python -m repro.dist.selftest (it sets XLA_FLAGS pre-import)"
    )
    # float32 end-to-end so the sharded/unsharded comparison is tight.
    cfg = get_reduced(
        arch, loss_chunk=0, param_dtype="float32", compute_dtype="float32"
    )
    model = build_model(cfg)
    rules = make_rules(
        None, cfg, multi_pod=fog_nodes > 1, device_count=devices, zero=zero
    )
    plan = rules.plan

    if gates == "full":
        gate_kw = dict(
            server_optimizer="fedavgm",
            clip_norm=1.0,
            dp_sigma=1e-3,
            compression="int8",
        )
    elif gates == "plain":  # bare FedAvg: every server-pipeline gate off
        gate_kw = dict(server_optimizer="fedavg")
    elif gates == "legacy":
        gate_kw = dict(server_optimizer="fedavgm")
    else:
        raise ValueError(f"unknown gates preset {gates!r}")
    fl_cfg = FLConfig(
        num_clients=max(2 * plan.num_clients, 8),
        slots=plan.num_clients,
        local_steps=1,
        inner_optimizer="sgdm",
        use_pallas_agg=pallas_agg,
        fog_nodes=fog_nodes,
        population=population,
        **gate_kw,
    )
    global_batch = plan.num_clients * batch_per_slot
    shape = ShapeSpec("selftest", "train", seq_len, global_batch)

    key = jax.random.PRNGKey(0)
    k_state, k_data, k_tel = jax.random.split(key, 3)
    state = init_fl_state(model, fl_cfg, k_state)
    n = fl_cfg.num_clients
    batch = dict(concrete_batch(cfg, shape, k_data))
    ks = jax.random.split(k_tel, 6)
    batch.update(
        slot_data_sizes=jax.random.uniform(
            ks[0], (fl_cfg.slots,), minval=10.0, maxval=100.0
        ),
        telemetry_cpu=jax.random.uniform(ks[1], (n,), minval=0.1, maxval=0.5),
        telemetry_mem=jax.random.uniform(ks[2], (n,), minval=0.1, maxval=0.5),
        telemetry_batt=jax.random.uniform(ks[3], (n,), minval=0.5, maxval=1.0),
        telemetry_energy=jax.random.uniform(ks[4], (n,), minval=0.0, maxval=0.1),
        hist=jax.random.dirichlet(
            ks[5], jnp.ones((fl_cfg.hist_bins,)), (n,)
        ),
    )

    tokens_per_client = seq_len * batch_per_slot
    flops = model.flops_per_token() * tokens_per_client

    # ---- sharded program ---------------------------------------------- #
    round_sharded = make_round_fn(
        model, fl_cfg, Runtime(mesh=rules.mesh, batch_axes=rules.batch_axes),
        flops_per_client_round=flops, rules=rules,
    )
    state_shardings = rules.shardings(rules.fl_state_specs(model, state))
    batch_shardings = rules.fl_batch_shardings(batch)

    jitted = jax.jit(
        round_sharded,
        in_shardings=(state_shardings, batch_shardings),
    )
    t0 = time.time()
    lowered = jitted.lower(state, batch)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    hlo = analyze_hlo(compiled.as_text())
    # The delta aggregation moves whole-model bytes; metric scalars don't.
    inter_client, _ = inter_client_all_reduces(hlo, rules, model.param_count())
    # The per-tier contract applies to the HIERARCHICAL implementation
    # (shard_map kernel: one explicit psum per tier). The reference fog
    # path under rules is GSPMD-scheduled — it legally fuses the
    # two-level segment reduction into the flat single all-reduce, so it
    # is held to the flat contract.
    contract_fog = fog_nodes if pallas_agg else 1
    contract_err = None
    try:
        assert_inter_client_contract(
            hlo, rules, model.param_count(), fog_nodes=contract_fog
        )
    except AssertionError as e:
        contract_err = str(e)
    result = {
        "arch": arch,
        "devices": devices,
        "pallas_agg": pallas_agg,
        "gates": gates,
        "fog_nodes": fog_nodes,
        "population": population,
        "contract_error": contract_err,
        "plan": {
            "num_clients": plan.num_clients,
            "zero": plan.zero,
            "model_axes": list(plan.model_axes),
            "model_split": list(plan.model_split),
        },
        "compile_s": round(compile_s, 2),
        "collective_counts": hlo.collectives.count_by_kind,
        "collective_bytes": {
            k: round(v) for k, v in hlo.collectives.bytes_by_kind.items()
        },
        "inter_client_all_reduces": inter_client,
        # Union-crossing count: flat contract is 1; the fog tiers are
        # one per level (edge psum + fog psum), both crossing the union.
        "ok": (
            contract_err is None
            and inter_client == (2 if contract_fog > 1 else 1)
        ),
    }
    if not check:
        return result

    # ---- equivalence: sharded vs single-device ------------------------ #
    # Same fl_cfg → with pallas_agg on, this compares the shard_map'd
    # kernel against the UNSHARDED kernel on one device.
    round_plain = jax.jit(
        make_round_fn(model, fl_cfg, Runtime(), flops_per_client_round=flops)
    )
    s_sh, s_pl = state, state
    for _ in range(rounds):
        s_sh, m_sh = compiled(s_sh, batch) if rounds == 1 else jitted(s_sh, batch)
        s_pl, m_pl = round_plain(s_pl, batch)
    diffs = {
        k: abs(float(m_sh[k]) - float(m_pl[k]))
        for k in m_pl
    }
    import numpy as np

    def _max_diff(sa, sb):
        flat_a = jax.tree.leaves(jax.device_get(sa.params))
        flat_b = jax.tree.leaves(jax.device_get(sb.params))
        return max(
            float(np.max(np.abs(a.astype(np.float64) - b.astype(np.float64))))
            for a, b in zip(flat_a, flat_b)
        )

    max_param_diff = _max_diff(s_sh, s_pl)
    metrics_ok = all(
        v <= 1e-3 * (1.0 + abs(float(m_pl[k]))) for k, v in diffs.items()
    )
    result.update(
        metric_diffs={k: float(f"{v:.3e}") for k, v in diffs.items()},
        max_param_diff=max_param_diff,
        loss=float(m_pl["loss"]),
        equivalence_ok=bool(metrics_ok and max_param_diff < 1e-4),
    )
    if pallas_agg:
        # Third leg: the pure-reference round (kernel off everywhere)
        # must also agree — sharded kernel == unsharded kernel == ref.
        round_ref = jax.jit(
            make_round_fn(
                model,
                dataclasses.replace(fl_cfg, use_pallas_agg=False),
                Runtime(),
                flops_per_client_round=flops,
            )
        )
        s_rf = state
        for _ in range(rounds):
            s_rf, _m_rf = round_ref(s_rf, batch)
        ref_diff = _max_diff(s_sh, s_rf)
        result["max_param_diff_ref"] = ref_diff
        result["equivalence_ok"] = bool(
            result["equivalence_ok"] and ref_diff < 1e-4
        )
    result["ok"] = bool(result["ok"] and result["equivalence_ok"])

    if faults_check:
        # Fault-layer contract on the shard_map path (repro.sim.faults):
        # (a) an all-off FaultConfig leaves the sharded round BITWISE
        # identical to a build without the fault field, and (b) a
        # faulted sharded round matches the faulted single-device round
        # to the same tolerance as the clean equivalence check (the
        # fault plan is drawn at global level — only the aggregation it
        # feeds is shard_map'd).
        from repro.sim.faults import FaultConfig

        def sharded_round(flc):
            fn = make_round_fn(
                model, flc,
                Runtime(mesh=rules.mesh, batch_axes=rules.batch_axes),
                flops_per_client_round=flops, rules=rules,
            )
            return jax.jit(
                fn, in_shardings=(state_shardings, batch_shardings)
            )

        s_a, m_a = jitted(state, batch)
        s_b, m_b = sharded_round(
            dataclasses.replace(fl_cfg, faults=FaultConfig())
        )(state, batch)
        bit_diff = _max_diff(s_a, s_b)
        shared = set(m_a) & set(m_b)
        metrics_bit_ok = all(
            float(m_a[k]) == float(m_b[k]) for k in shared
        )
        fl_f = dataclasses.replace(
            fl_cfg,
            faults=FaultConfig(
                crash_rate=0.3, max_retries=2, corrupt_rate=0.2,
                quorum_frac=0.25,
            ),
        )
        # The main selftest batch deliberately fails the Eq. 3 gate
        # (nobody admitted — participation is irrelevant to the HLO and
        # equivalence checks above). The fault contract needs admitted
        # clients, so this leg feeds healthy, energy-rich telemetry.
        batch_f = dict(batch)
        batch_f.update(
            telemetry_cpu=jnp.full((n,), 0.9, jnp.float32),
            telemetry_mem=jnp.full((n,), 0.9, jnp.float32),
            telemetry_batt=jnp.full((n,), 0.95, jnp.float32),
            telemetry_energy=jnp.full((n,), 0.9, jnp.float32),
        )
        round_fs = sharded_round(fl_f)
        round_fp = jax.jit(
            make_round_fn(
                model, fl_f, Runtime(), flops_per_client_round=flops
            )
        )
        counter_keys = (
            "fault_dispatched", "fault_completed", "fault_terminal",
            "fault_lost", "fault_retries",
        )
        s_fs = s_fp = state
        counters = dict.fromkeys(counter_keys, 0)
        for _ in range(2):
            s_fs, m_fs = round_fs(s_fs, batch_f)
            s_fp, m_fp = round_fp(s_fp, batch_f)
            for k in counter_keys:
                counters[k] += int(m_fs[k])
        result.update(
            faults_bitwise_ok=bool(bit_diff == 0.0 and metrics_bit_ok),
            faults_equiv_diff=_max_diff(s_fs, s_fp),
            faults_conserved=bool(
                counters["fault_dispatched"]
                == counters["fault_completed"]
                + counters["fault_terminal"]
                + counters["fault_lost"]
            ),
            faults_counters=counters,
        )
        result["ok"] = bool(
            result["ok"]
            and result["faults_bitwise_ok"]
            and result["faults_conserved"]
            and result["faults_equiv_diff"] < 1e-4
        )
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--no-check", action="store_true",
                    help="compile + HLO analysis only (no execution)")
    ap.add_argument("--pallas-agg", action="store_true",
                    help="route through the sharded delta-pipeline kernel")
    ap.add_argument("--gates", default="legacy",
                    choices=("legacy", "plain", "full"),
                    help="server-pipeline gate preset")
    ap.add_argument("--fog-nodes", type=int, default=1,
                    help="fog-tier width (multi-pod plan; pod axis = fog)")
    ap.add_argument("--population", type=int, default=None,
                    help="virtual client registry size (cohort sampling)")
    ap.add_argument("--faults-check", action="store_true",
                    help="also verify the fault layer on the sharded "
                         "round: faults-off bitwise identity + faulted "
                         "sharded == faulted single-device")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    res = run_selftest(
        args.arch, args.devices, check=not args.no_check,
        seq_len=args.seq_len, zero=args.zero,
        pallas_agg=args.pallas_agg, gates=args.gates,
        fog_nodes=args.fog_nodes, population=args.population,
        faults_check=args.faults_check,
    )
    if args.json:
        print(json.dumps(res))
    else:
        for k, v in res.items():
            print(f"{k}: {v}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
