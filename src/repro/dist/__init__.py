"""Distribution layer: mesh plans, sharding rules, HLO accounting.

    meshes.py        MeshPlan / plan_for — per-arch axis factorizations
    sharding.py      ShardingRules / make_rules — logical→mesh PartitionSpecs
    hlo_analysis.py  analyze_hlo / count_axis_crossing — post-compile stats
    selftest.py      fake-device sharded-round equivalence worker
"""
from repro.dist.hlo_analysis import (
    CollectiveStats,
    HLOAnalysis,
    analyze_hlo,
    count_axis_crossing,
    inter_client_all_reduces,
)
from repro.dist.meshes import MeshPlan, plan_for
from repro.dist.sharding import ShardingRules, make_rules

__all__ = [
    "CollectiveStats",
    "HLOAnalysis",
    "MeshPlan",
    "ShardingRules",
    "analyze_hlo",
    "count_axis_crossing",
    "inter_client_all_reduces",
    "make_rules",
    "plan_for",
]
