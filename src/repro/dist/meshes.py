"""Mesh plans: how a device pool factorizes into FedFog's parallel axes.

The pod-scale round (fl/round.py) distributes over FOUR kinds of axes:

    pod      inter-pod replica axis (multi-pod only; size 2)
    client   concurrent FL cohort slots — the stacked per-slot replicas of
             the global model live here; Eq. 6's aggregation is the ONE
             collective that crosses it
    zero     intra-slot data/ZeRO axis — each slot's local batch and (with
             ``fsdp_params``) its parameters/moments shard here
    model    two tensor axes: ("expert","tp") for MoE archs,
             ("tp","sp") otherwise

A :class:`MeshPlan` is pure arithmetic — importing this module never
touches jax device state; :meth:`MeshPlan.build_mesh` is the only call
that does. The production contract (launch/mesh.py) is 256 chips/pod as
16 data × 16 model; ``plan_for`` refines that into the axes above with
per-arch divisibility (expert count, head count) and supports scaled-down
``device_count`` plans for CPU hosts backed by XLA's fake devices.
"""
from __future__ import annotations

import dataclasses
import math

from repro.models.config import ModelConfig

# Production contract (launch/mesh.py): per-pod data × model factorization.
DATA_PER_POD = 16
MODEL_PER_POD = 16
DEFAULT_ZERO = 2


def _largest_divisor(budget: int, dim: int) -> int:
    """Largest divisor of ``budget`` that also divides ``dim``."""
    for c in sorted((d for d in range(1, budget + 1) if budget % d == 0),
                    reverse=True):
        if dim % c == 0:
            return c
    return 1


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Axis factorization of one training/serving device pool.

    ``num_clients`` is the TOTAL slot count across pods (the stacked
    leading dim of per-slot params); per-pod it is ``num_clients //
    num_pods``. Invariants (asserted in tests/test_sharding_rules.py):

        num_clients * zero == num_pods * DATA_PER_POD   (production plans)
        model_split[0] * model_split[1] == MODEL_PER_POD
        num_experts % model_split[0] == 0               (MoE archs)
        num_heads   % model_split[0] == 0               (dense archs, tp>1)
    """

    num_pods: int
    num_clients: int  # total across pods
    zero: int
    model_axes: tuple[str, str]
    model_split: tuple[int, int]
    fsdp_params: bool = True

    # ------------------------------------------------------------------ #
    @property
    def multi_pod(self) -> bool:
        return self.num_pods > 1

    @property
    def client_axes(self) -> tuple[str, ...]:
        """Mesh axes the stacked slot dim shards over."""
        return ("pod", "client") if self.multi_pod else ("client",)

    @property
    def data_axes(self) -> tuple[str, ...]:
        """Mesh axes a serving batch dim shards over (all non-model axes)."""
        return self.client_axes + ("zero",)

    @property
    def axis_names(self) -> tuple[str, ...]:
        base = ("pod",) if self.multi_pod else ()
        return base + ("client", "zero") + self.model_axes

    @property
    def axis_sizes(self) -> tuple[int, ...]:
        base = (self.num_pods,) if self.multi_pod else ()
        return base + (
            self.num_clients // self.num_pods,
            self.zero,
        ) + self.model_split

    @property
    def shape(self) -> dict[str, int]:
        return dict(zip(self.axis_names, self.axis_sizes))

    @property
    def device_count(self) -> int:
        return math.prod(self.axis_sizes)

    # ------------------------------------------------------------------ #
    def build_mesh(self, devices=None):
        """Materialize the plan as a jax Mesh (first ``device_count``
        local devices unless an explicit device array is given)."""
        import jax
        import numpy as np

        if devices is None:
            return jax.make_mesh(self.axis_sizes, self.axis_names)
        devs = np.asarray(devices).reshape(self.axis_sizes)
        return jax.sharding.Mesh(devs, self.axis_names)


def plan_for(
    cfg: ModelConfig,
    *,
    multi_pod: bool = False,
    device_count: int | None = None,
    zero: int | None = None,
) -> MeshPlan:
    """Compute the per-arch mesh plan.

    Default (``device_count=None``) is the production pool: 256 chips per
    pod as (client·zero=16) × (model=16), doubled along a leading ``pod``
    axis when ``multi_pod``. An explicit ``device_count`` builds a scaled
    host plan with NO model parallelism (client·zero = device_count) —
    the shape used by fake-device CPU runs and the 8-device integration
    test.

    Model-axis factorization:
      * MoE archs: ``("expert", "tp")`` with the expert axis the largest
        16-divisor of ``num_experts`` (moonshot 64→16·1, mixtral 8→8·2).
      * Everything else: ``("tp", "sp")`` with tp the largest 16-divisor
        of the head count (rwkv6's heads are ``d_model//64``); archs whose
        head count resists 2-powers (hymba's 25) get tp=1 and lean on the
        ``sp`` axis for ffn/vocab/state dims.
    """
    num_pods = 2 if multi_pod else 1

    if device_count is None:
        data_per_pod = DATA_PER_POD
        model_total = MODEL_PER_POD
    else:
        if device_count % num_pods:
            raise ValueError(
                f"device_count {device_count} not divisible by {num_pods} pods"
            )
        data_per_pod = device_count // num_pods
        model_total = 1  # scaled host plans skip tensor parallelism

    z = zero if zero is not None else (
        DEFAULT_ZERO if data_per_pod % DEFAULT_ZERO == 0 else 1
    )
    if data_per_pod % z:
        raise ValueError(f"zero={z} does not divide data axis {data_per_pod}")
    clients_per_pod = data_per_pod // z

    if cfg.num_experts:
        e = _largest_divisor(model_total, cfg.num_experts)
        model_axes, model_split = ("expert", "tp"), (e, model_total // e)
    else:
        # rwkv6 has no attention heads; its head-sharded dims are d_model
        # in units of the fixed 64-wide rwkv head.
        heads = cfg.num_heads or max(cfg.d_model // 64, 1)
        t = _largest_divisor(model_total, heads)
        model_axes, model_split = ("tp", "sp"), (t, model_total // t)

    return MeshPlan(
        num_pods=num_pods,
        num_clients=clients_per_pod * num_pods,
        zero=z,
        model_axes=model_axes,
        model_split=model_split,
    )
