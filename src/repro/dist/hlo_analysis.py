"""Post-compile HLO accounting: collectives, dot FLOPs, HBM traffic.

``analyze_hlo(compiled.as_text())`` parses the optimized HLO module text —
no XLA internals, just the stable text format — and returns per-kind
collective counts/bytes plus dot-FLOP and memory-traffic estimates. The
launch dry-run records these per (arch × shape × mesh) cell, and the
sharded train path uses :func:`count_axis_crossing` to assert the FedFog
round contains exactly the paper's ONE inter-client all-reduce.

Collectives inside while-loop bodies are counted ONCE (static texts carry
no trip counts); such ops are surfaced in ``trip_count_warnings`` so the
per-round byte totals are read with the right caveat.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

# Bytes per element for HLO primitive types.
_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

# op name (with async -start variants normalized) -> canonical kind
_COLLECTIVE_KINDS = {
    "all-reduce": "all-reduce",
    "all-gather": "all-gather",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-broadcast": "collective-broadcast",
    "ragged-all-to-all": "all-to-all",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}0-9]+?))\s+"
    r"([\w\-]+)\("
)
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([\d,{} ]*)\}")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of one HLO result type (sums tuple elements)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
        total += _DTYPE_BYTES[dtype] * numel
    return total


def _shape_dims(type_str: str) -> tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",") if d) if dims else ()


def _parse_groups(line: str) -> list[list[int]] | None:
    """Replica groups from either text form; None = no groups attr
    (convention: one group spanning every participant)."""
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return [
            [int(x) for x in g.split(",") if x.strip()]
            for g in re.findall(r"\{([\d, ]*)\}", m.group(1))
        ]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return ids.reshape(ng, gs).tolist()
    m = _SRC_TGT_RE.search(line)
    if m:  # collective-permute: each pair is a 2-group
        pairs = re.findall(r"\{(\d+),\s*(\d+)\}", m.group(1))
        return [[int(a), int(b)] for a, b in pairs]
    return None


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    name: str
    kind: str
    bytes: float
    computation: str
    groups: list[list[int]] | None  # None = all participants together
    in_loop_body: bool = False


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    ops: tuple[CollectiveOp, ...]

    @property
    def count_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for op in self.ops:
            out[op.kind] = out.get(op.kind, 0) + 1
        return out

    @property
    def bytes_by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for op in self.ops:
            out[op.kind] = out.get(op.kind, 0.0) + op.bytes
        return out

    @property
    def total_bytes(self) -> float:
        return sum(op.bytes for op in self.ops)

    @property
    def trip_count_warnings(self) -> list[str]:
        return [
            f"{op.kind} {op.name} ({op.bytes:.2e} B) inside loop body "
            f"{op.computation}: bytes counted once, executes per iteration"
            for op in self.ops
            if op.in_loop_body
        ]


@dataclasses.dataclass(frozen=True)
class HLOAnalysis:
    collectives: CollectiveStats
    dot_flops: float  # 2·M·N·K over every dot (fusion bodies included)
    hbm_bytes: float  # entry args + outputs + materialized fusion results
    hbm_bytes_in: float
    hbm_bytes_out: float
    num_instructions: int


def analyze_hlo(hlo_text: str) -> HLOAnalysis:
    """Parse one optimized HLO module's text into traffic/compute stats."""
    shapes: dict[str, str] = {}  # instr name -> type string
    instrs: list[tuple[str, str, str, str, str]] = []  # comp, name, type, op, line
    comp = ""
    loop_bodies: set[str] = set()

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        # Computation header: "%name (params...) -> type {" (or ENTRY ...);
        # no "=" before the parameter list, ends with an opening brace.
        if (
            line.endswith("{")
            and "(" in line
            and "=" not in line.split("(", 1)[0]
        ):
            cm = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if cm:
                comp = cm.group(1)
                continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, opcode = im.groups()
        shapes[name] = type_str
        instrs.append((comp, name, type_str, opcode, line))
        if opcode == "while":
            bm = re.search(r"body=%?([\w.\-]+)", line)
            if bm:
                loop_bodies.add(bm.group(1))

    ops: list[CollectiveOp] = []
    dot_flops = 0.0
    entry_params = 0.0
    entry_out = 0.0
    fusion_bytes = 0.0
    entry_comp = instrs[0][0] if instrs else ""
    # The ENTRY computation is the one whose line in the text is marked
    # ENTRY; _COMP_RE can't see the marker after .match groups, so find it
    # directly.
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    if em:
        entry_comp = em.group(1)

    for comp, name, type_str, opcode, line in instrs:
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if opcode.endswith("-done"):
            continue  # async pair: counted at -start
        if base in _COLLECTIVE_KINDS:
            ops.append(
                CollectiveOp(
                    name=name,
                    kind=_COLLECTIVE_KINDS[base],
                    bytes=_shape_bytes(type_str),
                    computation=comp,
                    groups=_parse_groups(line),
                    in_loop_body=comp in loop_bodies,
                )
            )
        elif base == "dot":
            dims = _shape_dims(type_str)
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            # First operand: "dot(f32[8,16]{1,0} %arg0, ..." or "dot(arg0, ..."
            lhs_name = None
            if "dot(" in line:
                inner = line.split("dot(", 1)[1]
                pm = re.search(r"%([\w.\-]+)", inner)
                if pm is not None and pm.start() < inner.find(")"):
                    lhs_name = pm.group(1)
                else:  # typeless operand form: names only, commas top-level
                    first = inner.split(",", 1)[0].strip()
                    lhs_name = first.split()[-1] if first else None
            if cm is not None and lhs_name in shapes:
                lhs_dims = _shape_dims(shapes[lhs_name])
                k = math.prod(
                    lhs_dims[int(i)]
                    for i in cm.group(1).split(",")
                    if i and int(i) < len(lhs_dims)
                )
                dot_flops += 2.0 * math.prod(dims or (0,)) * k
        elif opcode == "parameter":
            if comp == entry_comp:
                entry_params += _shape_bytes(type_str)
        elif base in ("fusion", "custom-call"):
            fusion_bytes += _shape_bytes(type_str)
        if comp == entry_comp and line.lstrip().startswith("ROOT"):
            entry_out = _shape_bytes(type_str)

    return HLOAnalysis(
        collectives=CollectiveStats(ops=tuple(ops)),
        dot_flops=dot_flops,
        hbm_bytes=entry_params + entry_out + fusion_bytes,
        hbm_bytes_in=entry_params,
        hbm_bytes_out=entry_out,
        num_instructions=len(instrs),
    )


def inter_client_all_reduces(
    analysis: HLOAnalysis, rules, param_count: int
) -> tuple[int, float]:
    """Count all-reduces that cross the plan's client axes AND carry the
    model-delta payload (≥ half the fused f32 delta bytes, which filters
    the metric-scalar traffic). The FedFog contract is exactly ONE such
    op per round when the client axes span more than one device; callers
    should skip the check when ``delta_bytes`` is returned with a
    single-way client axis (count is 0 by construction there).

    Returns (count, delta_bytes).
    """
    mesh_shape = rules.mesh.shape
    delta_bytes = 4.0 * param_count / max(mesh_shape.get("zero", 1), 1)
    count = count_axis_crossing(
        analysis,
        rules.mesh,
        axes=rules.plan.client_axes,
        kinds=("all-reduce",),
        min_bytes=0.5 * delta_bytes,
    )
    return count, delta_bytes


def _fog_axis_split(mesh, client_axes, fog_nodes: int):
    """Split the client axes into a fog-tier prefix and an edge-tier
    suffix: ``fog_nodes`` must equal the device product of a leading
    prefix of ``client_axes`` (mirrors kernels.delta_pipeline
    ``split_fog_axes``, re-derived here so dist stays dependency-free).
    Returns (fog_axes, edge_axes)."""
    prod = 1
    for i, a in enumerate(client_axes):
        if prod == fog_nodes:
            return tuple(client_axes[:i]), tuple(client_axes[i:])
        prod *= int(mesh.shape.get(a, 1))
    if prod == fog_nodes:
        return tuple(client_axes), ()
    raise ValueError(
        f"fog_nodes={fog_nodes} is not the device product of a leading "
        f"prefix of client axes {tuple(client_axes)} (mesh {dict(mesh.shape)})"
    )


def assert_inter_client_contract(
    analysis: HLOAnalysis, rules, param_count: int, fog_nodes: int = 1
) -> tuple[int, float]:
    """Post-compile guard for the paper's §III communication contract:
    exactly ONE delta-sized all-reduce crosses the client axes per
    compiled round. No-op (count 0 by construction) when the client
    axes span a single device. Returns (count, delta_bytes) so callers
    can log what they checked. Raises AssertionError on violation —
    both the reference fused-buffer aggregation and the sharded
    delta-pipeline kernel path must satisfy it.

    With ``fog_nodes > 1`` the contract becomes per-tier: the client
    axes split into a fog prefix and an edge suffix, and the compiled
    round must carry exactly ONE delta-sized all-reduce confined to the
    edge axes (the fog-local partial sum; zero when the edge suffix
    spans a single device) plus exactly ONE crossing the fog axes (the
    cloud combine). Returns (edge_count + fog_count, delta_bytes)."""
    count, delta_bytes = inter_client_all_reduces(analysis, rules, param_count)
    ways = getattr(rules, "client_ways", None)
    if ways is None:
        ways = math.prod(
            int(rules.mesh.shape.get(a, 1)) for a in rules.plan.client_axes
        )
    if fog_nodes > 1 and ways > 1:
        fog_axes, edge_axes = _fog_axis_split(
            rules.mesh, rules.plan.client_axes, fog_nodes
        )
        min_bytes = 0.5 * delta_bytes
        edge_ways = math.prod(
            int(rules.mesh.shape.get(a, 1)) for a in edge_axes
        )
        edge_count = count_axis_crossing(
            analysis, rules.mesh, axes=edge_axes,
            kinds=("all-reduce",), min_bytes=min_bytes, not_axes=fog_axes,
        )
        fog_count = count_axis_crossing(
            analysis, rules.mesh, axes=fog_axes,
            kinds=("all-reduce",), min_bytes=min_bytes, not_axes=edge_axes,
        )
        want_edge = 1 if edge_ways > 1 else 0
        if edge_count != want_edge or fog_count != 1:
            raise AssertionError(
                f"fog-tier collective contract violated: found "
                f"{edge_count} edge-tier (axes {edge_axes}, expected "
                f"{want_edge}) and {fog_count} fog-tier (axes "
                f"{fog_axes}, expected 1) delta-sized "
                f"({delta_bytes:.0f}B) all-reduces"
            )
        return edge_count + fog_count, delta_bytes
    if ways > 1 and count != 1:
        raise AssertionError(
            f"inter-client all-reduce contract violated: found {count} "
            f"delta-sized ({delta_bytes:.0f}B) all-reduces crossing "
            f"{tuple(rules.plan.client_axes)}, expected exactly 1"
        )
    return count, delta_bytes


def count_axis_crossing(
    analysis: HLOAnalysis,
    mesh,
    axes=("client",),
    kinds=("all-reduce",),
    min_bytes: float = 0.0,
    not_axes=(),
) -> int:
    """Number of collectives whose replica groups CROSS the given mesh
    axes — i.e. some group contains two devices with different coordinates
    along one of ``axes``. Partition ids index ``mesh.devices`` flattened
    row-major (the jit/GSPMD device-assignment order).

    ``min_bytes`` filters metric-scalar traffic so the model-delta
    aggregation can be isolated (the paper's one inter-client collective).
    ``not_axes`` additionally requires the op to stay CONFINED to slices
    of those axes (no group crosses them) — this is how the fog contract
    tells a tier-local psum from one flat all-reduce spanning both tiers.
    """
    names = list(mesh.axis_names)
    sizes = [int(mesh.shape[a]) for a in names]
    idxs = [names.index(a) for a in axes if a in names]
    not_idxs = [names.index(a) for a in not_axes if a in names]
    if not idxs:
        return 0
    total = math.prod(sizes)

    def crosses(groups, which) -> bool:
        if groups is None:
            return any(sizes[i] > 1 for i in which)
        for g in groups:
            coords = np.array(np.unravel_index(np.asarray(g) % total, sizes))
            for i in which:
                if len(set(coords[i].tolist())) > 1:
                    return True
        return False

    return sum(
        1
        for op in analysis.collectives.ops
        if op.kind in kinds
        and op.bytes >= min_bytes
        and crosses(op.groups, idxs)
        and not (not_idxs and crosses(op.groups, not_idxs))
    )
