"""Sharding rules: logical param/batch/cache axes → mesh PartitionSpecs.

The models declare LOGICAL axes per parameter dim (``ParamDecl.axes`` —
"embed", "heads", "mlp", "experts", …). :class:`ShardingRules` maps those
onto the :class:`~repro.dist.meshes.MeshPlan` mesh axes with a rule table
plus a divisibility guard: an axis is only taken when its size divides the
dim (GQA kv heads smaller than tp, hymba's 25 heads, etc. fall back to
replication instead of failing to lower).

Rule table (production plans; size-1 axes drop out automatically):

    embed       zero            (param FSDP — off when ``plan.fsdp_params``
                                 is False or ``fsdp=False`` for serving)
    heads/kv    tp
    head_dim    sp
    mlp/vocab/ssm   tp, sp      (joint — the big ffn/vocab dims absorb the
                                 full 16-way model split)
    experts     expert
    expert_mlp  tp
    layers / None   replicated  (layers is the scan-carried stack dim)

Stacked FL params (``stacked=True``) prepend the slot axis sharded over
``plan.client_axes`` — the layout whose aggregation is the round's ONE
inter-client all-reduce. Batch specs shard the batch dim over all data
axes; decode caches fall back to SEQUENCE-parallel sharding when the
batch dim is unshardable (the long_500k cells with global_batch=1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from jax.sharding import PartitionSpec as P

from repro.dist.meshes import MeshPlan, plan_for
from repro.models.config import ModelConfig

# Logical axis -> ordered mesh-axis candidates. Axes are taken greedily
# left-to-right while the running product divides the dim.
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "layers": (),
    "embed": ("zero",),  # FSDP; dropped when fsdp is off
    "heads": ("tp",),
    "kv": ("tp",),
    "head_dim": ("sp",),
    "mlp": ("tp", "sp"),
    "vocab": ("tp", "sp"),
    "ssm": ("tp", "sp"),
    "experts": ("expert",),
    "expert_mlp": ("tp",),
}


def _flat_with_axes(shapes, laxes):
    """Zip a ShapeDtypeStruct tree with its logical-axes tree.

    ``axes_tree`` leaves are tuples (which jax.tree would descend into),
    so both trees are flattened explicitly with matching is_leaf guards.
    """
    import jax

    flat_s, treedef = jax.tree.flatten(shapes)
    flat_a, _ = jax.tree.flatten(
        laxes, is_leaf=lambda x: isinstance(x, tuple)
    )
    assert len(flat_s) == len(flat_a), (len(flat_s), len(flat_a))
    return flat_s, flat_a, treedef


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    cfg: ModelConfig
    plan: MeshPlan
    mesh: Any  # jax.sharding.Mesh (or anything exposing .shape: dict)

    # ------------------------------------------------------------------ #
    # Axis helpers
    # ------------------------------------------------------------------ #
    def _axis_size(self, name: str) -> int:
        return int(self.mesh.shape.get(name, 1))

    def _present(self, axes) -> tuple[str, ...]:
        return tuple(a for a in axes if self._axis_size(a) > 1)

    def _as_spec_entry(self, axes):
        """Mesh-axis tuple -> PartitionSpec entry (size-1 axes dropped)."""
        axes = self._present(axes)
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes

    def _take_axes(self, candidates, dim: int, used: set[str]):
        """Greedy divisible prefix of ``candidates`` for a dim of extent
        ``dim``; each mesh axis is used at most once per spec."""
        chosen: list[str] = []
        prod = 1
        for a in candidates:
            size = self._axis_size(a)
            if size <= 1 or a in used:
                continue
            if dim % (prod * size):
                continue
            chosen.append(a)
            prod *= size
        used.update(chosen)
        if not chosen:
            return None
        return chosen[0] if len(chosen) == 1 else tuple(chosen)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        """Intra-slot data axes — how a per-slot batch shards inside the
        client vmap of the FL round."""
        return self._present(("zero",))

    @property
    def client_ways(self) -> int:
        """Total mesh extent the client/slot axis is sharded over."""
        prod = 1
        for a in self._present(self.plan.client_axes):
            prod *= self._axis_size(a)
        return prod

    def fused_delta_spec(self, p_total: int | None = None, *,
                         shard_p: bool = True):
        """PartitionSpec for the fused (C, P) client-delta buffer: the
        client dim over the plan's client axes, the P dim over zero when
        it divides (the reference one-all-reduce aggregation layout).
        ``shard_p=False`` keeps P whole per client shard — the layout
        the sharded delta-pipeline kernel consumes (each shard needs its
        clients' full rows for exact clip norms / compression tables)."""
        from jax.sharding import PartitionSpec as P

        z = "zero" if shard_p and self._axis_size("zero") > 1 else None
        if z is not None and p_total is not None and p_total % self._axis_size("zero"):
            z = None
        return P(self._as_spec_entry(self.plan.client_axes), z)

    def fused_delta_sharding(self, p_total: int | None = None, *,
                             shard_p: bool = True):
        from jax.sharding import NamedSharding

        return NamedSharding(
            self.mesh, self.fused_delta_spec(p_total, shard_p=shard_p)
        )

    @property
    def serve_batch_axes(self) -> tuple[str, ...]:
        """All data axes — how a serving batch dim shards (no slot stack)."""
        return self._present(self.plan.data_axes)

    # ------------------------------------------------------------------ #
    # Parameters / optimizer state
    # ------------------------------------------------------------------ #
    def param_specs(self, shapes, laxes, *, stacked: bool = False,
                    fsdp: bool | None = None):
        """PartitionSpec tree for a param tree.

        ``stacked=True`` prepends the per-slot replica axis (sharded over
        ``plan.client_axes``) — the FL round's in-flight layout.
        ``fsdp`` overrides ``plan.fsdp_params`` (serving passes False: no
        ZeRO sharding of weights on the decode path).
        """
        import jax

        use_fsdp = self.plan.fsdp_params if fsdp is None else fsdp
        client_entry = (
            self._as_spec_entry(self.plan.client_axes) if stacked else None
        )
        flat_s, flat_a, treedef = _flat_with_axes(shapes, laxes)

        specs = []
        for sds, axes in zip(flat_s, flat_a):
            assert len(axes) == len(sds.shape), (axes, sds.shape)
            used: set[str] = set(self.plan.client_axes) if stacked else set()
            entries = []
            for dim, name in zip(sds.shape, axes):
                rule = LOGICAL_RULES.get(name, ()) if name else ()
                if not use_fsdp:
                    rule = tuple(a for a in rule if a != "zero")
                entries.append(self._take_axes(rule, dim, used))
            if stacked:
                entries = [client_entry] + entries
            specs.append(P(*entries))
        return jax.tree.unflatten(treedef, specs)

    def opt_spec_tree(self, shapes, laxes, *, stacked: bool = False):
        """Specs for one optimizer-moment tree (mirrors the params: ZeRO
        moments shard exactly like the weights they track)."""
        return self.param_specs(shapes, laxes, stacked=stacked, fsdp=True)

    # ------------------------------------------------------------------ #
    # Batches
    # ------------------------------------------------------------------ #
    def _data_prod(self) -> int:
        prod = 1
        for a in self.serve_batch_axes:
            prod *= self._axis_size(a)
        return prod

    def train_batch_specs(self, specs: Mapping[str, Any]) -> dict[str, P]:
        """Global (slot-major) train inputs: batch dim over ALL data axes
        (pod × client × zero); the round reshapes to (slots, per_slot) and
        re-pins with ``constrain_batch``."""
        entry = self._as_spec_entry(self.plan.data_axes)
        prod = self._data_prod()
        out = {}
        for k, sds in specs.items():
            dims = tuple(sds.shape)
            if entry is not None and dims and dims[0] % prod == 0:
                out[k] = P(entry, *([None] * (len(dims) - 1)))
            else:
                out[k] = P()
        return out

    def serve_batch_specs(self, specs: Mapping[str, Any]) -> dict[str, P]:
        """Serving inputs: batch dim over all data axes; batch-unshardable
        cells (long-context, global_batch=1) fall back to sharding the
        sequence dim (sequence-parallel prefill/decode)."""
        entry = self._as_spec_entry(self.plan.data_axes)
        prod = self._data_prod()
        out = {}
        for k, sds in specs.items():
            dims = tuple(sds.shape)
            if entry is None or not dims:
                out[k] = P()
            elif dims[0] % prod == 0:
                out[k] = P(entry, *([None] * (len(dims) - 1)))
            elif len(dims) >= 2 and dims[1] % prod == 0 and dims[1] >= prod:
                out[k] = P(None, entry, *([None] * (len(dims) - 2)))
            else:
                out[k] = P()
        return out

    # ------------------------------------------------------------------ #
    # Decode caches
    # ------------------------------------------------------------------ #
    def cache_specs(self, cache):
        """Specs for a decode-cache tree.

        Cache leaves are (layers, batch, ...) stacks: prefer sharding the
        batch dim (dim 1) over the data axes; when the batch is too small
        (long_500k's global_batch=1) shard the largest remaining dim —
        the sequence for KV caches (sequence-parallel decode), the state/
        feature dim for O(1)-state families (rwkv/ssm). The leading layer
        stack is never sharded.
        """
        import jax

        entry = self._as_spec_entry(self.plan.data_axes)
        prod = self._data_prod()

        def one(sds):
            dims = tuple(sds.shape)
            if entry is None or len(dims) < 3:
                return P()
            none = [None] * len(dims)
            if dims[1] % prod == 0 and dims[1] >= prod:
                none[1] = entry
                return P(*none)
            # largest shardable trailing dim, never dim 0 (layers)
            rest = sorted(range(2, len(dims)), key=lambda i: -dims[i])
            for i in rest:
                if dims[i] % prod == 0 and dims[i] >= prod:
                    none[i] = entry
                    return P(*none)
            return P()

        return jax.tree.map(one, cache)

    # ------------------------------------------------------------------ #
    # FL round wiring (shared by launch/train, launch/dryrun, selftest)
    # ------------------------------------------------------------------ #
    def fl_state_specs(self, model, state_abs):
        """PartitionSpec FLState for the round's carried state: params and
        server moments via the rule table, scheduler/rng scalars
        replicated. ``state_abs`` is an abstract (or concrete) FLState —
        only ``server_mu is None`` is read from it."""
        import jax

        from repro.fl.state import FLState

        shapes, laxes = model.param_shapes(), model.param_axes()
        rep = P()
        return FLState(
            params=self.param_specs(shapes, laxes, stacked=False),
            server_mu=(
                self.opt_spec_tree(shapes, laxes, stacked=False)
                if state_abs.server_mu is not None
                else None
            ),
            server_count=rep,
            sched=jax.tree.map(lambda _: rep, state_abs.sched),
            rng=rep,
            step=rep,
        )

    def fl_batch_shardings(self, batch):
        """NamedShardings for a round-batch dict: model inputs (tokens /
        patch_embeds / frames) over the data axes, the (N-client)
        scheduler inputs replicated."""
        from jax.sharding import NamedSharding

        model_in = {
            k: batch[k]
            for k in ("tokens", "patch_embeds", "frames")
            if k in batch
        }
        out = {
            k: NamedSharding(self.mesh, v)
            for k, v in self.train_batch_specs(model_in).items()
        }
        rep = self.replicated()
        for k in batch:
            out.setdefault(k, rep)
        return out

    # ------------------------------------------------------------------ #
    # NamedSharding constructors
    # ------------------------------------------------------------------ #
    def shardings(self, spec_tree):
        """PartitionSpec tree -> NamedSharding tree on this mesh."""
        import jax
        from jax.sharding import NamedSharding

        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    def replicated(self):
        from jax.sharding import NamedSharding

        return NamedSharding(self.mesh, P())


def make_rules(
    mesh,
    cfg: ModelConfig,
    *,
    multi_pod: bool = False,
    zero: int | None = None,
    device_count: int | None = None,
) -> ShardingRules:
    """Build the plan + plan-shaped mesh + rules for one config.

    ``mesh`` may be the production (pod ×) data × model mesh from
    launch/mesh.py — its devices are re-laid-out onto the plan's axes —
    or None to allocate ``plan.device_count`` local devices directly.
    """
    plan = plan_for(
        cfg, multi_pod=multi_pod, device_count=device_count, zero=zero
    )
    if mesh is None:
        mesh = plan.build_mesh()
    elif tuple(getattr(mesh, "axis_names", ())) != plan.axis_names:
        import numpy as np

        devs = np.asarray(mesh.devices)
        if devs.size != plan.device_count:
            raise ValueError(
                f"mesh has {devs.size} devices; plan needs {plan.device_count}"
            )
        mesh = plan.build_mesh(devs.reshape(plan.axis_sizes))
    return ShardingRules(cfg=cfg, plan=plan, mesh=mesh)
