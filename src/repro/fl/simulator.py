"""Paper-scale FedFog simulator: N edge clients, small models, full DES.

This is the engine behind the paper-table benchmarks (EXPERIMENTS.md
§Paper-fidelity): EMNIST-like / HAR-like tasks, the complete scheduler
(Eqs. 1-12), telemetry + FaaS latency/energy simulation, drift injection,
attacks, and all four policies (FedFog / RCS / FogFaaS / Vanilla FL).

Unlike the pod-scale runtime (fl/round.py) which maps clients onto mesh
slots, here ALL N clients are vmapped — at MLP scale that is the fastest
way to simulate a 100-device deployment on one host, and it keeps the
simulator exactly faithful to the paper's synchronous-round semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg_mod
from repro.core import privacy as privacy_mod
from repro.core.scheduler import SchedulerConfig, account_energy, schedule_round
from repro.core.selection import random_selection_mask
from repro.core.types import init_scheduler_state
from repro.data import emnist_like, har_like
from repro.data.telemetry import (
    TelemetryConfig,
    init_telemetry,
    make_profiles,
    step_telemetry,
)
from repro.fl import attacks as attacks_mod
from repro.fl.compression import apply_compression, wire_bytes_per_param
from repro.sim.faas import FaasSimConfig, round_energy_j, round_times_ms

Array = jax.Array


# --------------------------------------------------------------------- #
# Small model (MLP) for the edge tasks
# --------------------------------------------------------------------- #
def mlp_init(key: Array, sizes: tuple[int, ...]):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append(
            {
                "w": jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5,
                "b": jnp.zeros((b,)),
            }
        )
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def _ce_loss(params, x, y, num_classes):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# --------------------------------------------------------------------- #
# Simulator
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    task: str = "emnist"  # "emnist" | "har"
    num_clients: int = 64
    rounds: int = 50
    local_epochs: int = 3  # E in Eq. 5
    local_batch: int = 32
    lr: float = 0.05  # η in Eq. 5
    policy: str = "fedfog"  # fedfog | rcs | fogfaas | vanilla
    top_k: int | None = 24  # participation budget per round
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    telemetry: TelemetryConfig | None = None
    faas: FaasSimConfig = dataclasses.field(default_factory=FaasSimConfig)
    drift_period: int = 0  # inject drift every k rounds (0 = off)
    attack: str = "none"
    attack_fraction: float = 0.0
    # Calibrated so Table V reproduces the paper's severity ordering:
    # model_replacement > label_flip > noise > dropout.
    attack_noise_scale: float = 0.05
    attack_replacement_scale: float = 1.0
    compression: str = "none"
    dp_sigma: float = 0.0
    clip_norm: float = 0.0
    server_lr: float = 1.0
    hidden: tuple[int, ...] = (128, 64)
    seed: int = 0

    def data_cfg(self):
        if self.task == "emnist":
            return emnist_like.EmnistLikeConfig(
                drift_period=self.drift_period, seed=self.seed
            )
        return har_like.HarLikeConfig(drift_period=self.drift_period, seed=self.seed)

    def dims(self):
        if self.task == "emnist":
            return 28 * 28, 62
        return har_like.WINDOW * har_like.CHANNELS, har_like.NUM_CLASSES


class FedFogSimulator:
    def __init__(self, cfg: SimulatorConfig):
        self.cfg = cfg
        self.data_cfg = cfg.data_cfg()
        in_dim, n_cls = cfg.dims()
        self.num_classes = n_cls
        self.sizes = (in_dim,) + cfg.hidden + (n_cls,)
        self.tel_cfg = cfg.telemetry or TelemetryConfig(
            num_clients=cfg.num_clients, seed=cfg.seed
        )
        self.profiles = make_profiles(self.tel_cfg)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = mlp_init(key, self.sizes)
        self.n_params = sum(
            int(jnp.size(l)) for l in jax.tree.leaves(self.params)
        )
        self.sched_state = init_scheduler_state(
            cfg.num_clients, n_cls, cfg.scheduler.theta_e
        )
        # Bootstrap the drift reference with the true round-0 distributions,
        # otherwise round 0 flags every client as "drifted" vs the uniform
        # prior and selects nobody.
        import dataclasses as _dc

        self.sched_state = _dc.replace(
            self.sched_state,
            prev_hist=self._histograms(jnp.zeros((), jnp.int32)),
        )
        self.telemetry = init_telemetry(self.tel_cfg)
        self.data_sizes = jnp.exp(
            jax.random.normal(jax.random.PRNGKey(cfg.seed + 40), (cfg.num_clients,))
            * 0.5
            + jnp.log(300.0)
        )
        # malicious client designation (fixed at start, §IV.D)
        n_mal = int(round(cfg.attack_fraction * cfg.num_clients))
        self.malicious = jax.random.permutation(
            jax.random.PRNGKey(cfg.seed + 41),
            jnp.arange(cfg.num_clients) < n_mal,
        )
        self._round_jit = jax.jit(self._round)

    # ------------------------------------------------------------------ #
    def _client_update(self, params, cid, round_idx, key, malicious):
        """E local epochs of SGD on one client's data (Eq. 5)."""
        cfg = self.cfg
        if cfg.task == "emnist":
            x, y = emnist_like.client_batch(
                self.data_cfg, cid, round_idx, key, cfg.local_batch * cfg.local_epochs
            )
        else:
            x, y = har_like.client_batch(
                self.data_cfg, cid, round_idx, key, cfg.local_batch * cfg.local_epochs
            )
        if cfg.attack == "label_flip":
            y = jnp.where(malicious, (self.num_classes - 1) - y, y)
        xs = x.reshape(cfg.local_epochs, cfg.local_batch, -1)
        ys = y.reshape(cfg.local_epochs, cfg.local_batch)

        def step(p, xy):
            g = jax.grad(_ce_loss)(p, xy[0], xy[1], self.num_classes)
            return jax.tree.map(lambda a, b: a - cfg.lr * b, p, g), None

        p_new, _ = jax.lax.scan(step, params, (xs, ys))
        return jax.tree.map(lambda a, b: a - b, p_new, params)

    def _histograms(self, round_idx):
        fn = (
            emnist_like.client_histogram
            if self.cfg.task == "emnist"
            else har_like.client_histogram
        )
        return jax.vmap(lambda c: fn(self.data_cfg, c, round_idx))(
            jnp.arange(self.cfg.num_clients)
        )

    # ------------------------------------------------------------------ #
    def _round(self, params, sched_state, telemetry, round_idx, key):
        cfg = self.cfg
        n = cfg.num_clients
        k_sel, k_data, k_attack, k_dp, k_tel, k_eval = jax.random.split(key, 6)

        hist = self._histograms(round_idx)
        decision = schedule_round(sched_state, telemetry, hist, cfg.scheduler)

        # --- policy-specific participation --------------------------- #
        if cfg.policy == "fedfog":
            mask = decision.selection.mask
            if cfg.top_k is not None:
                from repro.core.selection import topk_mask

                mask = topk_mask(decision.selection.utility, mask, cfg.top_k)
        elif cfg.policy == "rcs":
            mask = random_selection_mask(k_sel, n, cfg.top_k or n)
        else:  # fogfaas / vanilla: everyone alive participates
            mask = telemetry.batt > 0.05

        # --- local training over ALL clients (vmapped), masked ------- #
        cids = jnp.arange(n)
        deltas = jax.vmap(
            lambda cid, k, m: self._client_update(params, cid, round_idx, k, m)
        )(cids, jax.random.split(k_data, n), self.malicious)

        if cfg.clip_norm > 0:
            from repro.optim import clip_by_global_norm

            deltas = jax.vmap(lambda d: clip_by_global_norm(d, cfg.clip_norm)[0])(
                deltas
            )
        if cfg.attack not in ("none", "label_flip"):
            deltas = attacks_mod.corrupt_deltas(
                deltas, self.malicious & mask, cfg.attack, k_attack,
                noise_scale=cfg.attack_noise_scale,
                replacement_scale=cfg.attack_replacement_scale,
            )
            mask = attacks_mod.dropout_mask(mask, self.malicious, cfg.attack)
        deltas = apply_compression(deltas, cfg.compression)

        agg = agg_mod.fedavg_stacked(deltas, mask, self.data_sizes)
        if cfg.dp_sigma > 0:
            agg = privacy_mod.gaussian_mechanism(
                agg,
                k_dp,
                privacy_mod.DPConfig(
                    sigma=cfg.dp_sigma, sensitivity=cfg.clip_norm or 1.0
                ),
            )
        new_params = jax.tree.map(
            lambda p, a: p + cfg.server_lr * a, params, agg
        )

        # --- DES: latency + energy (§IV.F) --------------------------- #
        workload = 6.0 * self.n_params * cfg.local_batch * cfg.local_epochs
        up_bytes = wire_bytes_per_param(cfg.compression) * self.n_params
        warm = sched_state.warm
        if cfg.policy in ("fogfaas",):
            warm = jnp.zeros_like(warm)  # naive platform: no keep-alive
        per_ms, round_ms, orch_ms = round_times_ms(
            cfg.faas, self.profiles, mask, warm, workload, up_bytes,
            2.0 * self.n_params,
            policy="fedfog" if cfg.policy in ("fedfog", "rcs", "vanilla") else "fogfaas",
        )
        energy = round_energy_j(cfg.faas, self.profiles, mask, warm, workload, up_bytes)
        cold_starts = jnp.sum((mask & ~warm).astype(jnp.int32))

        new_sched = account_energy(decision.new_state, energy, cfg.scheduler)
        new_tel = step_telemetry(
            self.tel_cfg, telemetry, mask, energy, self.profiles, k_tel
        )

        # --- eval ------------------------------------------------------ #
        ev = (
            emnist_like.eval_batch(self.data_cfg, k_eval, 512)
            if cfg.task == "emnist"
            else har_like.eval_batch(self.data_cfg, k_eval, 512)
        )
        logits = mlp_apply(new_params, ev[0])
        acc = jnp.mean((jnp.argmax(logits, -1) == ev[1]).astype(jnp.float32))

        metrics = {
            "accuracy": acc,
            "num_selected": jnp.sum(mask.astype(jnp.int32)),
            "round_latency_ms": round_ms,
            "orchestration_ms": orch_ms,
            "energy_j": jnp.sum(energy),
            "cold_starts": cold_starts,
            "mean_drift": jnp.mean(decision.selection.drift),
            "mean_utility": jnp.mean(decision.selection.utility),
            "mean_battery": jnp.mean(new_tel.batt),
        }
        return new_params, new_sched, new_tel, metrics

    # ------------------------------------------------------------------ #
    def run(self, rounds: int | None = None) -> dict[str, Any]:
        rounds = rounds or self.cfg.rounds
        key = jax.random.PRNGKey(self.cfg.seed + 100)
        history: dict[str, list] = {}
        params, sched, tel = self.params, self.sched_state, self.telemetry
        for r in range(rounds):
            key, k = jax.random.split(key)
            params, sched, tel, metrics = self._round_jit(
                params, sched, tel, jnp.asarray(r, jnp.int32), k
            )
            for name, v in metrics.items():
                history.setdefault(name, []).append(float(v))
        self.params, self.sched_state, self.telemetry = params, sched, tel
        history["final_accuracy"] = history["accuracy"][-1]
        history["peak_accuracy"] = max(history["accuracy"])
        history["total_energy_j"] = sum(history["energy_j"])
        history["mean_latency_ms"] = sum(history["round_latency_ms"]) / rounds
        history["total_cold_starts"] = sum(history["cold_starts"])
        return history
