"""Paper-scale FedFog simulator: N edge clients, small models, full DES.

This is the engine behind the paper-table benchmarks (docs/EXPERIMENTS.md
maps suites to paper tables): EMNIST-like / HAR-like tasks, the complete scheduler
(Eqs. 1-12), telemetry + FaaS latency/energy simulation, drift injection,
attacks, and all four policies (FedFog / RCS / FogFaaS / Vanilla FL).

Unlike the pod-scale runtime (fl/round.py) which maps clients onto mesh
slots, here ALL N clients are vmapped — at MLP scale that is the fastest
way to simulate a 100-device deployment on one host, and it keeps the
simulator exactly faithful to the paper's synchronous-round semantics.

Two execution engines share ONE round function (``_round``):

  * ``run()``        — per-round jitted loop. One dispatch + host sync per
                       round; keep for debugging / streaming metrics.
  * ``run_scanned()`` — the whole multi-round experiment compiled into a
                       single ``jax.lax.scan``: per-round metrics are
                       stacked on-device and transferred to the host ONCE
                       at the end. This is the hot path behind every
                       benchmark suite, and what ``repro.sim.sweep`` vmaps
                       over seeds.

All round state is functional: ``init_state(seed)`` builds an immutable
``(env, params, sched_state, telemetry)`` tuple and is traceable over the
seed, so a whole seed batch can be initialized inside one vmapped program.
DES cost accounting (latency / energy / cold starts) comes from the shared
``repro.sim.des.RoundCostModel`` — the same model the pod-scale engine
uses, so the two engines cannot drift apart on §IV.F semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg_mod
from repro.core import privacy as privacy_mod
from repro.core.scheduler import SchedulerConfig, account_energy, schedule_round
from repro.core.selection import random_selection_mask, topk_mask
from repro.core.types import (
    init_population_scheduler_state,
    init_scheduler_state,
    static_on,
)
from repro.data import emnist_like, har_like
from repro.data.telemetry import (
    TelemetryConfig,
    init_telemetry,
    make_profiles,
    step_telemetry,
)
from repro.fl import attacks as attacks_mod
from repro.fl import fog as fog_mod
from repro.fl.compression import apply_compression, wire_bytes_per_param
from repro.fl.fuse import (
    fuse_clients,
    fuse_vector,
    fused_gaussian_noise,
    stacked_leaf_sizes,
)
from repro.obs.history import finalize_history
from repro.optim import clip_by_global_norm
from repro.sim.des import FaasSimConfig, RoundCostModel
from repro.sim.faults import config as faults_config
from repro.sim.faults import inject as faults_inject
from repro.sim.faults.config import FaultConfig

Array = jax.Array


# --------------------------------------------------------------------- #
# Small model (MLP) for the edge tasks
# --------------------------------------------------------------------- #
def mlp_init(key: Array, sizes: tuple[int, ...]):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append(
            {
                "w": jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5,
                "b": jnp.zeros((b,)),
            }
        )
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def _ce_loss(params, x, y, num_classes):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# --------------------------------------------------------------------- #
# Simulator
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    task: str = "emnist"  # "emnist" | "har"
    num_clients: int = 64
    rounds: int = 50
    local_epochs: int = 3  # E in Eq. 5
    local_batch: int = 32
    lr: float = 0.05  # η in Eq. 5
    policy: str = "fedfog"  # fedfog | rcs | fogfaas | vanilla
    top_k: int | None = 24  # participation budget per round
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    telemetry: TelemetryConfig | None = None
    faas: FaasSimConfig = dataclasses.field(default_factory=FaasSimConfig)
    drift_period: int = 0  # inject drift every k rounds (0 = off)
    attack: str = "none"
    attack_fraction: float = 0.0
    # Calibrated so Table V reproduces the paper's severity ordering:
    # model_replacement > label_flip > noise > dropout.
    attack_noise_scale: float = 0.05
    attack_replacement_scale: float = 1.0
    compression: str = "none"
    dp_sigma: float = 0.0
    clip_norm: float = 0.0
    server_lr: float = 1.0
    aggregator: str = "fedavg"  # "fedavg" | "median" | "trimmed"
    trim_fraction: float = 0.1  # trimmed-mean tail fraction per side
    # Route the aggregation (Eq. 6 weighted sum, or the in-kernel
    # median / trimmed selection) + DP noise + server apply through the
    # fused Pallas delta-pipeline kernel (kernels/delta_pipeline): one
    # HBM pass over the (N, P) delta stack instead of one per stage per
    # leaf. Also engages on the async engine's flush path (staleness
    # discounting included; robust aggregators are unweighted so they
    # ignore staleness there). Interpret-mode fallback off-TPU — a
    # correctness tool, slow on CPU, hence default off.
    use_pallas_agg: bool = False
    # Virtual client population M (None → dense: population == num_clients).
    # In population mode only cheap (M,) registries (telemetry, profiles,
    # scheduler rows, data sizes) are carried at M; each round samples a
    # num_clients-sized cohort, so all O(model) work — local updates, the
    # fused (C, P) delta buffer, the Pallas pass — is cohort-sized.
    # Structural for the sweep layer (a Python-level branch).
    population: int | None = None
    # Fog tier width F of the edge → fog → cloud reduction: each fog
    # aggregator partially reduces its contiguous block of cohort clients,
    # the cloud combines the F partials (fl/fog.py). 1 = flat (bitwise
    # identical to the pre-fog path); > 1 requires aggregator="fedavg".
    fog_nodes: int = 1
    # Fault-injection + recovery plan (repro.sim.faults). None or an
    # all-inert FaultConfig leaves every code path VERBATIM — the fault
    # layer's single structural gate (`faults.active`) is off and the
    # traced program is bitwise identical to a no-faults build. Rates /
    # scales are numeric for the sweep layer; the retry cap, failover
    # flag and deadline None-ness are structural.
    faults: FaultConfig | None = None
    hidden: tuple[int, ...] = (128, 64)
    seed: int = 0

    def data_cfg(self):
        if self.task == "emnist":
            return emnist_like.EmnistLikeConfig(
                drift_period=self.drift_period, seed=self.seed
            )
        return har_like.HarLikeConfig(drift_period=self.drift_period, seed=self.seed)

    def dims(self):
        if self.task == "emnist":
            return 28 * 28, 62
        return har_like.WINDOW * har_like.CHANNELS, har_like.NUM_CLASSES


class FedFogSimulator:
    def __init__(
        self, cfg: SimulatorConfig, *, defer_state: bool = False, tap=None
    ):
        """``defer_state=True`` skips the eager default-seed state build —
        for callers (the sweep layer) that trace ``init_state`` per seed
        inside a compiled program and would discard the eager one.

        ``tap`` (a ``repro.obs.MetricTap``) streams decimated per-round
        metrics out of ``run_scanned()`` via an ordered ``io_callback``
        (and out of ``run()`` host-side) while the program executes.
        ``None`` — the default — leaves the traced program bitwise
        identical to the pre-tap engine; the tap is a structural gate,
        and the per-instance jit means a given (simulator, tap) pair
        compiles exactly once."""
        self.cfg = cfg
        self.tap = tap if (tap is not None and tap.enabled) else None
        self.data_cfg = cfg.data_cfg()
        in_dim, n_cls = cfg.dims()
        self.num_classes = n_cls
        self.sizes = (in_dim,) + cfg.hidden + (n_cls,)
        # Population/cohort split: per-client registries live at M =
        # population, all model-sized work at C = num_clients. Dense mode
        # (population in (None, num_clients)) keeps the flat round
        # function VERBATIM — bitwise oracle discipline.
        self.population = cfg.population or cfg.num_clients
        self._pop_mode = self.population != cfg.num_clients
        if self.population < cfg.num_clients:
            raise ValueError(
                f"population={cfg.population} must be >= the cohort size "
                f"num_clients={cfg.num_clients}"
            )
        fog_mod.validate_fog_config(
            cfg.fog_nodes, cfg.num_clients, cfg.aggregator
        )
        # ONE structural gate for the whole fault layer (lifted rates
        # answer True via static_any — the sweep registers the gate).
        self._faults_on = faults_config.active(cfg.faults)
        if cfg.faults is not None:
            faults_config.validate(cfg.faults)
        self.tel_cfg = cfg.telemetry or TelemetryConfig(
            num_clients=self.population, seed=cfg.seed
        )
        if self.tel_cfg.num_clients != self.population:
            raise ValueError(
                f"telemetry.num_clients={self.tel_cfg.num_clients} must "
                f"match the population size {self.population}"
            )
        # Cohort-sized telemetry config for stepping the gathered rows
        # in population mode (step_telemetry draws shape (num_clients,)).
        self._tel_cfg_cohort = dataclasses.replace(
            self.tel_cfg, num_clients=cfg.num_clients
        )
        # When telemetry was derived from the simulator seed, sweep seeds
        # re-derive it; an explicitly provided TelemetryConfig stays fixed.
        self._tel_follows_seed = cfg.telemetry is None
        self.n_mal = int(round(cfg.attack_fraction * self.population))
        self.cost_model = RoundCostModel(cfg.faas)
        self.n_params = sum(a * b + b for a, b in zip(self.sizes[:-1], self.sizes[1:]))
        self.env = self.params = self.sched_state = self.telemetry = None
        if not defer_state:
            self._ensure_state()
        # params/sched/telemetry are the scan carry: donate them so the
        # runtime reuses their buffers for the advanced state (CPU has no
        # donation support and warns, so gate on the backend). env is NOT
        # donated — it is reused across runs.
        donate = (1, 2, 3) if jax.default_backend() != "cpu" else ()
        self._round_jit = jax.jit(self._round, donate_argnums=donate)
        self._scan_jit = jax.jit(
            self._scan_rounds, static_argnames=("rounds",),
            donate_argnums=donate,
        )

    def _ensure_state(self):
        if self.env is None:
            env, params, sched, tel = self.init_state_fast(self.cfg.seed)
            self.env = env
            self.params, self.sched_state, self.telemetry = params, sched, tel

    def init_state_fast(self, seed):
        """``init_state`` through a shared jitted executable in population
        mode. Eagerly, the (M,)-row registries cost ~15 separate RNG
        dispatches — ~0.9 s at M = 1e6 on host, paid per instance — vs
        one fused program compiled once per config. Dense mode keeps the
        eager path verbatim (bitwise oracle discipline)."""
        if self._pop_mode:
            return _shared_init_jit(self.cfg)(seed)
        return self.init_state(seed)

    @property
    def profiles(self):
        """Device profiles of the default-seed env (None until state init)."""
        return None if self.env is None else self.env["profiles"]

    # ------------------------------------------------------------------ #
    def init_state(self, seed):
        """Functional state init: (env, params, sched_state, telemetry).

        ``seed`` may be a Python int (eager path) or a traced int32 — the
        whole init is jax-traceable, which is what lets the sweep layer
        vmap it over a seed batch inside one compiled program.
        """
        cfg = self.cfg
        seed = jnp.asarray(seed, jnp.int32)
        data_cfg = dataclasses.replace(self.data_cfg, seed=seed)
        params = mlp_init(jax.random.PRNGKey(seed), self.sizes)
        tel_cfg = (
            dataclasses.replace(self.tel_cfg, seed=seed)
            if self._tel_follows_seed
            else self.tel_cfg
        )
        profiles = make_profiles(tel_cfg)
        telemetry = init_telemetry(tel_cfg)
        if self._pop_mode:
            # Population mode: (M,) registries, no (M, V) histogram table
            # — the drift reference is recomputed per cohort from
            # last_hist_round (see core.types.PopulationSchedulerState).
            sched = init_population_scheduler_state(
                self.population, cfg.scheduler.theta_e
            )
            data_sizes = jnp.exp(
                jax.random.normal(
                    jax.random.PRNGKey(seed + 40), (self.population,)
                )
                * 0.5
                + jnp.log(300.0)
            )
            # Random-permutation placement is O(M log M) — a 1M-row sort
            # (~0.8 s on host) spent shuffling an all-False array when no
            # attack is configured. n_mal is static, so branch in Python;
            # the key is dedicated (seed + 41), skipping it shifts no
            # other stream.
            if self.n_mal == 0:
                malicious = jnp.zeros((self.population,), bool)
            else:
                malicious = jax.random.permutation(
                    jax.random.PRNGKey(seed + 41),
                    jnp.arange(self.population) < self.n_mal,
                )
            env = {
                "profiles": profiles,
                "data_sizes": data_sizes,
                "malicious": malicious,
                "data_seed": seed,
            }
            return env, params, sched, telemetry
        sched = init_scheduler_state(
            cfg.num_clients, self.num_classes, cfg.scheduler.theta_e
        )
        # Bootstrap the drift reference with the true round-0 distributions,
        # otherwise round 0 flags every client as "drifted" vs the uniform
        # prior and selects nobody.
        sched = dataclasses.replace(
            sched,
            prev_hist=self._histograms(data_cfg, jnp.zeros((), jnp.int32)),
        )
        data_sizes = jnp.exp(
            jax.random.normal(jax.random.PRNGKey(seed + 40), (cfg.num_clients,))
            * 0.5
            + jnp.log(300.0)
        )
        # malicious client designation (fixed at start, §IV.D)
        malicious = jax.random.permutation(
            jax.random.PRNGKey(seed + 41),
            jnp.arange(cfg.num_clients) < self.n_mal,
        )
        env = {
            "profiles": profiles,
            "data_sizes": data_sizes,
            "malicious": malicious,
            "data_seed": seed,
        }
        return env, params, sched, telemetry

    # ------------------------------------------------------------------ #
    def _client_update(self, data_cfg, params, cid, round_idx, key, malicious):
        """E local epochs of SGD on one client's data (Eq. 5)."""
        cfg = self.cfg
        if cfg.task == "emnist":
            x, y = emnist_like.client_batch(
                data_cfg, cid, round_idx, key, cfg.local_batch * cfg.local_epochs
            )
        else:
            x, y = har_like.client_batch(
                data_cfg, cid, round_idx, key, cfg.local_batch * cfg.local_epochs
            )
        if cfg.attack == "label_flip":
            y = jnp.where(malicious, (self.num_classes - 1) - y, y)
        xs = x.reshape(cfg.local_epochs, cfg.local_batch, -1)
        ys = y.reshape(cfg.local_epochs, cfg.local_batch)

        def step(p, xy):
            g = jax.grad(_ce_loss)(p, xy[0], xy[1], self.num_classes)
            return jax.tree.map(lambda a, b: a - cfg.lr * b, p, g), None

        p_new, _ = jax.lax.scan(step, params, (xs, ys))
        return jax.tree.map(lambda a, b: a - b, p_new, params)

    def _histograms(self, data_cfg, round_idx, cids=None):
        fn = (
            emnist_like.client_histogram
            if self.cfg.task == "emnist"
            else har_like.client_histogram
        )
        if cids is None:
            return jax.vmap(lambda c: fn(data_cfg, c, round_idx))(
                jnp.arange(self.cfg.num_clients)
            )
        # Cohort variant: explicit client ids, per-client round indices
        # (the population drift reference is recomputed at each member's
        # last-observed round).
        rounds = jnp.broadcast_to(
            jnp.asarray(round_idx, jnp.int32), cids.shape
        )
        return jax.vmap(lambda c, r: fn(data_cfg, c, r))(cids, rounds)

    # ------------------------------------------------------------------ #
    def _participation(self, decision, telemetry, k_sel):
        """Policy-specific participation mask for one scheduling decision.

        Shared by the synchronous round and the event-driven async engine
        (``repro.sim.events.engine``), so both admit exactly the same
        clients for a given (state, policy).
        """
        cfg = self.cfg
        if cfg.policy == "fedfog":
            mask = decision.selection.mask
            if cfg.top_k is not None:
                mask = topk_mask(decision.selection.utility, mask, cfg.top_k)
        elif cfg.policy == "rcs":
            # `is None` (not `or`): top_k may be a traced int32 scalar.
            k = cfg.top_k if cfg.top_k is not None else cfg.num_clients
            mask = random_selection_mask(k_sel, cfg.num_clients, k)
        else:  # fogfaas / vanilla: everyone alive participates
            mask = telemetry.batt > 0.05
        return mask

    def _local_deltas(self, data_cfg, params, round_idx, mask, malicious,
                      k_data, k_attack, cids=None):
        """Vmapped local training over the cohort + clip/attack/compression.

        Returns ``(deltas, mask)`` — ``mask`` may shrink under the dropout
        attack. Shared by both engines: the sync round computes and
        aggregates in the same step; the async engine computes at dispatch
        time and aggregates at completion/flush time. ``cids`` defaults to
        the dense registry (all ``num_clients`` clients); population mode
        passes the sampled cohort's ids.
        """
        cfg = self.cfg
        n = cfg.num_clients
        if cids is None:
            cids = jnp.arange(n)
        deltas = jax.vmap(
            lambda cid, k, m: self._client_update(
                data_cfg, params, cid, round_idx, k, m
            )
        )(cids, jax.random.split(k_data, n), malicious)

        if cfg.clip_norm > 0:
            deltas = jax.vmap(lambda d: clip_by_global_norm(d, cfg.clip_norm)[0])(
                deltas
            )
        if cfg.attack not in ("none", "label_flip"):
            deltas = attacks_mod.corrupt_deltas(
                deltas, malicious & mask, cfg.attack, k_attack,
                noise_scale=cfg.attack_noise_scale,
                replacement_scale=cfg.attack_replacement_scale,
            )
            mask = attacks_mod.dropout_mask(mask, malicious, cfg.attack)
        deltas = apply_compression(deltas, cfg.compression)
        return deltas, mask

    def _round_workload(self):
        """(workload_flops, upload_bytes, download_bytes) per client-round."""
        cfg = self.cfg
        workload = 6.0 * self.n_params * cfg.local_batch * cfg.local_epochs
        up_bytes = wire_bytes_per_param(cfg.compression) * self.n_params
        return workload, up_bytes, 2.0 * self.n_params

    def _eval_accuracy(self, data_cfg, params, k_eval):
        """Held-out accuracy on a 512-sample eval batch."""
        ev = (
            emnist_like.eval_batch(data_cfg, k_eval, 512)
            if self.cfg.task == "emnist"
            else har_like.eval_batch(data_cfg, k_eval, 512)
        )
        logits = mlp_apply(params, ev[0])
        return jnp.mean((jnp.argmax(logits, -1) == ev[1]).astype(jnp.float32))

    # ------------------------------------------------------------------ #
    def _apply_deltas(self, params, deltas, mask, data_sizes, k_dp):
        """Aggregate cohort deltas and apply the server update.

        Extracted op-for-op from the flat round body so the dense and
        population rounds share one aggregation path; with
        ``fog_nodes > 1`` the Eq. 6 reduction runs hierarchically
        (fog partials → cloud combine, ``fl/fog.py``) on both the
        Pallas-kernel and reference branches.
        """
        cfg = self.cfg
        if cfg.use_pallas_agg:
            # Fused delta-pipeline kernel: aggregation (Eq. 6 weighting,
            # or the in-kernel median / trimmed selection network) + DP
            # noise + apply in ONE pass over the fused (N, P) delta
            # stack (clip/compression already happened in _local_deltas,
            # shared with the async engine). The DP noise vector is
            # built with the reference per-leaf key recipe, so enabling
            # the kernel does not change the noise draws.
            from repro.kernels.delta_pipeline import delta_pipeline_apply

            cat_d, _ = fuse_clients(deltas)
            base_flat, unfuse_vec = fuse_vector(params)
            noise = None
            if static_on(cfg.dp_sigma):
                noise = fused_gaussian_noise(
                    k_dp,
                    cfg.dp_sigma * (cfg.clip_norm or 1.0),
                    stacked_leaf_sizes(deltas),
                    [x.shape for x in jax.tree.leaves(params)],
                )
            if cfg.fog_nodes > 1:
                new_flat = fog_mod.fog_pipeline_apply(
                    cat_d, base_flat, mask, data_sizes,
                    lr=cfg.server_lr, dp_noise=noise,
                    fog_nodes=cfg.fog_nodes,
                )
            else:
                new_flat = delta_pipeline_apply(
                    cat_d, base_flat, mask, data_sizes,
                    lr=cfg.server_lr, dp_noise=noise,
                    trim_fraction=cfg.trim_fraction,
                    aggregator=cfg.aggregator,
                )
            new_params = unfuse_vec(new_flat)
        else:
            if cfg.aggregator == "median":
                agg = agg_mod.median_aggregate(deltas, mask)
            elif cfg.aggregator == "trimmed":
                agg = agg_mod.trimmed_mean_aggregate(
                    deltas, mask, cfg.trim_fraction
                )
            elif cfg.fog_nodes > 1:
                agg = fog_mod.fog_aggregate_tree(
                    deltas, mask, data_sizes, cfg.fog_nodes
                )
            else:
                agg = agg_mod.fedavg_stacked(deltas, mask, data_sizes)
            if static_on(cfg.dp_sigma):
                agg = privacy_mod.gaussian_mechanism(
                    agg,
                    k_dp,
                    privacy_mod.DPConfig(
                        sigma=cfg.dp_sigma, sensitivity=cfg.clip_norm or 1.0
                    ),
                )
            new_params = jax.tree.map(
                lambda p, a: p + cfg.server_lr * a, params, agg
            )
        return new_params

    # ------------------------------------------------------------------ #
    def _plan_faults(self, key, mask, warm, deltas, costs):
        """Realize one round's faults (sync emulation, sim/faults) off a
        dedicated sub-key: ``fold_in(key, 8)`` — disjoint from the 6-way
        round split and the population cohort fold (7), so faulted runs
        replay exactly from the seed and fault draws never perturb any
        other stream. Returns ``(plan, deltas)`` with corrupted-payload
        noise already applied (the `fl/attacks.py` machinery, accounted
        as a fault)."""
        fc = self.cfg.faults
        k_plan, k_noise = jax.random.split(jax.random.fold_in(key, 8))
        plan = faults_inject.plan_round(
            fc, k_plan, mask, ~warm, costs.per_client_ms,
            fog_nodes=self.cfg.fog_nodes,
        )
        deltas = attacks_mod.corrupt_deltas(
            deltas, plan.corrupt, "noise", k_noise,
            noise_scale=fc.corrupt_scale,
        )
        return plan, deltas

    # ------------------------------------------------------------------ #
    def _round(self, env, params, sched_state, telemetry, round_idx, key):
        """One synchronous FL round — pure function of its arguments, so it
        is equally valid as a jitted step, a ``lax.scan`` body, and a
        vmapped-per-seed program. Dispatches to the population-mode round
        when a virtual population larger than the cohort is configured."""
        if self._pop_mode:
            return self._round_population(
                env, params, sched_state, telemetry, round_idx, key
            )
        cfg = self.cfg
        data_cfg = dataclasses.replace(self.data_cfg, seed=env["data_seed"])
        malicious = env["malicious"]
        k_sel, k_data, k_attack, k_dp, k_tel, k_eval = jax.random.split(key, 6)

        hist = self._histograms(data_cfg, round_idx)
        decision = schedule_round(sched_state, telemetry, hist, cfg.scheduler)

        mask = self._participation(decision, telemetry, k_sel)
        deltas, mask = self._local_deltas(
            data_cfg, params, round_idx, mask, malicious, k_data, k_attack
        )

        # --- DES: latency + energy (§IV.F, shared RoundCostModel) ----- #
        # Computed BEFORE aggregation (pure, value-identical reordering)
        # so the fault layer can price retry chains off per_client_ms.
        workload, up_bytes, down_bytes = self._round_workload()
        warm = sched_state.warm
        if cfg.policy in ("fogfaas",):
            warm = jnp.zeros_like(warm)  # naive platform: no keep-alive
        costs = self.cost_model.round_costs(
            env["profiles"], mask, warm, workload, up_bytes, down_bytes,
            policy="fedfog" if cfg.policy in ("fedfog", "rcs", "vanilla") else "fogfaas",
        )

        counters = faults_inject.zero_counters()
        agg_mask, energy_j, round_ms = mask, costs.energy_j, costs.round_ms
        skip = None
        if self._faults_on:
            plan, deltas = self._plan_faults(key, mask, warm, deltas, costs)
            agg_mask = plan.arrived  # Eq. 6 reweights over arrivals only
            energy_j = costs.energy_j * plan.attempts  # retries repay
            round_ms = plan.round_ms
            skip, counters = plan.skip, plan.counters

        new_params = self._apply_deltas(
            params, deltas, agg_mask, env["data_sizes"], k_dp
        )
        if skip is not None:
            # Below quorum: the round is skipped and the model carries
            # over bitwise (the discarded aggregate is never selected).
            new_params = jax.tree.map(
                lambda p, q: jnp.where(skip, p, q), params, new_params
            )

        new_sched = account_energy(decision.new_state, energy_j, cfg.scheduler)
        new_tel = step_telemetry(
            self.tel_cfg, telemetry, mask, energy_j, env["profiles"], k_tel
        )

        acc = self._eval_accuracy(data_cfg, new_params, k_eval)

        metrics = {
            "accuracy": acc,
            "num_selected": jnp.sum(mask.astype(jnp.int32)),
            "round_latency_ms": round_ms,
            "orchestration_ms": costs.orchestration_ms,
            "energy_j": jnp.sum(energy_j),
            "cold_starts": costs.cold_starts,
            "mean_drift": jnp.mean(decision.selection.drift),
            "mean_utility": jnp.mean(decision.selection.utility),
            "mean_battery": jnp.mean(new_tel.batt),
            **counters,
        }
        return new_params, new_sched, new_tel, metrics

    # ------------------------------------------------------------------ #
    def _round_population(self, env, params, pop_sched, telemetry,
                          round_idx, key):
        """One synchronous round over a virtual population.

        The (M,)-sized registries (telemetry, profiles, scheduler rows,
        data sizes, malicious flags) stay resident; a stratified
        ``num_clients``-sized cohort is sampled per round
        (``fold_in(key, 7)`` — disjoint from the 6-way round key split),
        its rows gathered, the ENTIRE flat round machinery
        (scheduling, local updates, fused aggregation, DES costs,
        telemetry AR(1) step) runs at cohort size, and the advanced rows
        scatter back. Unsampled clients are frozen until next sampled —
        the cost of a round never depends on M.
        """
        cfg = self.cfg
        data_cfg = dataclasses.replace(self.data_cfg, seed=env["data_seed"])
        k_sel, k_data, k_attack, k_dp, k_tel, k_eval = jax.random.split(key, 6)
        k_cohort = jax.random.fold_in(key, 7)

        ids = fog_mod.stratified_cohort(
            k_cohort, self.population, cfg.num_clients
        )
        tel_c = fog_mod.gather_rows(telemetry, ids)
        prof_c = fog_mod.gather_rows(env["profiles"], ids)
        sizes_c = env["data_sizes"][ids]
        mal_c = env["malicious"][ids]

        hist = self._histograms(data_cfg, round_idx, cids=ids)
        # The drift reference: with drift off, client histograms are
        # round-independent, so the current round's histograms ARE the
        # last-observed ones — skip the second Dirichlet pass (it is the
        # dominant population-mode overhead inside the compiled round).
        # With drift on, recompute at each member's last-observed round.
        if cfg.drift_period:
            prev_fn = lambda c, r: self._histograms(data_cfg, r, cids=c)
        else:
            prev_fn = lambda c, r: hist
        sched_c = fog_mod.gather_cohort_sched(pop_sched, ids, prev_fn)
        decision = schedule_round(sched_c, tel_c, hist, cfg.scheduler)

        mask = self._participation(decision, tel_c, k_sel)
        deltas, mask = self._local_deltas(
            data_cfg, params, round_idx, mask, mal_c, k_data, k_attack,
            cids=ids,
        )

        # --- DES: latency + energy (§IV.F, shared RoundCostModel) ----- #
        # Before aggregation, as in the dense round, for the fault layer.
        workload, up_bytes, down_bytes = self._round_workload()
        warm = sched_c.warm
        if cfg.policy in ("fogfaas",):
            warm = jnp.zeros_like(warm)  # naive platform: no keep-alive
        costs = self.cost_model.round_costs(
            prof_c, mask, warm, workload, up_bytes, down_bytes,
            policy="fedfog" if cfg.policy in ("fedfog", "rcs", "vanilla") else "fogfaas",
        )

        counters = faults_inject.zero_counters()
        agg_mask, energy_j, round_ms = mask, costs.energy_j, costs.round_ms
        skip = None
        if self._faults_on:
            plan, deltas = self._plan_faults(key, mask, warm, deltas, costs)
            agg_mask = plan.arrived
            energy_j = costs.energy_j * plan.attempts
            round_ms = plan.round_ms
            skip, counters = plan.skip, plan.counters

        new_params = self._apply_deltas(
            params, deltas, agg_mask, sizes_c, k_dp
        )
        if skip is not None:
            new_params = jax.tree.map(
                lambda p, q: jnp.where(skip, p, q), params, new_params
            )

        sched_rows = account_energy(
            decision.new_state, energy_j, cfg.scheduler
        )
        new_sched = fog_mod.scatter_cohort_sched(
            pop_sched, ids, sched_rows, round_idx
        )
        tel_rows = step_telemetry(
            self._tel_cfg_cohort, tel_c, mask, energy_j, prof_c, k_tel
        )
        new_tel = fog_mod.scatter_rows(telemetry, ids, tel_rows)

        acc = self._eval_accuracy(data_cfg, new_params, k_eval)

        metrics = {
            "accuracy": acc,
            "num_selected": jnp.sum(mask.astype(jnp.int32)),
            "round_latency_ms": round_ms,
            "orchestration_ms": costs.orchestration_ms,
            "energy_j": jnp.sum(energy_j),
            "cold_starts": costs.cold_starts,
            "mean_drift": jnp.mean(decision.selection.drift),
            "mean_utility": jnp.mean(decision.selection.utility),
            "mean_battery": jnp.mean(new_tel.batt),
            **counters,
        }
        return new_params, new_sched, new_tel, metrics

    # ------------------------------------------------------------------ #
    def _scan_rounds(self, env, params, sched_state, telemetry, key, *, rounds):
        """All ``rounds`` rounds inside ONE program: ``lax.scan`` over the
        round body, stacking per-round metrics on-device."""

        def body(carry, round_idx):
            params, sched, tel, key = carry
            key, k = jax.random.split(key)
            params, sched, tel, metrics = self._round(
                env, params, sched, tel, round_idx, k
            )
            if self.tap is not None:
                # Streaming tap: every k-th round's metrics leave the
                # device mid-scan through an ordered io_callback (the
                # cond + decimation live in MetricTap.emit). Pure side
                # effect — metrics/carry values are untouched, so the
                # tapped trace computes bitwise what the untapped one
                # does.
                self.tap.emit(metrics, round_idx)
            return (params, sched, tel, key), metrics

        (params, sched, tel, _), stacked = jax.lax.scan(
            body,
            (params, sched_state, telemetry, key),
            jnp.arange(rounds, dtype=jnp.int32),
        )
        return params, sched, tel, stacked

    # ------------------------------------------------------------------ #
    def _finalize(self, history: dict[str, Any], rounds: int) -> dict[str, Any]:
        """Shared summary schema (repro.obs.history) + tracker summary."""
        finalize_history(history, rounds=rounds)
        if self.tap is not None:
            from repro.obs.history import summary_metrics

            self.tap.tracker.log_summary(
                {**self.tap.const, **summary_metrics(history)}
            )
        return history

    def run(self, rounds: int | None = None) -> dict[str, Any]:
        """Per-round jitted loop (debug/streaming path).

        One dispatch and one metrics host-sync per round; prefer
        ``run_scanned()`` for anything performance-sensitive.
        """
        rounds = rounds or self.cfg.rounds
        self._ensure_state()
        key = jax.random.PRNGKey(self.cfg.seed + 100)
        history: dict[str, list] = {}
        params, sched, tel = self.params, self.sched_state, self.telemetry
        for r in range(rounds):
            key, k = jax.random.split(key)
            params, sched, tel, metrics = self._round_jit(
                self.env, params, sched, tel, jnp.asarray(r, jnp.int32), k
            )
            for name, v in metrics.items():
                history.setdefault(name, []).append(float(v))
            if self.tap is not None:
                # Same rows/decimation as the scanned tap, host-side.
                self.tap.host_log(metrics, r)
        self.params, self.sched_state, self.telemetry = params, sched, tel
        return self._finalize(history, rounds)

    def run_scanned(self, rounds: int | None = None) -> dict[str, Any]:
        """Scan-compiled engine: the full experiment as one XLA program.

        Semantics match ``run()`` (same round function, same key chain);
        metrics histories agree to float tolerance. Returns the same
        history dict, but the device→host transfer happens once.
        """
        rounds = int(rounds or self.cfg.rounds)
        self._ensure_state()
        key = jax.random.PRNGKey(self.cfg.seed + 100)
        params, sched, tel, stacked = self._scan_jit(
            self.env, self.params, self.sched_state, self.telemetry, key,
            rounds=rounds,
        )
        self.params, self.sched_state, self.telemetry = params, sched, tel
        host = jax.device_get(stacked)  # single device→host transfer
        history = {name: [float(x) for x in v] for name, v in host.items()}
        return self._finalize(history, rounds)

    def aot_scanned(self, rounds: int | None = None):
        """AOT-compile the scan program (``jit.lower(...).compile()``).

        The jit dispatch caches are per-instance, so a seed sweep of
        fresh simulators would otherwise recompile per instance; the
        returned executable can be shared across any ``FedFogSimulator``
        with the same config shape via ``run_scanned_with``. Note the
        AOT path does NOT populate this instance's jit cache — execute
        through the returned object, not ``run_scanned()``.
        """
        if self.tap is not None:
            # A tapped program embeds host callbacks — it would execute,
            # but the whole point of aot_scanned is cross-instance /
            # on-disk executable reuse, which callbacks cannot survive.
            raise ValueError(
                "aot_scanned() does not support metric taps; build this "
                "simulator with tap=None (taps stream via run_scanned())"
            )
        rounds = int(rounds or self.cfg.rounds)
        self._ensure_state()
        key = jax.random.PRNGKey(self.cfg.seed + 100)
        return self._scan_jit.lower(
            self.env, self.params, self.sched_state, self.telemetry, key,
            rounds=rounds,
        ).compile()

    def run_scanned_with(
        self, compiled, rounds: int | None = None
    ) -> dict[str, Any]:
        """``run_scanned`` semantics through a pre-compiled executable
        from ``aot_scanned`` (this instance's or a same-shape peer's)."""
        rounds = int(rounds or self.cfg.rounds)
        self._ensure_state()
        key = jax.random.PRNGKey(self.cfg.seed + 100)
        params, sched, tel, stacked = compiled(
            self.env, self.params, self.sched_state, self.telemetry, key
        )
        self.params, self.sched_state, self.telemetry = params, sched, tel
        host = jax.device_get(stacked)
        history = {name: [float(x) for x in v] for name, v in host.items()}
        return self._finalize(history, rounds)


# --------------------------------------------------------------------- #
# Shared population-mode init executables. Keyed on the frozen config so
# every same-config instance (benchmarks time fresh instances; tests
# build many) reuses one compiled init program instead of re-tracing —
# and instead of the eager per-op dispatch sequence, whose O(M) RNG
# draws dominate construction time at large populations.
# --------------------------------------------------------------------- #
_INIT_JIT_CACHE: dict[SimulatorConfig, Any] = {}


def _shared_init_jit(cfg: SimulatorConfig):
    fn = _INIT_JIT_CACHE.get(cfg)
    if fn is None:
        fn = _INIT_JIT_CACHE[cfg] = jax.jit(
            FedFogSimulator(cfg, defer_state=True).init_state
        )
    return fn
