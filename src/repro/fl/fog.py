"""Fog-tier hierarchical reduction — edge → fog → cloud (paper Fig. 1).

The FedFog topology is edge devices → fog nodes → cloud, but Eq. 6 is
associative: the staleness-discounted weighted aggregate decomposes into
per-fog PARTIAL sums (each fog aggregator reduces only its own clients)
plus one tiny cloud combine of ``fog_nodes`` partials:

    partial_f = Σ_{i∈f} m_i·disc_i·Δ_i        (P,) per fog
    Σdm_f     = Σ_{i∈f} m_i·disc_i            scalar per fog
    Σm_f      = Σ_{i∈f} m_i                   scalar per fog
    cloud:  agg = (Σ_f partial_f) / (Σ_f Σdm_f + ε) · damping

which equals the flat aggregate up to float reassociation (the partial
sums reduce in per-fog order). Robust aggregators (median / trimmed) are
order statistics over the FULL client axis — they do not decompose into
fog partials, so ``fog_nodes > 1`` composes only with ``fedavg`` (the
callers raise ``ValueError`` otherwise).

Three entries share the cloud-combine math:

  * :func:`fog_aggregate` — reference path: ``segment_sum`` partials over
    an arbitrary client→fog assignment (the hypothesis property in
    tests/test_fog_population.py permutes it), matching
    ``core.aggregation.fedavg_stacked`` / ``sim.events.staleness
    .async_aggregate`` to float tolerance.
  * :func:`fog_pipeline_apply` — kernel path: one
    ``kernels.delta_pipeline.delta_pipeline_partial`` Pallas pass per
    fog's contiguous client block, then the shared replicated epilogue
    (``kernels.delta_pipeline.sharded.combine_epilogue``).
  * under mesh ``rules`` the fog tier maps onto the pod×client axes:
    ``delta_pipeline_apply_sharded(..., fog_nodes=F)`` runs ONE packed
    psum per tier (dist/hlo_analysis asserts the per-tier contract).

This module also hosts the population/cohort sampling used by both
engines: a population of ``M`` virtual clients is carried as cheap (M,)
scheduler/telemetry rows, and each round gathers a C-sized cohort so all
O(model) work (local updates, the fused (C, P) buffer, the Pallas pass)
is built for C clients only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import PopulationSchedulerState, SchedulerState

Array = jax.Array
_EPS = 1e-12  # matches core.aggregation / kernels.delta_pipeline


# --------------------------------------------------------------------- #
# population / cohort sampling
# --------------------------------------------------------------------- #
def stratified_cohort(key: Array, population: int, cohort: int) -> Array:
    """Sample ``cohort`` distinct client ids from ``[0, population)``.

    Stratified without-replacement draw in O(cohort): stratum ``i`` is
    ``[⌊i·M/C⌋, ⌊(i+1)·M/C⌋)`` and contributes exactly one uniform id,
    so the ids come back sorted and distinct by construction — the
    gather/scatter rows of the population state never collide within a
    round. With ``population == cohort`` every stratum has width 1 and
    the sample is ``arange(cohort)`` (the dense registry).
    """
    bounds = (jnp.arange(cohort + 1, dtype=jnp.int32) * population) // cohort
    lo, hi = bounds[:-1], bounds[1:]
    return lo + jax.random.randint(
        key, (cohort,), jnp.zeros_like(lo), jnp.maximum(hi - lo, 1)
    )


def gather_rows(tree, ids: Array):
    """Row-gather every leaf of a per-client pytree (leading dim = N)."""
    return jax.tree.map(lambda a: a[ids], tree)


def scatter_rows(tree, ids: Array, rows):
    """Scatter cohort rows back into the per-population pytree."""
    return jax.tree.map(lambda a, r: a.at[ids].set(r), tree, rows)


def gather_cohort_sched(
    pop: PopulationSchedulerState, ids: Array, hist_fn
) -> SchedulerState:
    """Materialize a cohort-sized ``SchedulerState`` from population rows.

    ``prev_hist`` is NOT stored per population client ((M, V) floats is
    the one piece of scheduler state that is not cheap at 1M clients).
    Instead the population state carries ``last_hist_round`` and the
    drift reference is recomputed for the C cohort members only:
    ``hist_fn(ids, round)`` is deterministic in (client, round), so the
    recomputed reference equals what ``schedule_round`` would have
    stored (``drift_score`` renormalizes both sides, so the smoothing
    double-application is value-neutral for the gate).
    """
    from repro.core.drift import normalize_histogram

    prev = normalize_histogram(hist_fn(ids, pop.last_hist_round[ids]))
    return SchedulerState(
        prev_hist=prev,
        theta_e=pop.theta_e[ids],
        warm=pop.warm[ids],
        last_used=pop.last_used[ids],
        energy_spent=pop.energy_spent[ids],
        round_index=pop.round_index,
    )


def scatter_cohort_sched(
    pop: PopulationSchedulerState,
    ids: Array,
    cohort: SchedulerState,
    hist_round: Array,
) -> PopulationSchedulerState:
    """Write a cohort's advanced scheduler rows back into the population.

    ``prev_hist`` is dropped in favour of recording which round the
    cohort's histograms were taken at (``last_hist_round``); everything
    else scatters row-for-row. Unsampled clients keep their rows frozen
    until the next time the cohort lands on them.
    """
    return PopulationSchedulerState(
        theta_e=pop.theta_e.at[ids].set(cohort.theta_e),
        warm=pop.warm.at[ids].set(cohort.warm),
        last_used=pop.last_used.at[ids].set(cohort.last_used),
        energy_spent=pop.energy_spent.at[ids].set(cohort.energy_spent),
        last_hist_round=pop.last_hist_round.at[ids].set(
            jnp.asarray(hist_round, jnp.int32)
        ),
        round_index=cohort.round_index,
    )


def gather_sched_rows(sched: SchedulerState, ids: Array) -> SchedulerState:
    """Cohort rows of a FULL (population-sized) ``SchedulerState`` —
    the pod-scale runtime variant, where the drift histograms are opaque
    caller data and ``prev_hist`` stays materialized at (M, V)."""
    return SchedulerState(
        prev_hist=sched.prev_hist[ids],
        theta_e=sched.theta_e[ids],
        warm=sched.warm[ids],
        last_used=sched.last_used[ids],
        energy_spent=sched.energy_spent[ids],
        round_index=sched.round_index,
    )


def scatter_sched_rows(
    pop: SchedulerState, ids: Array, rows: SchedulerState
) -> SchedulerState:
    return SchedulerState(
        prev_hist=pop.prev_hist.at[ids].set(rows.prev_hist),
        theta_e=pop.theta_e.at[ids].set(rows.theta_e),
        warm=pop.warm.at[ids].set(rows.warm),
        last_used=pop.last_used.at[ids].set(rows.last_used),
        energy_spent=pop.energy_spent.at[ids].set(rows.energy_spent),
        round_index=rows.round_index,
    )


# --------------------------------------------------------------------- #
# fog-tier reduction
# --------------------------------------------------------------------- #
def fog_assignment(num_clients: int, fog_nodes: int) -> Array:
    """Default client→fog map: contiguous blocks (fog ``f`` owns clients
    ``[f·C/F, (f+1)·C/F)``) — the layout the kernel path's per-fog
    reshape and the pod-major mesh sharding both assume."""
    return (
        jnp.arange(num_clients, dtype=jnp.int32) * fog_nodes
    ) // num_clients


def fog_partial_sums(
    updates: Array,  # (C, P) fused client deltas
    mask: Array,  # (C,) participation
    weights: Array,  # (C,) |D_i| dataset sizes
    fog_nodes: int,
    staleness: Array | None = None,  # (C,)
    staleness_exponent: Array | float = 0.0,
    assignment: Array | None = None,  # (C,) int32 fog id per client
):
    """Per-fog partial sums: ``(partials (F, P), sdm (F,), sm (F,))``.

    This is the fog aggregator's whole job — each fog reduces only its
    own clients' rows; nothing model-sized crosses fogs until the cloud
    combine. ``assignment`` defaults to contiguous blocks.
    """
    if assignment is None:
        assignment = fog_assignment(updates.shape[0], fog_nodes)
    m = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    if staleness is not None:
        s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
        dm = m * (1.0 + s) ** (-jnp.asarray(staleness_exponent, jnp.float32))
    else:
        dm = m
    partials = jax.ops.segment_sum(
        dm[:, None] * updates.astype(jnp.float32), assignment,
        num_segments=fog_nodes,
    )
    sdm = jax.ops.segment_sum(dm, assignment, num_segments=fog_nodes)
    sm = jax.ops.segment_sum(m, assignment, num_segments=fog_nodes)
    return partials, sdm, sm


def cloud_combine(
    partials: Array,  # (F, P) fog partial weighted sums
    sdm: Array,  # (F,) per-fog Σ mask·|D|·disc
    sm: Array,  # (F,) per-fog Σ mask·|D|
    has_stale: bool,
) -> Array:
    """Cloud tier: combine fog partials into the normalized aggregate.

    Mirrors the sharded kernel's post-psum normalization term for term
    (Σpartial/(Σdm+ε) then the ``async_aggregate`` global damping when
    staleness weighting is on).
    """
    agg_sum = jnp.sum(partials, axis=0)
    tdm, tm = jnp.sum(sdm), jnp.sum(sm)
    if has_stale:
        agg = agg_sum / (tdm + _EPS)
        return agg * ((tdm + _EPS) / (tm + _EPS))
    return agg_sum / (tm + _EPS)


def fog_aggregate(
    updates: Array,  # (C, P) fused client deltas
    mask: Array,
    weights: Array,
    fog_nodes: int,
    staleness: Array | None = None,
    staleness_exponent: Array | float = 0.0,
    assignment: Array | None = None,
) -> Array:
    """Hierarchical Eq. 6: fog partials → cloud combine, on one host.

    Equals ``fedavg_stacked`` (no staleness) / ``async_aggregate``
    (staleness) up to float reassociation, for ANY client→fog
    assignment — associativity is the whole correctness argument, and
    the hypothesis property in tests/test_fog_population.py exercises it
    under permuted assignments.
    """
    partials, sdm, sm = fog_partial_sums(
        updates, mask, weights, fog_nodes, staleness, staleness_exponent,
        assignment,
    )
    return cloud_combine(partials, sdm, sm, staleness is not None)


def fog_aggregate_tree(
    deltas,  # (C, ...)-stacked pytree of client deltas
    mask: Array,
    weights: Array,
    fog_nodes: int,
    staleness: Array | None = None,
    staleness_exponent: Array | float = 0.0,
):
    """Pytree wrapper for the reference engines: fuse → fog_aggregate →
    unfuse, so the stacked-delta paths route through the identical
    hierarchical math as the fused-buffer paths."""
    from repro.fl.fuse import fuse_clients

    cat, unfuse = fuse_clients(deltas)
    return unfuse(
        fog_aggregate(
            cat, mask, weights, fog_nodes, staleness, staleness_exponent
        )
    )


def fog_pipeline_apply(
    updates: Array,  # (C, P) fused client deltas
    base: Array,  # (P,) fused global model
    mask: Array,
    weights: Array,
    lr: Array | float = 1.0,
    staleness: Array | None = None,
    staleness_exponent: Array | float = 0.0,
    dp_noise: Array | None = None,  # (P,) caller-built
    momentum: Array | None = None,  # (P,) fused server momentum
    *,
    fog_nodes: int,
    clip_norm: float = 0.0,
    compression: str = "none",
    topk_fraction: float = 0.05,
    seg_sizes: tuple[int, ...] | None = None,
    server_optimizer: str = "fedavg",
    server_momentum: float = 0.9,
    block_d: int | None = None,
    interpret: bool | None = None,
):
    """Single-host kernel path of the fog tier (fedavg only).

    Each fog's contiguous (C/F, P) client block runs ONE
    ``delta_pipeline_partial`` Pallas pass (clip norms and compression
    tables are fog-local, like the sharded kernel's shard-local ones);
    the cloud combines the F partials and runs the shared replicated
    epilogue. Same return convention as ``delta_pipeline_apply``.
    """
    from repro.kernels.delta_pipeline.delta_pipeline import DEFAULT_BLOCK_D
    from repro.kernels.delta_pipeline.ops import delta_pipeline_partial
    from repro.kernels.delta_pipeline.sharded import combine_epilogue

    c, _ = updates.shape
    if c % fog_nodes:
        raise ValueError(
            f"client count {c} not divisible by fog_nodes {fog_nodes}"
        )
    block_d = DEFAULT_BLOCK_D if block_d is None else block_d
    per_fog = c // fog_nodes
    has_mu = momentum is not None and server_optimizer in (
        "fedavgm", "fedadam"
    )
    m = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    if staleness is not None:
        s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
        dm = m * (1.0 + s) ** (-jnp.asarray(staleness_exponent, jnp.float32))
    else:
        dm = m

    partials, sdm, sm = [], [], []
    for f in range(fog_nodes):
        sl = slice(f * per_fog, (f + 1) * per_fog)
        partials.append(
            delta_pipeline_partial(
                updates[sl], dm[sl],
                clip_norm=clip_norm, compression=compression,
                topk_fraction=topk_fraction, seg_sizes=seg_sizes,
                block_d=block_d, interpret=interpret,
            )
        )
        sdm.append(jnp.sum(dm[sl]))
        sm.append(jnp.sum(m[sl]))
    agg_sum = sum(partials[1:], partials[0])
    out, mu2 = combine_epilogue(
        agg_sum, sum(sdm[1:], sdm[0]), sum(sm[1:], sm[0]), base,
        jnp.asarray(lr, jnp.float32),
        has_stale=staleness is not None,
        dp_noise=dp_noise,
        momentum=momentum if has_mu else None,
        server_optimizer=server_optimizer,
        server_momentum=server_momentum,
    )
    if has_mu:
        return out, mu2
    return out


def validate_fog_config(
    fog_nodes: int, num_clients: int, aggregator: str
) -> None:
    """Shared fog-tier config validation for every engine entry point."""
    if fog_nodes < 1:
        raise ValueError(f"fog_nodes must be >= 1, got {fog_nodes}")
    if fog_nodes == 1:
        return
    if num_clients % fog_nodes:
        raise ValueError(
            f"fog_nodes={fog_nodes} must divide the cohort size "
            f"{num_clients}"
        )
    if aggregator != "fedavg":
        raise ValueError(
            f"aggregator={aggregator!r} is an order statistic over the "
            "full client axis; it does not decompose into fog partials "
            "(fog_nodes > 1 requires aggregator='fedavg')"
        )
