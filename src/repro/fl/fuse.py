"""Fused (C, P) client-delta buffers — the delta pipeline's data layout.

Every server-side pass over the per-client updates (clip, compression
emulation, DP noise, staleness weighting, Eq. 6 aggregation, server
apply) is memory-bound: it touches each of the C·P delta floats once.
Keeping the deltas as a parameter pytree forces one XLA kernel per leaf
per stage; concatenating the leaves into ONE ``(C, P)`` f32 buffer lets
a whole stage run as a single fused pass — and feeds the Pallas
``kernels.delta_pipeline`` kernel directly.

``fuse_clients`` was born in ``fl/round.py`` for the one-all-reduce
sharding contract (PR 2); it now lives here so ``fl/round.py``,
``fl/simulator.py``, ``fl/compression.py`` and the async event engine
can all share it without import cycles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fuse_clients(tree):
    """Concat every (C, ...)-stacked leaf into ONE (C, P) f32 buffer.

    Returns the buffer and the inverse, which accepts either an
    aggregated/applied ``(P,)`` vector or a still-stacked ``(C, P)``
    buffer (split along the last axis + reshape + cast back to each
    leaf's dtype). The sharded round wraps this with its client-axis
    sharding constraint; the Pallas-fused delta pipeline feeds the
    buffer straight to the kernel so the whole clip→compress→aggregate→
    apply chain is one pass over (C, P).
    """
    flat, treedef = jax.tree.flatten(tree)
    shapes = [x.shape[1:] for x in flat]
    dtypes = [x.dtype for x in flat]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    cat = jnp.concatenate(
        [x.reshape((x.shape[0], -1)).astype(jnp.float32) for x in flat],
        axis=1,
    )

    def unfuse(vec):
        parts = jnp.split(vec, list(np.cumsum(sizes)[:-1]), axis=-1)
        leaves = [
            p.reshape(p.shape[:-1] + s).astype(dt)
            for p, s, dt in zip(parts, shapes, dtypes)
        ]
        return jax.tree.unflatten(treedef, leaves)

    return cat, unfuse


def fuse_vector(tree):
    """Concat an UNstacked parameter pytree into one (P,) f32 vector.

    Returns the vector and the inverse (split + reshape + cast back) —
    the ``base``/``mu`` companion of ``fuse_clients`` for the server
    side of the pipeline.
    """
    flat, treedef = jax.tree.flatten(tree)
    shapes = [x.shape for x in flat]
    dtypes = [x.dtype for x in flat]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    cat = jnp.concatenate(
        [x.reshape(-1).astype(jnp.float32) for x in flat]
    )

    def unfuse(vec):
        parts = jnp.split(vec, list(np.cumsum(sizes)[:-1]))
        leaves = [
            p.reshape(s).astype(dt)
            for p, s, dt in zip(parts, shapes, dtypes)
        ]
        return jax.tree.unflatten(treedef, leaves)

    return cat, unfuse


def leaf_sizes(tree) -> tuple[int, ...]:
    """Static per-leaf flat sizes of an UNstacked pytree — the segment
    lengths of the fused (C, P) buffer, in ``jax.tree.flatten`` order.
    For a (C, ...)-stacked tree, pass one client's slice or divide by C.
    """
    return tuple(
        int(np.prod(x.shape)) if x.shape else 1
        for x in jax.tree.leaves(tree)
    )


def stacked_leaf_sizes(tree) -> tuple[int, ...]:
    """Segment lengths of ``fuse_clients(tree)`` — per-leaf flat sizes
    with the leading client axis excluded."""
    return tuple(
        int(np.prod(x.shape[1:])) if x.shape[1:] else 1
        for x in jax.tree.leaves(tree)
    )


def segment_ids(sizes: tuple[int, ...]) -> jnp.ndarray:
    """(P,) int32 leaf-segment id per fused-buffer column (static)."""
    return jnp.asarray(
        np.repeat(np.arange(len(sizes)), sizes), jnp.int32
    )


def fused_gaussian_noise(key, std, sizes: tuple[int, ...], shapes=None):
    """(P,) DP noise vector matching ``core.privacy.gaussian_mechanism``.

    The reference mechanism splits ``key`` once per pytree leaf and
    draws ``normal(k_i, leaf.shape)``; building the fused vector from
    the SAME per-leaf keys and shapes keeps the fused pipeline's noise
    draws identical to the per-leaf reference path (JAX random bits are
    generated from the flat element count, so ``normal(k, shape)``
    reshaped to 1-D equals ``normal(k, (size,))``).

    ``shapes``: optional per-leaf shapes (defaults to 1-D ``(size,)``).
    """
    keys = jax.random.split(key, len(sizes))
    std = jnp.asarray(std, jnp.float32)
    if shapes is None:
        shapes = [(s,) for s in sizes]
    return jnp.concatenate(
        [
            (std * jax.random.normal(k, shp, dtype=jnp.float32)).reshape(-1)
            for k, shp in zip(keys, shapes)
        ]
    )
