"""Client-delta compression (uplink reduction — beyond-paper extension the
paper's energy model rewards: TX bytes enter Eq. E_i = Σ C_cpu·CPU + C_tx·TX).

  * int8:  per-leaf symmetric quantization (scale = max|x| / 127).
  * topk:  keep the largest-|x| fraction per leaf, zero the rest.

Both are simulate-and-dequantize: the aggregation math stays fp32, while
``wire_bytes_per_param`` feeds the DES energy/latency model and the
collective-bytes accounting in the roofline.

Execution strategies (``apply_compression(..., fused=)``):

  * ``fused=True`` (default): ONE pass over the fused ``(C, P)`` buffer
    (``fl.fuse.fuse_clients``). The per-(client, leaf) reductions — int8
    max-abs via a segment scatter-max, top-k thresholds via static leaf
    slices (``lax.top_k`` needs the per-leaf ``k``) — write only tiny
    ``(C, L)`` tables; the quantize/dequantize or threshold-mask
    transform then runs as a single fused elementwise pass instead of
    one XLA kernel chain per leaf.
  * ``fused=False``: the original per-leaf ``jax.tree`` loop — kept as
    the tested reference. The two paths agree BITWISE (same reduction
    elements, same elementwise ops; tests/test_delta_pipeline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fl.fuse import fuse_clients, segment_ids, stacked_leaf_sizes


def compress_int8(deltas):
    """Quantize -> dequantize each leaf (slot dim preserved)."""
    def one(l):
        x = l.astype(jnp.float32)
        red = tuple(range(1, x.ndim))
        scale = jnp.max(jnp.abs(x), axis=red, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(jnp.float32) * scale).astype(l.dtype)

    return jax.tree.map(one, deltas)


def compress_topk(deltas, fraction: float):
    """Keep the top-|fraction| magnitude entries per (slot, leaf)."""
    def one(l):
        x = l.astype(jnp.float32)
        c = x.shape[0]
        flat = x.reshape(c, -1)
        k = max(1, int(flat.shape[1] * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][:, -1:]  # kth largest
        keep = jnp.abs(flat) >= thresh
        return (flat * keep).reshape(x.shape).astype(l.dtype)

    return jax.tree.map(one, deltas)


def _compress_fused(deltas, kind: str, fraction: float):
    """One fused (C, P) buffer pass; bitwise-equal to the per-leaf path.

    The (C, L) scale/threshold tables come from the SAME
    ``kernels.delta_pipeline.segment_table`` the Pallas pipeline uses
    (a segment scatter-max IS the per-leaf max; a static leaf slice of
    the concat IS the leaf), and the elementwise transform applies
    identical ops per element — so fusing changes the kernel count, not
    a single bit of the output.
    """
    from repro.kernels.delta_pipeline import segment_table

    cat, unfuse = fuse_clients(deltas)
    sizes = stacked_leaf_sizes(deltas)
    seg = segment_ids(sizes)
    tab = segment_table(cat, kind, fraction, sizes)
    if kind == "int8":
        scale = tab[:, seg]  # (C, P) gather, fused into the consumer
        q = jnp.clip(jnp.round(cat / scale), -127, 127).astype(jnp.int8)
        return unfuse(q.astype(jnp.float32) * scale)
    # topk: the buffer-wide mask+multiply is the single fused pass.
    thresh = tab[:, seg]  # (C, P)
    return unfuse(cat * (jnp.abs(cat) >= thresh))


def apply_compression(
    deltas, kind: str, topk_fraction: float = 0.05, *, fused: bool = True
):
    if kind == "none":
        return deltas
    if fused and len(jax.tree.leaves(deltas)) > 1:
        if kind in ("int8", "topk"):
            return _compress_fused(deltas, kind, topk_fraction)
        raise ValueError(f"unknown compression {kind!r}")
    if kind == "int8":
        return compress_int8(deltas)
    if kind == "topk":
        return compress_topk(deltas, topk_fraction)
    raise ValueError(f"unknown compression {kind!r}")


def wire_bytes_per_param(kind: str, topk_fraction: float = 0.05) -> float:
    """Uplink bytes per parameter under each scheme (bf16 baseline)."""
    if kind == "none":
        return 2.0
    if kind == "int8":
        return 1.0
    if kind == "topk":
        return topk_fraction * 6.0  # value (2B) + index (4B) per kept entry
    raise ValueError(kind)
