"""Client-delta compression (uplink reduction — beyond-paper extension the
paper's energy model rewards: TX bytes enter Eq. E_i = Σ C_cpu·CPU + C_tx·TX).

  * int8:  per-leaf symmetric quantization (scale = max|x| / 127).
  * topk:  keep the largest-|x| fraction per leaf, zero the rest.

Both are simulate-and-dequantize: the aggregation math stays fp32, while
``wire_bytes_per_param`` feeds the DES energy/latency model and the
collective-bytes accounting in the roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(deltas):
    """Quantize -> dequantize each leaf (slot dim preserved)."""
    def one(l):
        x = l.astype(jnp.float32)
        red = tuple(range(1, x.ndim))
        scale = jnp.max(jnp.abs(x), axis=red, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(jnp.float32) * scale).astype(l.dtype)

    return jax.tree.map(one, deltas)


def compress_topk(deltas, fraction: float):
    """Keep the top-|fraction| magnitude entries per (slot, leaf)."""
    def one(l):
        x = l.astype(jnp.float32)
        c = x.shape[0]
        flat = x.reshape(c, -1)
        k = max(1, int(flat.shape[1] * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][:, -1:]  # kth largest
        keep = jnp.abs(flat) >= thresh
        return (flat * keep).reshape(x.shape).astype(l.dtype)

    return jax.tree.map(one, deltas)


def apply_compression(deltas, kind: str, topk_fraction: float = 0.05):
    if kind == "none":
        return deltas
    if kind == "int8":
        return compress_int8(deltas)
    if kind == "topk":
        return compress_topk(deltas, topk_fraction)
    raise ValueError(f"unknown compression {kind!r}")


def wire_bytes_per_param(kind: str, topk_fraction: float = 0.05) -> float:
    """Uplink bytes per parameter under each scheme (bf16 baseline)."""
    if kind == "none":
        return 2.0
    if kind == "int8":
        return 1.0
    if kind == "topk":
        return topk_fraction * 6.0  # value (2B) + index (4B) per kept entry
    raise ValueError(kind)
