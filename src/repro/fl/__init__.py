"""Federated runtime: round function, state, attacks, compression."""
from repro.fl.round import AttackConfig, make_round_fn
from repro.fl.state import FLConfig, FLState, abstract_fl_state, init_fl_state

__all__ = [
    "AttackConfig",
    "FLConfig",
    "FLState",
    "abstract_fl_state",
    "init_fl_state",
    "make_round_fn",
]
