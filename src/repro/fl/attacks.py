"""Adversarial client behaviours (paper §IV.D).

Four attacks, matching Table V:
  * label_flip       — class k -> (K-1)-k on the malicious clients' labels
                       (for LM tasks: token t -> vocab-1-t on targets).
  * noise            — Gaussian perturbation of the client's delta.
  * dropout          — client unpredictably drops (delta zeroed + excluded).
  * model_replacement— the client returns an arbitrary large update.

All operate on slot-stacked trees with a (C,) malicious mask so they can be
applied inside the jitted round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def flip_labels(tokens: Array, malicious: Array, vocab_size: int) -> Array:
    """tokens: (C, ...) int; malicious: (C,) bool. k -> (V-1)-k."""
    flipped = (vocab_size - 1) - tokens
    m = malicious.reshape((-1,) + (1,) * (tokens.ndim - 1))
    return jnp.where(m, flipped, tokens)


def corrupt_deltas(
    deltas, malicious: Array, kind: str, key: Array, *, noise_scale: float = 0.5,
    replacement_scale: float = 10.0,
):
    """Apply a delta-space attack for malicious slots. deltas: (C, ...) tree."""
    if kind == "none" or kind == "label_flip":
        return deltas  # label_flip acts on data, not deltas
    flat, treedef = jax.tree.flatten(deltas)
    keys = jax.random.split(key, len(flat))

    def mal(l):
        return malicious.reshape((-1,) + (1,) * (l.ndim - 1))

    if kind == "noise":
        out = [
            jnp.where(
                mal(l),
                l + noise_scale * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype),
                l,
            )
            for l, k in zip(flat, keys)
        ]
    elif kind == "model_replacement":
        out = [
            jnp.where(
                mal(l),
                replacement_scale
                * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype),
                l,
            )
            for l, k in zip(flat, keys)
        ]
    elif kind == "dropout":
        out = [jnp.where(mal(l), jnp.zeros_like(l), l) for l in flat]
    else:
        raise ValueError(f"unknown attack {kind!r}")
    return jax.tree.unflatten(treedef, out)


def dropout_mask(mask: Array, malicious: Array, kind: str) -> Array:
    """Dropout also removes the slot from aggregation weights."""
    if kind == "dropout":
        return mask & ~malicious
    return mask
