"""The FedFog round — the paper's Fig. 1 dataflow as ONE jittable step.

    schedule (Eqs. 1/2/3/7/10, over the N-client registry)
      └─ slot occupancy: top-C eligible clients by utility
    local training (Eq. 5): C slots × E local steps, fresh inner optimizer
      (serverless/stateless semantics), vmap over the slot axis — NO
      cross-client collectives during local steps (the paper's
      communication-reduction payoff)
    deltas: clip (DP sensitivity) → attacks (eval) → compression
    aggregate (Eq. 6): masked weighted reduction over the slot axis — the
      ONE inter-client collective per round (all-reduce over pod×client)
    server update: FedAvg / FedAvgM / FedAdam on the aggregated delta
    bookkeeping: cold starts (Eq. 4), energy (Eq. 10 + §IV.F), drift state

`make_round_fn` returns `round_fn(state, batch) -> (state, metrics)` ready
for jax.jit with the shardings from dist/sharding.py. Shape-static
throughout: masks, not dynamic sets.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg_mod
from repro.core import privacy as privacy_mod
from repro.core.scheduler import account_energy, schedule_round
from repro.core.selection import random_selection_mask
from repro.fl import attacks as attacks_mod
from repro.fl import fog as fog_mod
from repro.fl.compression import apply_compression, wire_bytes_per_param
from repro.fl.fuse import (
    fuse_clients,
    fuse_vector,
    fused_gaussian_noise,
    stacked_leaf_sizes,
)
from repro.fl.state import FLConfig, FLState
from repro.kernels.delta_pipeline import (
    delta_pipeline_apply,
    delta_pipeline_apply_sharded,
)
from repro.models.transformer import Runtime
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgdm
from repro.sim.des import RoundCostModel
from repro.sim.faults import config as faults_config
from repro.sim.faults import inject as faults_inject

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    kind: str = "none"  # none|label_flip|noise|dropout|model_replacement
    fraction: float = 0.0  # fraction of malicious slots
    noise_scale: float = 0.5
    replacement_scale: float = 10.0


def _inner_optimizer(fl_cfg: FLConfig):
    if fl_cfg.inner_optimizer == "adamw":
        return adamw(fl_cfg.inner_lr)
    return sgdm(fl_cfg.inner_lr, fl_cfg.inner_momentum)


def _slot_assignment(decision, fl_cfg: FLConfig, rng: Array):
    """Top-C eligible clients by utility -> (slot_client_ids, slot_mask).

    Policies (§IV.B): fedfog = utility-ranked eligible; rcs = uniform random;
    fogfaas/vanilla = fixed round-robin over all clients (no gating).
    """
    n, c = fl_cfg.num_clients, fl_cfg.slots
    sel = decision.selection
    if fl_cfg.policy == "fedfog":
        # Sort by (eligible desc, utility desc): eligible clients first.
        key_val = sel.utility - 1e6 * (~sel.mask)
        order = jnp.argsort(-key_val, stable=True)
        slot_ids = order[:c].astype(jnp.int32)
        slot_mask = sel.mask[slot_ids]
    elif fl_cfg.policy == "rcs":
        rmask = random_selection_mask(rng, n, c)
        order = jnp.argsort(-rmask.astype(jnp.int32), stable=True)
        slot_ids = order[:c].astype(jnp.int32)
        slot_mask = rmask[slot_ids]
    else:  # fogfaas / vanilla: first C clients, no FL-aware gating
        slot_ids = jnp.arange(c, dtype=jnp.int32)
        slot_mask = jnp.ones((c,), bool)
    return slot_ids, slot_mask


def make_round_fn(
    model,
    fl_cfg: FLConfig,
    runtime: Runtime = Runtime(),
    attack: AttackConfig = AttackConfig(),
    *,
    flops_per_client_round: float | None = None,
    rules=None,
):
    """Build the jittable FedFog round.

    batch dict (leading dims slot-major):
      tokens:          (global_batch, S+1) int32  [reshaped to (C, B_c, S+1)]
      patch_embeds / frames: optional modality inputs, (global_batch, ...)
      slot_data_sizes: (C,) f32 — |D_i| of each slot occupant
      telemetry_cpu/mem/batt/energy: (N,) f32
      hist:            (N, hist_bins) f32
    """
    c = fl_cfg.slots
    init_inner, update_inner = _inner_optimizer(fl_cfg)
    flops_round = flops_per_client_round or 0.0
    # §IV.F cost accounting shared with the paper-scale simulator — both
    # engines derive energy/cold-start semantics from the same model.
    cost_model = RoundCostModel.from_scheduler(fl_cfg.scheduler)
    # Pallas-fused delta pipeline: clip → compression emulation →
    # aggregate (Eq. 6 / in-kernel median / trimmed) → DP noise → server
    # momentum → apply, in ONE HBM pass over the fused (C, P) buffer
    # (plus a norm-reduction pass when clipping — kernels/delta_pipeline).
    # Single-host: every aggregator runs in-kernel; delta attacks
    # (noise/dropout/model_replacement) land between clip and compress,
    # so those two stages split out of the kernel (clip+corrupt outside,
    # compression onward fused). Under mesh `rules` the sharded entry
    # (`delta_pipeline_apply_sharded`) runs the same pipeline per client
    # shard with exactly ONE cross-shard psum — the one-all-reduce HLO
    # contract holds on the fast path too. Robust aggregators need the
    # full client axis on-device to sort, so under rules they keep the
    # reference (fused-buffer all-reduce) path. Full matrix:
    # docs/EXPERIMENTS.md "Pipeline-kernel gates".
    use_pallas = fl_cfg.use_pallas_agg and rules is None
    use_pallas_sharded = (
        fl_cfg.use_pallas_agg
        and rules is not None
        and fl_cfg.aggregator == "fedavg"
        and attack.kind == "none"
    )
    # Population mode: the scheduler registry is (M,)-sized; each round
    # gathers a stratified N-client window's rows and scatters them back.
    # Dense mode (population unset or == num_clients) keeps the flat
    # round VERBATIM — bitwise oracle discipline.
    pop_mode = (
        fl_cfg.population is not None
        and fl_cfg.population != fl_cfg.num_clients
    )
    # Fault layer (repro.sim.faults): Python-level gate — with the plan
    # off, every line below is the verbatim pre-fault round (bitwise
    # contract, same as the paper-scale simulator's gate).
    faults_on = faults_config.active(fl_cfg.faults)

    # Pod-scale sharding constraints: pin the slot-stacked replicas to the
    # client axis (and moments to the ZeRO axis) instead of trusting GSPMD
    # propagation through the broadcast.
    if rules is not None:
        shapes, laxes = model.param_shapes(), model.param_axes()
        _stacked = rules.shardings(
            rules.param_specs(shapes, laxes, stacked=True)
        )
        _stacked_opt = rules.shardings(
            rules.opt_spec_tree(shapes, laxes, stacked=True)
        )

        def constrain_stacked(t):
            return jax.lax.with_sharding_constraint(t, _stacked)

        def constrain_opt_tree(t):
            return jax.lax.with_sharding_constraint(t, _stacked_opt)

        from jax.sharding import NamedSharding, PartitionSpec as P

        _client_ent = rules._as_spec_entry(rules.plan.client_axes)
        _zero_ent = "zero" if "zero" in rules.mesh.shape else None

        def fuse_deltas(tree, shard_p=True):
            """Concat every delta leaf into ONE (C, P) f32 buffer so the
            cross-client aggregation lowers to a single all-reduce — the
            paper's one-collective-per-round contract, asserted by
            dist.hlo_analysis on the compiled round. Returns the buffer
            and the inverse (split + reshape + cast back).
            ``shard_p=False`` gives the sharded-kernel layout (client
            axis split, full P rows per shard)."""
            cat, unfuse = fuse_clients(tree)
            cat = jax.lax.with_sharding_constraint(
                cat,
                rules.fused_delta_sharding(cat.shape[1], shard_p=shard_p),
            )
            return cat, unfuse

        def constrain_batch(tree):
            """Pin slot-major batches to (client, zero, ...) so activations
            keep the intra-slot data sharding through the reshape."""
            def one(x):
                spec = P(_client_ent, _zero_ent, *([None] * (x.ndim - 2)))
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(rules.mesh, spec)
                )

            return jax.tree.map(one, tree)
    else:
        constrain_stacked = constrain_opt_tree = lambda t: t
        constrain_batch = lambda t: t
        fuse_deltas = None

    def per_slot_loss(params_c, batch_c):
        return model.loss(params_c, batch_c, runtime)

    def round_fn(state: FLState, batch) -> tuple[FLState, dict]:
        from repro.core.types import ClientTelemetry

        rng, k_sched, k_attack, k_dp, k_mal = jax.random.split(state.rng, 5)

        # ---- 1. schedule over the N-client registry (Eqs. 1/2/3/7) ----- #
        telemetry = ClientTelemetry(
            cpu=batch["telemetry_cpu"],
            mem=batch["telemetry_mem"],
            batt=batch["telemetry_batt"],
            energy=batch["telemetry_energy"],
        )
        if pop_mode:
            # Sample the round's scheduling window from the (M,) registry
            # (fold_in key 7 — disjoint from the 5-way round split) and
            # gather its scheduler rows; the batch's telemetry/hist rows
            # are window-positional (the caller feeds N rows for the
            # window, not the whole population).
            window_ids = fog_mod.stratified_cohort(
                jax.random.fold_in(state.rng, 7),
                fl_cfg.population, fl_cfg.num_clients,
            )
            sched_view = fog_mod.gather_sched_rows(state.sched, window_ids)
        else:
            window_ids = None
            sched_view = state.sched
        decision = schedule_round(
            sched_view, telemetry, batch["hist"], fl_cfg.scheduler
        )
        slot_ids, slot_mask = _slot_assignment(decision, fl_cfg, k_sched)
        slot_sizes = batch["slot_data_sizes"]

        # ---- 2. local training: C slots × E local steps --------------- #
        def to_slots(x):
            return x.reshape((c, x.shape[0] // c) + x.shape[1:])

        model_batch = constrain_batch(
            {
                k: to_slots(v)
                for k, v in batch.items()
                if k in ("tokens", "patch_embeds", "frames")
            }
        )
        if attack.kind == "label_flip":
            n_mal = int(round(attack.fraction * c))
            malicious = jnp.arange(c) < n_mal
            malicious = jax.random.permutation(k_mal, malicious)
            model_batch["tokens"] = attacks_mod.flip_labels(
                model_batch["tokens"], malicious, model.cfg.vocab_size
            )
        elif attack.kind != "none":
            n_mal = int(round(attack.fraction * c))
            malicious = jax.random.permutation(
                k_mal, jnp.arange(c) < n_mal
            )
        else:
            malicious = jnp.zeros((c,), bool)

        params0 = state.params
        params_stacked = constrain_stacked(
            jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (c,) + p.shape), params0
            )
        )
        inner_state = init_inner(params_stacked)
        inner_state = inner_state._replace(
            mu=constrain_opt_tree(inner_state.mu),
            nu=None if inner_state.nu is None else constrain_opt_tree(inner_state.nu),
        )

        grad_fn = jax.vmap(jax.value_and_grad(per_slot_loss))

        if fl_cfg.microbatch > 1:
            # Gradient accumulation: scan over micro-splits of each slot's
            # batch, accumulating fp32 grads. Bounds live activations to one
            # microbatch's worth — the decisive train-memory knob at 14B+.
            mb = fl_cfg.microbatch

            def grad_fn(params_s, batch_s):  # noqa: F811
                micro = {
                    k: jnp.moveaxis(
                        v.reshape((v.shape[0], mb, v.shape[1] // mb) + v.shape[2:]),
                        1, 0,
                    )
                    for k, v in batch_s.items()
                }

                def acc_step(carry, mbatch):
                    g_acc, l_acc = carry
                    loss, g = jax.vmap(jax.value_and_grad(per_slot_loss))(
                        params_s, mbatch
                    )
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    return (g_acc, l_acc + jnp.mean(loss)), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params_s
                )
                (g, l), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), micro)
                g = jax.tree.map(lambda a: (a / mb), g)
                return l / mb, g

        if fl_cfg.local_steps == 1:
            loss, grads = grad_fn(params_stacked, model_batch)
            updates, inner_state2 = update_inner(grads, inner_state, params_stacked)
            params_stacked = apply_updates(params_stacked, updates)
            mean_loss = jnp.mean(loss)
        else:
            # Split each slot's batch into E microbatches along the batch dim.
            e = fl_cfg.local_steps

            def split_steps(x):  # (C, B_c, ...) -> (E, C, B_c/E, ...)
                b_c = x.shape[1]
                return jnp.moveaxis(
                    x.reshape((c, e, b_c // e) + x.shape[2:]), 1, 0
                )

            micro = {k: split_steps(v) for k, v in model_batch.items()}

            def one_step(carry, mb):
                params_s, inner, _ = carry
                loss, grads = grad_fn(params_s, mb)
                updates, inner = update_inner(grads, inner, params_s)
                params_s = apply_updates(params_s, updates)
                return (params_s, inner, jnp.mean(loss)), None

            (params_stacked, inner_state2, mean_loss), _ = jax.lax.scan(
                one_step, (params_stacked, inner_state, jnp.zeros(())), micro
            )
        del inner_state2

        # ---- 3. deltas: clip → attack → compress ----------------------- #
        deltas = jax.tree.map(
            lambda p, p0: (
                p.astype(jnp.float32) - p0.astype(jnp.float32)[None]
            ).astype(p.dtype),
            params_stacked,
            params0,
        )
        use_kernel = use_pallas or use_pallas_sharded
        # Delta attacks land BETWEEN clip and compress, so when the
        # kernel path is on those two stages split: reference clip +
        # corrupt here, compression onward stays fused (the kernel then
        # runs with clip_norm=0).
        split_clip = use_kernel and attack.kind not in ("none", "label_flip")
        if not use_kernel:
            # Reference pipeline: one XLA pass per stage per leaf. On
            # the fused path these stages all fold into the kernel call
            # below.
            if fl_cfg.clip_norm > 0:
                deltas = jax.vmap(
                    lambda d: clip_by_global_norm(d, fl_cfg.clip_norm)[0]
                )(deltas)
            if attack.kind not in ("none", "label_flip"):
                deltas = attacks_mod.corrupt_deltas(
                    deltas, malicious, attack.kind, k_attack,
                    noise_scale=attack.noise_scale,
                    replacement_scale=attack.replacement_scale,
                )
                slot_mask = attacks_mod.dropout_mask(
                    slot_mask, malicious, attack.kind
                )
            deltas = apply_compression(
                deltas, fl_cfg.compression, fl_cfg.topk_fraction
            )
        elif split_clip:
            if fl_cfg.clip_norm > 0:
                deltas = jax.vmap(
                    lambda d: clip_by_global_norm(d, fl_cfg.clip_norm)[0]
                )(deltas)
            deltas = attacks_mod.corrupt_deltas(
                deltas, malicious, attack.kind, k_attack,
                noise_scale=attack.noise_scale,
                replacement_scale=attack.replacement_scale,
            )
            slot_mask = attacks_mod.dropout_mask(
                slot_mask, malicious, attack.kind
            )

        # ---- 3b. fault plan: who actually arrives (repro.sim.faults) --- #
        # Slot-level serverless failure plan: retries with backoff, fog
        # outages, deadline losses and the quorum decision, drawn from a
        # key chain disjoint from the round's 5-way split (fold_in 11) so
        # faulted runs replay deterministically per seed. The arrival
        # mask replaces ``slot_mask`` BEFORE aggregation, so Eq. 6
        # reweights over the arrivals only, on every aggregation path
        # (reference, fog tier, fused kernel, sharded kernel).
        fault_counters = faults_inject.zero_counters()
        fault_skip = None
        fault_round_ms = None
        if faults_on:
            fc = fl_cfg.faults
            k_fplan, k_fnoise = jax.random.split(
                jax.random.fold_in(state.rng, 11)
            )
            # Under mesh rules the plan must run as a replicated island:
            # its (slots,) pred chains mix gathers from client-sharded
            # arrays, and letting the SPMD partitioner reshard those mid-
            # chain has been observed to MISCOMPILE (spmd_partitioner
            # "involuntary full rematerialization" + wrong fail masks),
            # breaking sharded-vs-plain fault replay. The arrays are
            # tiny, so replication is free.
            _rep = (
                (lambda t: jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(rules.mesh, P())
                    ), t))
                if rules is not None else (lambda t: t)
            )
            plan = faults_inject.plan_round(
                fc, k_fplan, _rep(slot_mask),
                _rep(~sched_view.warm[slot_ids]),
                _rep(decision.delays_ms[slot_ids]),
                fog_nodes=fl_cfg.fog_nodes,
            )
            plan = _rep(plan)
            # Partitionable threefry for the payload noise: legacy
            # (non-partitionable) threefry draws DIFFERENT bits under a
            # multi-device lowering depending on the leaf's sharding
            # spec, which would make a faulted sharded round diverge
            # from its single-host replay by O(corrupt_scale). The
            # context only rebinds the bit generator for these draws.
            with jax.threefry_partitionable(True):
                deltas = attacks_mod.corrupt_deltas(
                    deltas, plan.corrupt, "noise", k_fnoise,
                    noise_scale=fc.corrupt_scale,
                )
            slot_mask = plan.arrived
            fault_counters = plan.counters
            fault_skip = plan.skip
            fault_round_ms = plan.round_ms

        # ---- 4+5. aggregate (Eq. 6) + server update -------------------- #
        if use_kernel:
            # Fused delta-pipeline kernel: clip, compression emulation,
            # aggregation, DP noise, server momentum and the apply all
            # happen in one pass over the fused (C, P) buffer — the
            # memory-bound pipeline never re-reads the delta stack from
            # HBM (clipping adds one norm-reduction pass). Under mesh
            # rules the buffer is client-sharded and the sharded entry
            # combines per-shard partial sums with ONE psum.
            if use_pallas_sharded:
                cat_d, _ = fuse_deltas(deltas, shard_p=False)
            else:
                cat_d, _ = fuse_clients(deltas)
            base_flat, unfuse_vec = fuse_vector(params0)
            seg = stacked_leaf_sizes(deltas)
            noise = None
            if fl_cfg.dp_sigma > 0:
                noise = fused_gaussian_noise(
                    k_dp,
                    fl_cfg.dp_sigma * (fl_cfg.clip_norm or 1.0),
                    seg,
                    [x.shape for x in jax.tree.leaves(params0)],
                )
            mu_flat = unfuse_mu = None
            if (
                fl_cfg.server_optimizer in ("fedavgm", "fedadam")
                and state.server_mu is not None
            ):
                mu_flat, unfuse_mu = fuse_vector(state.server_mu)
            kernel_clip = 0.0 if split_clip else fl_cfg.clip_norm
            kw = dict(
                lr=fl_cfg.server_lr, dp_noise=noise, momentum=mu_flat,
                clip_norm=kernel_clip,
                compression=fl_cfg.compression,
                topk_fraction=fl_cfg.topk_fraction,
                seg_sizes=seg,
                server_optimizer=fl_cfg.server_optimizer,
                server_momentum=fl_cfg.server_momentum,
            )
            if use_pallas_sharded:
                outs = delta_pipeline_apply_sharded(
                    cat_d, base_flat, slot_mask, slot_sizes,
                    mesh=rules.mesh, client_axes=rules.plan.client_axes,
                    fog_nodes=fl_cfg.fog_nodes,
                    **kw,
                )
            elif fl_cfg.fog_nodes > 1:
                # Single-host fog tier: one delta_pipeline_partial pass
                # per fog's contiguous slot block + the shared cloud
                # epilogue (fl/fog.py; fedavg-only, enforced by config).
                outs = fog_mod.fog_pipeline_apply(
                    cat_d, base_flat, slot_mask, slot_sizes,
                    fog_nodes=fl_cfg.fog_nodes,
                    **kw,
                )
            else:
                outs = delta_pipeline_apply(
                    cat_d, base_flat, slot_mask, slot_sizes,
                    trim_fraction=fl_cfg.trim_fraction,
                    aggregator=fl_cfg.aggregator,
                    **kw,
                )
            if mu_flat is not None:
                new_flat, new_mu_flat = outs
                new_mu = unfuse_mu(new_mu_flat)
            else:
                new_flat, new_mu = outs, state.server_mu
            new_params = unfuse_vec(new_flat)
            new_count = state.server_count + 1
        else:
            # On the pod-scale path the leaves are fused into one (C, P)
            # buffer first, so ALL the cross-client traffic of the round
            # is a single all-reduce instead of one per parameter tensor.
            agg_in, unfuse = (
                fuse_deltas(deltas) if fuse_deltas is not None
                else (deltas, None)
            )
            if fl_cfg.aggregator == "median":
                agg = agg_mod.median_aggregate(agg_in, slot_mask)
            elif fl_cfg.aggregator == "trimmed":
                agg = agg_mod.trimmed_mean_aggregate(
                    agg_in, slot_mask, fl_cfg.trim_fraction
                )
            elif fl_cfg.fog_nodes > 1:
                # Hierarchical Eq. 6 on the reference path: fog partials
                # → cloud combine (float-reassociated flat aggregate).
                if unfuse is not None:
                    agg = fog_mod.fog_aggregate(
                        agg_in, slot_mask, slot_sizes, fl_cfg.fog_nodes
                    )
                else:
                    agg = fog_mod.fog_aggregate_tree(
                        agg_in, slot_mask, slot_sizes, fl_cfg.fog_nodes
                    )
            else:
                agg = agg_mod.fedavg_stacked(agg_in, slot_mask, slot_sizes)
            if unfuse is not None:
                agg = unfuse(agg)
            if fl_cfg.dp_sigma > 0:
                dp = privacy_mod.DPConfig(
                    sigma=fl_cfg.dp_sigma,
                    sensitivity=fl_cfg.clip_norm or 1.0,
                )
                agg = privacy_mod.gaussian_mechanism(agg, k_dp, dp)
            new_params, new_mu, new_count = _server_update(
                fl_cfg, params0, agg, state.server_mu, state.server_count
            )

        if fault_skip is not None:
            # Below-quorum round: the model (and server optimizer state)
            # carries over bitwise — the attempted aggregate is discarded.
            new_params = jax.tree.map(
                lambda p, q: jnp.where(fault_skip, p, q), params0, new_params
            )
            if state.server_mu is not None:
                new_mu = jax.tree.map(
                    lambda p, q: jnp.where(fault_skip, p, q),
                    state.server_mu, new_mu,
                )
            new_count = jnp.where(fault_skip, state.server_count, new_count)

        # ---- 6. energy / cold-start / drift bookkeeping ---------------- #
        # Per-LOGICAL-client energy: compute ∝ FLOPs for selected clients,
        # uplink ∝ compressed delta bytes (§IV.F) — via the shared DES
        # cost model (repro.sim.des).
        tx_bytes = wire_bytes_per_param(
            fl_cfg.compression, fl_cfg.topk_fraction
        ) * float(model.param_count())
        round_energy_j = cost_model.energy_j(
            decision.selection.mask, sched_view.warm, flops_round, tx_bytes
        )
        if faults_on:
            # Every launched attempt repays the slot's full per-round
            # energy (a crashed function restarts from the global model);
            # non-slot selected clients keep the 1× baseline.
            round_energy_j = round_energy_j * (
                jnp.ones_like(round_energy_j)
                .at[slot_ids]
                .set(jnp.maximum(plan.attempts, 1.0))
            )
        advanced = account_energy(
            decision.new_state, round_energy_j, fl_cfg.scheduler
        )
        if pop_mode:
            # Scatter the window's advanced rows back into the (M,)
            # registry; unsampled clients stay frozen until next sampled.
            new_sched = fog_mod.scatter_sched_rows(
                state.sched, window_ids, advanced
            )
        else:
            new_sched = advanced

        new_state = FLState(
            params=new_params,
            server_mu=new_mu,
            server_count=new_count,
            sched=new_sched,
            rng=rng,
            step=state.step + 1,
        )
        metrics = {
            "loss": mean_loss,
            "num_selected": decision.selection.num_selected,
            "slot_participation": jnp.sum(slot_mask.astype(jnp.int32)),
            "cold_starts": decision.cold_starts,
            # Synchronous round latency = slowest selected client (§III.H);
            # under faults the retry/backoff chain (deadline-capped).
            "round_latency_ms": (
                fault_round_ms
                if fault_round_ms is not None
                else jnp.max(
                    jnp.where(slot_mask, decision.delays_ms[slot_ids], 0.0)
                )
            ),
            "energy_j": jnp.sum(round_energy_j),
            "mean_utility": jnp.mean(decision.selection.utility),
            "mean_drift": jnp.mean(decision.selection.drift),
            # Fault/recovery counters — structurally always present
            # (zeros when the plan is off) so history schemas are stable
            # across faulted and clean runs.
            **fault_counters,
        }
        return new_state, metrics

    return round_fn


def _server_update(fl_cfg: FLConfig, params0, agg, mu, count):
    lr = fl_cfg.server_lr
    count = count + 1
    if fl_cfg.server_optimizer == "fedavg" or mu is None:
        new_params = jax.tree.map(
            lambda p, a: (p.astype(jnp.float32) + lr * a.astype(jnp.float32)).astype(
                p.dtype
            ),
            params0,
            agg,
        )
        return new_params, mu, count
    m = fl_cfg.server_momentum
    new_mu = jax.tree.map(
        lambda mu_l, a: m * mu_l + a.astype(jnp.float32), mu, agg
    )
    if fl_cfg.server_optimizer == "fedadam":
        # Adam-style with a fixed epsilon on the aggregated delta magnitude.
        new_params = jax.tree.map(
            lambda p, mu_l, a: (
                p.astype(jnp.float32)
                + lr * mu_l / (jnp.sqrt(jnp.square(a.astype(jnp.float32))) + 1e-3)
            ).astype(p.dtype),
            params0,
            new_mu,
            agg,
        )
    else:  # fedavgm
        new_params = jax.tree.map(
            lambda p, mu_l: (p.astype(jnp.float32) + lr * mu_l).astype(p.dtype),
            params0,
            new_mu,
        )
    return new_params, new_mu, count
