"""Federated training state + round configuration.

Key design decision (DESIGN.md §2): client slots are *stateless between
rounds*, mirroring the paper's serverless execution model — a training
"function invocation" receives the global model, runs E local steps with a
fresh inner optimizer, and returns a delta. Only the global model, the
server optimizer state and the (tiny, N-client) scheduler state persist.
This is also the memory win that lets 14B+ archs fit: no per-slot Adam
moments live across rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scheduler import SchedulerConfig
from repro.core.types import SchedulerState, _pytree_dataclass
from repro.sim.faults.config import FaultConfig


@_pytree_dataclass
class FLState:
    params: Any  # global model pytree (unstacked)
    server_mu: Any  # fp32 server momentum tree or None
    server_count: jax.Array  # () int32
    sched: SchedulerState  # N-client scheduler state
    rng: jax.Array
    step: jax.Array  # () int32 round index


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """One place for every FedFog-round knob."""

    num_clients: int = 64  # N: scheduling window per round (registry rows)
    slots: int = 16  # C: concurrent hardware cohort slots
    # M: virtual client population (None → dense: registry == window).
    # When set, the scheduler registry is (M,)-sized and each round
    # samples a stratified N-client window (fold_in(rng, 7)), gathers its
    # rows, schedules/trains/aggregates at window/slot size, and scatters
    # the advanced rows back — round cost never depends on M. Structural
    # for the sweep layer (Python-level branch). Unlike the paper-scale
    # simulator, the runtime keeps the full (M, hist_bins) drift table:
    # batch histograms are opaque caller data, not recomputable.
    population: int | None = None
    # F: fog tier width of the edge → fog → cloud reduction over the
    # slot axis (fl/fog.py; under mesh rules the pod axis is the fog
    # tier). 1 = flat, bitwise identical to the pre-fog path; > 1
    # requires aggregator="fedavg".
    fog_nodes: int = 1
    local_steps: int = 1  # E: local epochs/steps per round (Eq. 5)
    microbatch: int = 1  # gradient-accumulation splits per local step
    hist_bins: int = 64  # drift histogram buckets

    # Inner (client) optimizer — fresh every round (serverless).
    inner_optimizer: str = "sgdm"  # "sgdm" | "adamw"
    inner_lr: float = 0.02
    inner_momentum: float = 0.9

    # Server (outer) optimizer on aggregated deltas.
    server_optimizer: str = "fedavgm"  # "fedavg" | "fedavgm" | "fedadam"
    server_lr: float = 1.0
    server_momentum: float = 0.9

    # Aggregation & robustness.
    aggregator: str = "fedavg"  # "fedavg" | "median" | "trimmed"
    trim_fraction: float = 0.1  # trimmed-mean tail fraction per side
    clip_norm: float = 0.0  # per-client delta clip (0 = off); DP sensitivity S
    dp_sigma: float = 0.0  # central DP noise scale (0 = off)
    compression: str = "none"  # "none" | "int8" | "topk"
    topk_fraction: float = 0.05
    # Fuse the whole server-side delta pipeline — clip, top-k/int8
    # compression emulation, aggregation (Eq. 6 weighted sum, or the
    # in-kernel bitonic median / trimmed-mean selection), DP noise,
    # server momentum, apply — into the Pallas kernel family
    # (kernels/delta_pipeline): one HBM pass over the fused (C, P)
    # delta buffer (clipping adds a norm-reduction pass). Single-host,
    # every aggregator and attack config runs in-kernel (delta attacks
    # split clip+corrupt out, keeping compression onward fused). Under
    # mesh rules the FedAvg/no-attack configs route through the sharded
    # entry (one cross-shard psum — the one-all-reduce HLO contract
    # holds on the fast path); median/trimmed under rules keep the
    # reference path. Full matrix: docs/EXPERIMENTS.md.
    use_pallas_agg: bool = False

    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)

    # Baseline switches (§IV.B): "fedfog" | "rcs" | "fogfaas" | "vanilla"
    policy: str = "fedfog"

    # Fault-injection + recovery plan (repro.sim.faults). None or an
    # all-off plan leaves the round VERBATIM (Python-level gate) — the
    # faults-off bitwise contract holds on the pod-scale path too.
    faults: FaultConfig | None = None

    def __post_init__(self):
        assert self.slots >= 1 and self.num_clients >= self.slots
        if self.population is not None and self.population < self.num_clients:
            raise ValueError(
                f"population={self.population} must be >= the scheduling "
                f"window num_clients={self.num_clients}"
            )
        from repro.fl.fog import validate_fog_config

        validate_fog_config(self.fog_nodes, self.slots, self.aggregator)
        if self.faults is not None:
            from repro.sim.faults.config import validate as _validate_faults

            _validate_faults(self.faults)


def init_fl_state(model, fl_cfg: FLConfig, key: jax.Array,
                  server_mu: bool | None = None) -> FLState:
    from repro.core.types import init_scheduler_state

    k_params, k_rng = jax.random.split(key)
    params = model.init(k_params)
    use_mu = (
        fl_cfg.server_optimizer in ("fedavgm", "fedadam")
        if server_mu is None
        else server_mu
    )
    mu = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if use_mu
        else None
    )
    return FLState(
        params=params,
        server_mu=mu,
        server_count=jnp.zeros((), jnp.int32),
        sched=init_scheduler_state(
            fl_cfg.population or fl_cfg.num_clients,
            fl_cfg.hist_bins, fl_cfg.scheduler.theta_e,
        ),
        rng=k_rng,
        step=jnp.zeros((), jnp.int32),
    )


def abstract_fl_state(model, fl_cfg: FLConfig) -> FLState:
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: init_fl_state(model, fl_cfg, k), jax.random.PRNGKey(0)
    )
