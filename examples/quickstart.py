"""Quickstart: one FedFog round on a tiny LM, end to end.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API: build a model, configure the FedFog round (scheduler
thresholds straight from the paper), feed telemetry + histograms, train.
"""
import jax
import jax.numpy as jnp

from repro.core.scheduler import SchedulerConfig
from repro.fl import FLConfig, init_fl_state, make_round_fn
from repro.models import Family, ModelConfig, build_model


def main():
    cfg = ModelConfig(
        name="quickstart-lm", family=Family.DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        remat=False, loss_chunk=0,
    )
    model = build_model(cfg)

    fl = FLConfig(
        num_clients=16,  # N: registered edge clients
        slots=4,  # C: concurrent training slots
        local_steps=2,  # E in Eq. 5
        scheduler=SchedulerConfig(  # paper defaults (§III.I)
            theta_h=0.6, theta_e=0.5, theta_d=0.1
        ),
    )
    key = jax.random.PRNGKey(0)
    state = init_fl_state(model, fl, key)
    round_fn = jax.jit(make_round_fn(model, fl, flops_per_client_round=1e9))

    for r in range(5):
        key, k = jax.random.split(key)
        ks = jax.random.split(k, 7)
        batch = {
            "tokens": jax.random.randint(ks[0], (16, 33), 0, cfg.vocab_size),
            "slot_data_sizes": jnp.array([100.0, 220.0, 80.0, 150.0]),
            "telemetry_cpu": jax.random.uniform(ks[1], (16,), minval=0.4, maxval=1.0),
            "telemetry_mem": jax.random.uniform(ks[2], (16,), minval=0.4, maxval=1.0),
            "telemetry_batt": jax.random.uniform(ks[3], (16,), minval=0.3, maxval=1.0),
            "telemetry_energy": jax.random.uniform(ks[4], (16,), minval=0.4, maxval=1.0),
            "hist": jnp.abs(jax.random.normal(ks[5], (16, fl.hist_bins))) + 1.0,
        }
        state, m = round_fn(state, batch)
        print(
            f"round {r}: loss={float(m['loss']):.4f} "
            f"selected={int(m['num_selected'])}/16 "
            f"cold_starts={int(m['cold_starts'])} "
            f"latency={float(m['round_latency_ms']):.0f}ms "
            f"energy={float(m['energy_j']):.2f}J"
        )


if __name__ == "__main__":
    main()
