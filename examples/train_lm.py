"""End-to-end driver: federated training of a ~100M-parameter LM.

    PYTHONPATH=src python examples/train_lm.py --rounds 300          # full
    PYTHONPATH=src python examples/train_lm.py --preset small --rounds 20

Everything is real: synthetic non-IID federated token streams with drift,
telemetry-driven FedFog scheduling, serverless-semantics local training,
weighted FedAvg + server momentum, async checkpointing with auto-resume
(kill it mid-run and start again with --resume).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.core.scheduler import SchedulerConfig
from repro.data.synthetic import (
    FedDataConfig,
    all_client_histograms,
    client_data_sizes,
    round_batch,
)
from repro.data.telemetry import (
    TelemetryConfig,
    init_telemetry,
    make_profiles,
    step_telemetry,
)
from repro.fl import FLConfig, init_fl_state, make_round_fn
from repro.models import Family, ModelConfig, Runtime, build_model

PRESETS = {
    # ~103M params: the deliverable-scale end-to-end config.
    "100m": dict(num_layers=12, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32768, seq=256,
                 batch_per_slot=2),
    # CPU-friendly sanity scale.
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024, vocab_size=4096, seq=128,
                  batch_per_slot=2),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=PRESETS)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--inner-lr", type=float, default=0.08)
    ap.add_argument("--ckpt-dir", default="/tmp/fedfog_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    seq = p.pop("seq")
    batch_per_slot = p.pop("batch_per_slot")
    cfg = ModelConfig(
        name=f"fedfog-lm-{args.preset}", family=Family.DENSE, remat=False,
        loss_chunk=0, **p,
    )
    model = build_model(cfg)
    print(f"model: {model.param_count()/1e6:.1f}M params")

    fl = FLConfig(
        num_clients=args.clients, slots=args.slots,
        local_steps=args.local_steps, inner_lr=args.inner_lr,
        server_optimizer="fedavgm",
        scheduler=SchedulerConfig(theta_h=0.6, theta_e=0.5, theta_d=0.3),
    )
    data_cfg = FedDataConfig(vocab_size=cfg.vocab_size, drift_period=50,
                             seed=args.seed)
    tel_cfg = TelemetryConfig(num_clients=args.clients, seed=args.seed)
    profiles = make_profiles(tel_cfg)
    telemetry = init_telemetry(tel_cfg)
    sizes = client_data_sizes(data_cfg, args.clients)

    state = init_fl_state(model, fl, jax.random.PRNGKey(args.seed))
    start = 0
    checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
    if args.resume:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest, state)
            start = latest
            print(f"resumed from round {latest}")

    tokens_per_client = batch_per_slot * seq * args.local_steps
    round_fn = jax.jit(
        make_round_fn(
            model, fl, Runtime(),
            flops_per_client_round=model.flops_per_token() * tokens_per_client,
        ),
        donate_argnums=(0,),
    )

    data_key = jax.random.PRNGKey(args.seed + 1)
    for r in range(start, args.rounds):
        t0 = time.time()
        data_key, kb, kt = jax.random.split(data_key, 3)
        r_idx = jnp.asarray(r, jnp.int32)
        slot_ids = (jnp.arange(fl.slots) * 7 + r * fl.slots) % args.clients
        batch = {
            "tokens": round_batch(
                data_cfg, slot_ids, r_idx, kb,
                batch_per_slot * args.local_steps, seq,
            ),
            "slot_data_sizes": sizes[slot_ids],
            "telemetry_cpu": telemetry.cpu,
            "telemetry_mem": telemetry.mem,
            "telemetry_batt": telemetry.batt,
            "telemetry_energy": telemetry.energy,
            "hist": all_client_histograms(data_cfg, args.clients, r_idx,
                                          fl.hist_bins),
        }
        state, m = round_fn(state, batch)
        participated = jnp.zeros((args.clients,), bool).at[slot_ids].set(True)
        telemetry = step_telemetry(
            tel_cfg, telemetry, participated, jnp.zeros((args.clients,)),
            profiles, kt,
        )
        if r % 5 == 0 or r == args.rounds - 1:
            loss = float(m["loss"])
            print(
                f"[round {r:4d}] loss={loss:.4f} ppl={jnp.exp(loss):.1f} "
                f"selected={int(m['num_selected'])} "
                f"cold={int(m['cold_starts'])} "
                f"({time.time() - t0:.2f}s)",
                flush=True,
            )
        if (r + 1) % args.ckpt_every == 0:
            checkpointer.save(r + 1, state)
    checkpointer.wait()
    print("done")


if __name__ == "__main__":
    main()
