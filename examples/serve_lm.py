"""Serving example: static batch or continuous batching with any --arch.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --gen 32

    # Continuous batching: Poisson arrivals at 40 req/s into 8 slots,
    # 2s latency SLO, slot-level eviction/refill on ONE decode executable.
    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b \
        --engine continuous --requests 32 --rate 40 --slots 8 --slo-ms 2000

    # Same trace through the Pallas paged flash-decode kernel, streaming
    # per-step metrics through the obs tracker stack.
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b \
        --engine continuous --attn paged --track jsonl:/tmp/serve.jsonl

Runs the reduced (smoke-scale) config on CPU; the same driver serves full
configs on a TPU pod via launch/serve.py --scale full (sequence-sharded KV
for long-context cells, see DESIGN.md §4).
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
