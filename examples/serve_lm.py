"""Serving example: batched prefill + decode with any assigned --arch.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --gen 32

Runs the reduced (smoke-scale) config on CPU; the same driver serves full
configs on a TPU pod via launch/serve.py --scale full (sequence-sharded KV
for long-context cells, see DESIGN.md §4).
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
