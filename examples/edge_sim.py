"""Paper-faithful edge simulation: the FedFog scenario of §IV end to end.

    PYTHONPATH=src python examples/edge_sim.py [--rounds 30] [--clients 48]

Reproduces the qualitative story of the paper's Figures 5-9 on the
EMNIST-like task: FedFog vs FogFaaS vs Random Client Selection, with data
drift injected mid-run and 10% label-flipping adversaries — printing
accuracy / latency / energy / cold-start traces per policy.

``--engine scan`` (default) runs each experiment as ONE compiled XLA
program (jax.lax.scan over rounds); ``--engine loop`` keeps the per-round
jitted loop for streaming/debugging; ``--engine async`` swaps in the
event-driven engine (repro.sim.events) — FedBuff-style buffered
aggregation on a continuous virtual clock, with a straggler tail and
client churn, printing the flush timeline instead of the round table.
``--sweep-seeds K`` additionally demos the sweep API: all K seeds of all
three policies vmapped/compiled per policy, reported as mean ± 95% CI.

``--population M`` scales the virtual client registry past the cohort:
scheduler/telemetry state is kept for all M clients while every round
samples a stratified ``--clients``-sized cohort, so per-round cost stays
cohort-sized (try ``--population 1000000``). ``--fog-nodes F`` engages
the hierarchical edge → fog → cloud reduction: the cohort is split into
F contiguous groups, each fog node computes partial Eq. 6 sums, and the
cloud combines them (requires ``aggregator=fedavg``; F must divide the
cohort). Both default to the flat dense setup, which they reproduce
bitwise.
"""
import argparse

from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.obs import MetricTap, NoopTracker, tracker_from_spec
from repro.sim.faults import FaultConfig

# Short spec keys for --faults (comma-separated k=v pairs; bare
# "failover" sets the flag): crash=0.2,retries=2,deadline=4000,quorum=0.5
_FAULT_KEYS = {
    "timeout": "timeout_rate",
    "crash": "crash_rate",
    "drop": "drop_rate",
    "corrupt": "corrupt_rate",
    "partition": "partition_rate",
    "outage": "fog_outage_rate",
    "failover": "fog_failover",
    "retries": "max_retries",
    "backoff": "backoff_base_ms",
    "deadline": "deadline_ms",
    "quorum": "quorum_frac",
}


def parse_faults(spec: str) -> FaultConfig | None:
    """``--faults`` spec → FaultConfig ('' → None → verbatim engines)."""
    if not spec:
        return None
    kw = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "=" not in item:
            if item != "failover":
                raise SystemExit(f"--faults: bad item {item!r} "
                                 f"(known: {', '.join(_FAULT_KEYS)})")
            kw["fog_failover"] = True
            continue
        k, v = item.split("=", 1)
        if k not in _FAULT_KEYS:
            raise SystemExit(f"--faults: unknown key {k!r} "
                             f"(known: {', '.join(_FAULT_KEYS)})")
        field = _FAULT_KEYS[k]
        kw[field] = int(v) if field == "max_retries" else float(v)
    return FaultConfig(**kw)


def _make_tap(tracker, args, channel: str, **const):
    """A tap when tracking is on, else None (tap-free trace)."""
    if isinstance(tracker, NoopTracker):
        return None  # no sink — keep the engines untapped
    return MetricTap(
        tracker, every=args.track_every, const=const, channel=channel
    )


def sweep_demo(args, tracker) -> None:
    """Sweep-API example: policies × seeds as compiled programs."""
    from repro.sim import run_sweep

    cfg = SimulatorConfig(
        task="emnist",
        num_clients=args.clients,
        rounds=args.rounds,
        top_k=args.topk,
        drift_period=args.rounds // 2,
        attack="label_flip",
        attack_fraction=0.1,
        population=args.population,
        fog_nodes=args.fog_nodes,
        faults=parse_faults(args.faults),
    )
    res = run_sweep(
        cfg,
        seeds=range(args.sweep_seeds),
        axes={"policy": ["fedfog", "fogfaas", "rcs"]},
        tracker=None if isinstance(tracker, NoopTracker) else tracker,
    )
    mean, ci = res.mean_ci("accuracy")
    print(f"\n=== sweep: final accuracy over {args.sweep_seeds} seeds ===")
    for g, ov in enumerate(res.configs):
        print(f"{ov['policy']:10s} {mean[g, -1]:.3f} ± {ci[g, -1]:.3f}")


def async_demo(args, tracker) -> None:
    """Event-driven engine: overlapping cohorts, staleness, churn."""
    from repro.sim.events import AsyncConfig, AsyncFedFogSimulator, ChurnConfig

    sim = AsyncFedFogSimulator(
        SimulatorConfig(
            task="emnist", num_clients=args.clients, rounds=args.rounds,
            top_k=args.topk, policy="fedfog", seed=0,
            population=args.population, fog_nodes=args.fog_nodes,
            faults=parse_faults(args.faults),
        ),
        AsyncConfig.fedbuff(
            max(2, args.topk // 2),
            dispatch_interval_ms=args.interval_ms,
            straggler_sigma=0.4,
            churn=ChurnConfig(arrival_rate=0.05, departure_rate=0.05),
        ),
        tap=_make_tap(tracker, args, "flush", engine="async"),
    )
    h = sim.run()
    print("=== async engine (FedBuff, straggler tail, churn) ===")
    print("virtual_t(ms) | accuracy | aggregated | staleness | energy(J)")
    step = max(1, h["num_flushes"] // 12)
    for f in range(0, h["num_flushes"], step):
        print(
            f"{h['t_ms'][f]:13.0f} | {h['accuracy'][f]:8.3f} "
            f"| {int(h['num_aggregated'][f]):10d} "
            f"| {h['mean_staleness'][f]:9.2f} | {h['energy_j'][f]:9.2f}"
        )
    print(
        f"\ndispatches={h['num_dispatches']} flushes={h['num_flushes']} "
        f"completions={h['num_completions']} "
        f"lost_to_churn={h['lost_inflight']} "
        f"final_acc={h['final_accuracy']:.3f} "
        f"virtual_time={h['virtual_time_ms'] / 1e3:.1f}s"
    )
    if args.faults:
        print(
            f"faults: failures={h['fault_failures']} "
            f"retries={h['fault_retries']} "
            f"terminal={h['fault_terminal']} "
            f"deadline_lost={h['fault_lost_deadline']} "
            f"corrupt={h['fault_corrupt']} "
            f"rounds_skipped={h['fault_skipped']}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=48)
    ap.add_argument("--topk", type=int, default=16)
    ap.add_argument("--engine", choices=("scan", "loop", "async"),
                    default="scan")
    ap.add_argument("--interval-ms", type=float, default=1000.0,
                    help="async engine: virtual ms between dispatches")
    ap.add_argument("--sweep-seeds", type=int, default=0,
                    help="if >0, also run the multi-seed sweep demo")
    ap.add_argument("--population", type=int, default=None,
                    help="virtual client registry size M (>= --clients); "
                         "each round samples a stratified --clients-sized "
                         "cohort, so per-round cost stays cohort-sized "
                         "(default: dense, M == --clients)")
    ap.add_argument("--fog-nodes", type=int, default=1,
                    help="fog-tier width F of the edge->fog->cloud "
                         "reduction; F must divide --clients and needs "
                         "the fedavg aggregator (default 1 = flat, "
                         "bitwise identical to the pre-fog path)")
    ap.add_argument("--faults", default="",
                    help="fault-injection spec, e.g. "
                         "'crash=0.2,retries=2,deadline=8000,quorum=0.5' "
                         "(keys: timeout/crash/drop/corrupt/partition/"
                         "outage/failover/retries/backoff/deadline/"
                         "quorum; empty = faults off, engines verbatim)")
    ap.add_argument("--track", default="",
                    help="stream metrics to 'jsonl:PATH' / 'csv:PATH' "
                         "(comma-separate for multiple sinks); rounds "
                         "stream out of the compiled engines mid-run "
                         "via decimated io_callback taps (repro.obs)")
    ap.add_argument("--track-every", type=int, default=5,
                    help="tap decimation: emit every k-th round/flush")
    args = ap.parse_args()

    tracker = tracker_from_spec(args.track)
    with tracker:
        _run(args, tracker)


def _run(args, tracker):
    if args.engine == "async":
        async_demo(args, tracker)
        if args.sweep_seeds > 0:
            sweep_demo(args, tracker)
        return

    results = {}
    for policy in ("fedfog", "fogfaas", "rcs"):
        sim = FedFogSimulator(
            SimulatorConfig(
                task="emnist",
                num_clients=args.clients,
                rounds=args.rounds,
                top_k=args.topk,
                policy=policy,
                drift_period=args.rounds // 2,
                attack="label_flip",
                attack_fraction=0.1,
                seed=0,
                population=args.population,
                fog_nodes=args.fog_nodes,
                faults=parse_faults(args.faults),
            ),
            tap=_make_tap(tracker, args, "round", policy=policy),
        )
        h = sim.run_scanned() if args.engine == "scan" else sim.run()
        results[policy] = h
        print(f"\n=== {policy} ===")
        print("round | accuracy | latency(ms) | energy(J) | cold starts")
        for r in range(0, args.rounds, max(1, args.rounds // 10)):
            print(
                f"{r:5d} | {h['accuracy'][r]:8.3f} | {h['round_latency_ms'][r]:11.0f}"
                f" | {h['energy_j'][r]:9.2f} | {int(h['cold_starts'][r]):4d}"
            )

    if args.faults:
        print("\n=== fault & recovery totals (per policy) ===")
        print(f"{'policy':10s} {'dispatched':>10s} {'completed':>9s} "
              f"{'terminal':>8s} {'lost':>5s} {'retries':>7s} "
              f"{'skipped':>7s}")
        for policy, h in results.items():
            print(
                f"{policy:10s} {int(sum(h['fault_dispatched'])):10d} "
                f"{int(sum(h['fault_completed'])):9d} "
                f"{int(sum(h['fault_terminal'])):8d} "
                f"{int(sum(h['fault_lost'])):5d} "
                f"{int(sum(h['fault_retries'])):7d} "
                f"{int(sum(h['round_skipped'])):7d}"
            )

    print("\n=== summary (paper Fig. 5 analogue) ===")
    print(f"{'policy':10s} {'final_acc':>9s} {'mean_lat_ms':>12s} "
          f"{'total_energy':>13s} {'cold_starts':>12s}")
    for policy, h in results.items():
        print(
            f"{policy:10s} {h['final_accuracy']:9.3f} "
            f"{h['mean_latency_ms']:12.0f} {h['total_energy_j']:13.1f} "
            f"{int(h['total_cold_starts']):12d}"
        )

    if args.sweep_seeds > 0:
        sweep_demo(args, tracker)


if __name__ == "__main__":
    main()
