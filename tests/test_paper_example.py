"""The paper's worked numerical example (§III.G), encoded verbatim.

Three clients, α=(0.4,0.3,0.3), β=(0.4,0.4,0.2), thresholds
(θ_h, θ_e, θ_d) = (0.6, 0.5, 0.1). Expected:

  H = (0.65, 0.43, 0.81);  C_t = {c1, c3};
  FedAvg of Δw1=[0.2,-0.1] (|D1|=100) and Δw3=[0.5,0.0] (|D3|=300)
    -> w_{t+1} = [0.425, -0.025];
  U(c1)=0.53, U(c3)=0.684;  scheduling order puts c3 first;
  δ_cold=2000ms / δ_warm=200ms;
  DP (§III.K): σ=0.3, S=1.1, |C_t|=30, δ=1e-5 -> ε ≈ 1.8.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClientTelemetry,
    ColdStartConfig,
    Thresholds,
    epsilon,
    fedavg_stacked,
    health_score,
    invocation_delay,
    select_clients,
    threshold_mask,
    utility_ranking,
    utility_score,
)

ALPHA = jnp.array([0.4, 0.3, 0.3])
BETA = jnp.array([0.4, 0.4, 0.2])

# Client attribute table from §III.G: CPU, MEM, BATT, E, D.
CPU = jnp.array([0.8, 0.4, 0.9])
MEM = jnp.array([0.6, 0.5, 0.7])
BATT = jnp.array([0.5, 0.4, 0.8])
ENERGY = jnp.array([0.7, 0.6, 0.9])
DRIFT = jnp.array([0.05, 0.12, 0.02])

TELEMETRY = ClientTelemetry(cpu=CPU, mem=MEM, batt=BATT, energy=ENERGY)
THRESHOLDS = Thresholds(
    health=jnp.float32(0.6), energy=jnp.float32(0.5), drift=jnp.float32(0.1)
)


def test_health_scores_match_paper():
    h = health_score(TELEMETRY, ALPHA)
    np.testing.assert_allclose(np.asarray(h), [0.65, 0.43, 0.81], atol=1e-6)


def test_threshold_selection_matches_paper():
    h = health_score(TELEMETRY, ALPHA)
    mask = threshold_mask(h, ENERGY, DRIFT, THRESHOLDS)
    # c1 selected, c2 rejected (H=0.43 < 0.6), c3 selected.
    np.testing.assert_array_equal(np.asarray(mask), [True, False, True])


def test_fedavg_matches_paper():
    # Step 4: |D1|=100, |D3|=300 -> w = 0.25*[0.2,-0.1] + 0.75*[0.5,0.0]
    updates = {"w": jnp.array([[0.2, -0.1], [0.0, 0.0], [0.5, 0.0]])}
    mask = jnp.array([True, False, True])
    sizes = jnp.array([100.0, 250.0, 300.0])  # c2's size is irrelevant (masked)
    agg = fedavg_stacked(updates, mask, sizes)
    np.testing.assert_allclose(np.asarray(agg["w"]), [0.425, -0.025], atol=1e-6)


def test_utility_scores_match_paper():
    h = health_score(TELEMETRY, ALPHA)
    u = utility_score(h, ENERGY, DRIFT, BETA)
    # U(c1) = 0.4*0.65 + 0.4*0.7 - 0.2*0.05 = 0.53
    # U(c3) = 0.4*0.81 + 0.4*0.9 - 0.2*0.02 = 0.324 + 0.36 - 0.004 = 0.68
    np.testing.assert_allclose(float(u[0]), 0.53, atol=1e-5)
    np.testing.assert_allclose(float(u[2]), 0.68, atol=1e-5)


def test_scheduling_order_puts_c3_first():
    h = health_score(TELEMETRY, ALPHA)
    u = utility_score(h, ENERGY, DRIFT, BETA)
    order = utility_ranking(u)
    assert int(order[0]) == 2  # c3 is highest priority


def test_select_clients_end_to_end():
    h = health_score(TELEMETRY, ALPHA)
    res = select_clients(h, ENERGY, DRIFT, THRESHOLDS, BETA, k=None)
    np.testing.assert_array_equal(np.asarray(res.mask), [True, False, True])
    assert int(res.num_selected) == 2
    assert int(res.order[0]) == 2


def test_cold_start_delays_match_paper():
    cfg = ColdStartConfig(delta_cold_ms=2000.0, delta_warm_ms=200.0)
    warm = jnp.array([False, False, True])  # c1 first-time, c3 previously used
    d = invocation_delay(warm, cfg)
    assert float(d[0]) == 2000.0
    assert float(d[2]) == 200.0


def test_dp_epsilon_matches_paper():
    # §III.K Eq. 12: ε = sqrt(2·log(1.25/δ))/σ · S/|C_t|.
    # NOTE (paper arithmetic discrepancy, documented in DESIGN.md): with the
    # paper's stated σ=0.3, S=1.1, |C_t|=30, δ=1e-5 the formula yields
    # ε ≈ 0.592 — NOT the "≈ 1.8" quoted in the text. The quoted 1.8 follows
    # from the same formula with |C_t|=10 (or σ=0.1). We test the *formula*
    # (authoritative) and record both readings.
    eps30 = epsilon(sigma=0.3, sensitivity=1.1, num_clients=30, delta=1e-5)
    assert eps30 == pytest.approx(
        np.sqrt(2 * np.log(1.25 / 1e-5)) / 0.3 * 1.1 / 30, rel=1e-9
    )
    assert eps30 == pytest.approx(0.592, abs=5e-3)
    # The text's "≈1.8" is consistent with |C_t| = 10:
    eps10 = epsilon(sigma=0.3, sensitivity=1.1, num_clients=10, delta=1e-5)
    assert eps10 == pytest.approx(1.8, abs=0.03)
