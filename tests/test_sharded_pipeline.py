"""Fake-device kernel test: the sharded delta pipeline (shard_map +
per-shard Pallas partial kernel + ONE psum) must match the single-device
fused kernel and the pure-jnp oracle over the full gate matrix, with
exactly one client-crossing all-reduce in every compiled case.

Runs ``repro.kernels.delta_pipeline.sharded_selftest`` in a SUBPROCESS
because the fake-device count must be fixed before jax initializes.
"""
from _subproc import run_selftest_module


def _run_selftest(*extra):
    return run_selftest_module(
        "repro.kernels.delta_pipeline.sharded_selftest", *extra
    )


def test_sharded_pipeline_gate_matrix():
    res = _run_selftest("--devices", "8")
    assert res["client_ways"] == 4 and res["zero"] == 2
    # Every gate case: sharded == unsharded kernel == ref oracle within
    # tolerance, and exactly ONE all-reduce crosses the client axis with
    # the delta-sized partial-sum payload (the §III contract at kernel
    # granularity).
    for name, case in res["cases"].items():
        assert case["client_all_reduces"] == 1, (name, case)
        assert case["ok"], (name, case)
    assert res["ok"], res
