"""Fake-device kernel test: the sharded delta pipeline (shard_map +
per-shard Pallas partial kernel + ONE psum) must match the single-device
fused kernel and the pure-jnp oracle over the full gate matrix, with
exactly one client-crossing all-reduce in every compiled case.

Runs ``repro.kernels.delta_pipeline.sharded_selftest`` in a SUBPROCESS
because the fake-device count must be fixed before jax initializes.
"""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_selftest(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [
            sys.executable, "-m",
            "repro.kernels.delta_pipeline.sharded_selftest",
            "--json", *extra,
        ],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"sharded kernel selftest failed\nstdout: {proc.stdout[-2000:]}\n"
        f"stderr: {proc.stderr[-2000:]}"
    )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_pipeline_gate_matrix():
    res = _run_selftest("--devices", "8")
    assert res["client_ways"] == 4 and res["zero"] == 2
    # Every gate case: sharded == unsharded kernel == ref oracle within
    # tolerance, and exactly ONE all-reduce crosses the client axis with
    # the delta-sized partial-sum payload (the §III contract at kernel
    # granularity).
    for name, case in res["cases"].items():
        assert case["client_all_reduces"] == 1, (name, case)
        assert case["ok"], (name, case)
    assert res["ok"], res
