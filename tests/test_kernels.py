"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedavg import fedavg_apply, fedavg_apply_ref, fedavg_apply_tree
from repro.kernels.flash_attention import flash_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.wkv6 import wkv6_ref
from repro.kernels.wkv6.wkv6 import wkv6_fwd

KEY = jax.random.PRNGKey(7)


# --------------------------------------------------------------------- #
# flash_attention
# --------------------------------------------------------------------- #
FLASH_CASES = [
    # (b, h, hkv, sq, sk, hd, window, bidirectional, dtype)
    (1, 2, 2, 128, 128, 64, 0, False, jnp.float32),
    (2, 4, 2, 256, 256, 64, 0, False, jnp.float32),
    (1, 4, 1, 128, 256, 128, 0, False, jnp.float32),  # tail-aligned q
    (2, 2, 2, 256, 256, 64, 96, False, jnp.float32),  # sliding window
    (1, 8, 4, 128, 128, 128, 64, False, jnp.float32),  # GQA + window
    (1, 2, 1, 128, 128, 64, 0, True, jnp.float32),  # bidirectional
    (1, 2, 2, 256, 256, 64, 0, False, jnp.bfloat16),
    (1, 2, 2, 128, 128, 256, 0, False, jnp.float32),  # gemma3 head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
def test_flash_attention_matches_ref(case):
    b, h, hkv, sq, sk, hd, window, bidir, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, hd), jnp.float32).astype(dtype)
    out = flash_attention_fwd(
        q, k, v, window=window, bidirectional=bidir,
        block_q=64, block_kv=64, interpret=True,
    )
    ref = flash_attention_ref(q, k, v, window=window, bidirectional=bidir)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [
        flash_attention_fwd(
            q, k, v, window=100, block_q=bq, block_kv=bk, interpret=True
        )
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


# --------------------------------------------------------------------- #
# wkv6
# --------------------------------------------------------------------- #
WKV_CASES = [
    # (b, t, h, dk, dv, chunk, dtype)
    (1, 64, 2, 64, 64, 32, jnp.float32),
    (2, 128, 4, 64, 64, 32, jnp.float32),
    (1, 96, 1, 32, 64, 32, jnp.float32),
    (2, 64, 2, 64, 64, 64, jnp.float32),
    (1, 64, 2, 64, 64, 16, jnp.float32),
    (1, 64, 2, 64, 64, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", WKV_CASES, ids=str)
def test_wkv6_matches_ref(case):
    b, t, h, dk, dv, chunk, dtype = case
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, t, h, dk), jnp.float32).astype(dtype)
    k = (jax.random.normal(ks[1], (b, t, h, dk), jnp.float32) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, dv), jnp.float32).astype(dtype)
    ww = jax.random.uniform(ks[3], (b, t, h, dk), minval=-4.0, maxval=0.5)
    w = jnp.exp(-jnp.exp(ww)).astype(dtype)
    u = (jax.random.normal(ks[4], (h, dk), jnp.float32) * 0.3).astype(jnp.float32)
    y, s = wkv6_fwd(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = wkv6_ref(r, k, v, w, u)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=tol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=tol)


def test_wkv6_state_carry_composes():
    """Running two half-sequences with carried state == one full pass."""
    ks = jax.random.split(KEY, 5)
    b, t, h, dk, dv = 1, 64, 2, 64, 64
    r = jax.random.normal(ks[0], (b, t, h, dk))
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, dv))
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, t, h, dk), minval=-3, maxval=0)))
    u = jax.random.normal(ks[4], (h, dk)) * 0.3
    y_full, s_full = wkv6_ref(r, k, v, w, u)
    y1, s1 = wkv6_ref(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u)
    y2, s2 = wkv6_ref(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, initial_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-5)


# --------------------------------------------------------------------- #
# fedavg
# --------------------------------------------------------------------- #
FEDAVG_CASES = [
    (8, 1000, 256, jnp.float32),
    (16, 4096, 2048, jnp.float32),
    (32, 5000, 2048, jnp.bfloat16),
    (64, 333, 128, jnp.float32),
    (4, 2048, 4096, jnp.float32),  # block_d > d
]


@pytest.mark.parametrize("case", FEDAVG_CASES, ids=str)
def test_fedavg_matches_ref(case):
    n, d, bd, dtype = case
    ks = jax.random.split(KEY, 4)
    upd = jax.random.normal(ks[0], (n, d), jnp.float32).astype(dtype)
    base = jax.random.normal(ks[1], (d,), jnp.float32).astype(dtype)
    mask = jax.random.bernoulli(ks[2], 0.7, (n,))
    w = jnp.abs(jax.random.normal(ks[3], (n,))) * 100
    out = fedavg_apply(upd, base, mask, w, lr=0.9, block_d=bd)
    ref = fedavg_apply_ref(upd, base, mask, w, lr=0.9)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_fedavg_tree_matches_paper_example():
    """Kernel path reproduces the paper's §III.G FedAvg numbers."""
    upd = {"w": jnp.array([[0.2, -0.1], [0.0, 0.0], [0.5, 0.0]])}
    base = {"w": jnp.zeros((2,))}
    mask = jnp.array([True, False, True])
    sizes = jnp.array([100.0, 1.0, 300.0])
    out = fedavg_apply_tree(upd, base, mask, sizes)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.425, -0.025], atol=1e-6)


def test_fedavg_all_masked_is_safe():
    upd = jnp.ones((4, 16))
    base = jnp.zeros((16,))
    out = fedavg_apply(upd, base, jnp.zeros(4, bool), jnp.ones(4))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
