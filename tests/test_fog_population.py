"""Fog-tier reduction + population-scale cohort sampling (ISSUE 7).

Contracts:
  (a) ``fog_nodes=1 ∧ population=num_clients`` is BITWISE the flat path
      — sync scan engine, async event engine, and the grouped sweep all
      reproduce the pre-fog histories exactly (the fog/population knobs
      are static Python branches, not traced ops);
  (b) the fog decomposition is exact: fog-partial → cloud-combine equals
      the flat Eq. 6 weighted sum (plain and staleness-discounted) for
      any group count and any contiguous assignment, hypothesis-checked
      under permuted client data;
  (c) the async sync-recovery invariant (unbounded buffer, no churn,
      zero staleness discount == run_scanned) survives fog_nodes > 1;
  (d) population/fog_nodes are STRUCTURAL sweep axes (new compile-cache
      signature, never lifted to vmapped numeric data);
  (e) config validation: population < num_clients, fog_nodes not
      dividing the cohort, and fog_nodes > 1 with a robust aggregator
      are rejected eagerly;
  (f) the sharded two-tier kernel path holds the per-tier collective
      contract over the full gate matrix (subprocess fake-device run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_selftest_module
from repro.core.aggregation import fedavg_stacked
from repro.fl import fog
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import run_sweep
from repro.sim.events import AsyncConfig, AsyncFedFogSimulator, async_aggregate
from repro.sim.sweep import _factor_sim


def _cfg(**kw) -> SimulatorConfig:
    base = dict(
        task="emnist", num_clients=8, rounds=4, top_k=4, hidden=(16,), seed=0
    )
    base.update(kw)
    return SimulatorConfig(**base)


def _assert_hist_equal(h_a, h_b):
    for k in h_b:
        np.testing.assert_array_equal(
            np.asarray(h_a[k]), np.asarray(h_b[k]), err_msg=k
        )


# --------------------------------------------------------------------- #
# (a) fog_nodes=1 ∧ population=num_clients is bitwise the flat path
# --------------------------------------------------------------------- #
def test_sync_dense_population_bitwise_flat():
    h_flat = FedFogSimulator(_cfg()).run_scanned()
    h_pop = FedFogSimulator(
        _cfg(population=8, fog_nodes=1)
    ).run_scanned()
    _assert_hist_equal(h_pop, h_flat)


def test_async_dense_population_bitwise_flat():
    acfg = AsyncConfig(staleness_exponent=0.0)
    h_flat = AsyncFedFogSimulator(_cfg(), acfg).run()
    h_pop = AsyncFedFogSimulator(_cfg(population=8, fog_nodes=1), acfg).run()
    _assert_hist_equal(h_pop, h_flat)


def test_grouped_sweep_dense_population_bitwise_flat():
    seeds = [0, 1]
    r_flat = run_sweep(_cfg(), seeds=seeds, cache=False)
    r_pop = run_sweep(_cfg(population=8), seeds=seeds, cache=False)
    for name in r_flat.history:
        np.testing.assert_array_equal(
            r_pop.history[name], r_flat.history[name], err_msg=name
        )


# --------------------------------------------------------------------- #
# (b) fog decomposition is exact
# --------------------------------------------------------------------- #
def test_fog_aggregate_matches_flat_eq6():
    rng = np.random.default_rng(3)
    c, p = 16, 33
    upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
    mask = jnp.asarray(rng.random(c) < 0.7)
    w = jnp.asarray(rng.integers(5, 80, c), jnp.float32)
    flat = fedavg_stacked(upd, mask, w)
    for f in (1, 2, 4, 8, 16):
        got = fog.fog_aggregate(upd, mask, w, f)
        np.testing.assert_allclose(got, flat, rtol=1e-5, atol=1e-6)


def test_fog_aggregate_staleness_matches_async_aggregate():
    rng = np.random.default_rng(4)
    c, p = 12, 17
    upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
    mask = jnp.asarray(rng.random(c) < 0.8)
    w = jnp.asarray(rng.integers(5, 80, c), jnp.float32)
    stale = jnp.asarray(rng.integers(0, 5, c), jnp.float32)
    flat = async_aggregate(upd, mask, w, stale, 0.5)
    for f in (2, 4):
        got = fog.fog_aggregate(upd, mask, w, f, stale, 0.5)
        np.testing.assert_allclose(got, flat, rtol=1e-5, atol=1e-6)


def test_fog_partial_cloud_combine_property():
    """Hypothesis: for random weights/masks/staleness and PERMUTED
    fog assignments, fog partials combined at the cloud equal the flat
    Eq. 6 reduction (the decomposition is assignment-invariant)."""
    hyp = pytest.importorskip(
        "hypothesis", reason="dev dep; see requirements-dev.txt"
    )
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=30)
    @hyp.given(
        seed=st.integers(0, 2**31 - 1),
        fog_nodes=st.sampled_from([1, 2, 3, 4, 6]),
        use_stale=st.booleans(),
    )
    def check(seed, fog_nodes, use_stale):
        rng = np.random.default_rng(seed)
        c, p = 12, 9
        upd = jnp.asarray(rng.normal(size=(c, p)), jnp.float32)
        mask = jnp.asarray(rng.random(c) < 0.75)
        w = jnp.asarray(rng.uniform(1.0, 100.0, c), jnp.float32)
        stale = (
            jnp.asarray(rng.integers(0, 6, c), jnp.float32)
            if use_stale else None
        )
        exp = 0.5 if use_stale else 0.0
        # permuted (non-contiguous) group assignment
        assign = jnp.asarray(
            rng.permutation((np.arange(c) * fog_nodes) // c), jnp.int32
        )
        partials, sdm, sm = fog.fog_partial_sums(
            upd, mask, w, fog_nodes, stale, exp, assignment=assign
        )
        got = fog.cloud_combine(partials, sdm, sm, has_stale=use_stale)
        flat = (
            async_aggregate(upd, mask, w, stale, exp)
            if use_stale else fedavg_stacked(upd, mask, w)
        )
        np.testing.assert_allclose(got, flat, rtol=1e-5, atol=1e-6)

    check()


# --------------------------------------------------------------------- #
# (c) sync recovery with the fog tier engaged
# --------------------------------------------------------------------- #
def test_async_sync_recovery_with_fog():
    cfg = _cfg(fog_nodes=2, rounds=5)
    h_sync = FedFogSimulator(cfg).run_scanned()
    h_async = AsyncFedFogSimulator(
        cfg, AsyncConfig(staleness_exponent=0.0)
    ).run()
    assert h_async["num_flushes"] == cfg.rounds
    np.testing.assert_allclose(
        h_async["accuracy"], h_sync["accuracy"], rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        h_async["energy_j"], h_sync["energy_j"], rtol=1e-5, atol=1e-5
    )


def test_fog_ref_matches_flat_in_simulator():
    """fog_nodes=2 changes only float reassociation: accuracy trajectory
    must match the flat run within tolerance (same selections — the
    scheduler never sees the fog tier)."""
    h_flat = FedFogSimulator(_cfg(rounds=3)).run_scanned()
    h_fog = FedFogSimulator(_cfg(rounds=3, fog_nodes=2)).run_scanned()
    np.testing.assert_allclose(
        h_fog["accuracy"], h_flat["accuracy"], rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(h_fog["num_selected"],
                                  h_flat["num_selected"])


# --------------------------------------------------------------------- #
# population-scale cohort sampling
# --------------------------------------------------------------------- #
def test_population_cohort_sampling_runs_sync_and_async():
    cfg = _cfg(population=64, rounds=3)
    h = FedFogSimulator(cfg).run_scanned()
    assert np.isfinite(np.asarray(h["accuracy"])).all()
    assert len(h["accuracy"]) == cfg.rounds
    ha = AsyncFedFogSimulator(
        cfg, AsyncConfig(staleness_exponent=0.0)
    ).run()
    assert ha["num_flushes"] == cfg.rounds
    assert np.isfinite(np.asarray(ha["accuracy"])).all()


def test_stratified_cohort_shape_and_bounds():
    ids = fog.stratified_cohort(jax.random.PRNGKey(0), 1_000_000, 64)
    ids = np.asarray(ids)
    assert ids.shape == (64,)
    assert (np.diff(ids) > 0).all()  # sorted, distinct (one per stratum)
    assert ids.min() >= 0 and ids.max() < 1_000_000
    # dense population degenerates to the identity window
    np.testing.assert_array_equal(
        np.asarray(fog.stratified_cohort(jax.random.PRNGKey(1), 8, 8)),
        np.arange(8),
    )


# --------------------------------------------------------------------- #
# (d) population/fog_nodes are structural sweep axes
# --------------------------------------------------------------------- #
def test_population_and_fog_are_structural_in_sweep():
    base = _cfg()
    s0, n0 = _factor_sim(base)
    s1, n1 = _factor_sim(_cfg(population=64))
    s2, n2 = _factor_sim(_cfg(fog_nodes=2))
    assert s0 != s1 and s0 != s2  # distinct compile signatures
    assert n0 == n1 == n2  # never lifted into numeric data


def test_sweep_fog_axis_groups_separately():
    res = run_sweep(
        _cfg(rounds=2), seeds=[0], axes={"fog_nodes": [1, 2]}, cache=False
    )
    acc = res.metric("accuracy")
    assert acc.shape[0] == 2
    np.testing.assert_allclose(acc[0], acc[1], rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------- #
# (e) eager validation
# --------------------------------------------------------------------- #
def test_validation_rejects_bad_configs():
    with pytest.raises(ValueError, match="population"):
        FedFogSimulator(_cfg(population=4))  # < num_clients
    with pytest.raises(ValueError, match="fog_nodes"):
        FedFogSimulator(_cfg(fog_nodes=3))  # 3 ∤ 8
    with pytest.raises(ValueError, match="fedavg"):
        FedFogSimulator(_cfg(fog_nodes=2, aggregator="median"))
    from repro.fl import FLConfig

    with pytest.raises(ValueError, match="population"):
        FLConfig(num_clients=8, slots=4, population=4)
    with pytest.raises(ValueError, match="fog_nodes"):
        FLConfig(num_clients=8, slots=4, fog_nodes=3)


# --------------------------------------------------------------------- #
# (f) sharded two-tier gate matrix (subprocess, fake devices)
# --------------------------------------------------------------------- #
def test_fog_sharded_gate_matrix():
    res = run_selftest_module("repro.kernels.delta_pipeline.fog_selftest")
    assert res["fog_nodes"] == 2
    for name, case in res["cases"].items():
        assert case["edge_all_reduces"] == 1, (name, case)
        assert case["fog_all_reduces"] == 1, (name, case)
        assert case["contract_ok"], (name, case)
        assert case["ok"], (name, case)
    # flat fog_nodes=1 on the same mesh keeps the single-psum contract
    assert res["flat"]["ok"], res["flat"]
    assert res["ok"], res
