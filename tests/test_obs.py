"""Observability subsystem: trackers, in-scan metric taps, shared history.

The standing contracts under test:

  * tap OFF (``tap=None`` or ``every=0``) leaves every engine's history
    bitwise identical to the pre-observability path — the tap is a
    structural gate, not a runtime branch;
  * tap ON streams decimated rows out of the compiled programs mid-run,
    and each streamed row agrees exactly with the final history at its
    sampled step;
  * the tap does not break compile-once: a second tapped ``run_scanned``
    on the same instance is a jit cache hit (one cached executable);
  * engine-health conditions (``lost_inflight``) surface as explicit
    warnings — tracker event when a tracker is attached, plain
    ``warnings.warn`` otherwise;
  * both engines share one finalize schema (``repro.obs.history``).
"""
import json
import warnings

import numpy as np
import pytest

from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.obs import (
    CompositeTracker,
    CsvTracker,
    JsonlTracker,
    MemoryTracker,
    MetricTap,
    NoopTracker,
    finalize_history,
    summary_metrics,
    tracker_from_spec,
)
from repro.sim.events import AsyncConfig, AsyncFedFogSimulator, ChurnConfig


def _cfg(**kw):
    kw.setdefault("task", "emnist")
    kw.setdefault("num_clients", 8)
    kw.setdefault("rounds", 12)
    kw.setdefault("seed", 0)
    return SimulatorConfig(**kw)


# --------------------------------------------------------------------- #
# trackers
# --------------------------------------------------------------------- #
def test_jsonl_tracker_round_trip(tmp_path):
    path = tmp_path / "t.jsonl"
    with JsonlTracker(str(path)) as t:
        t.log({"event": "round", "accuracy": 0.5}, step=3)
        t.log_summary({"final_accuracy": 0.9})
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert lines[0]["step"] == 3 and lines[0]["accuracy"] == 0.5
    assert lines[1]["summary"] is True
    assert lines[1]["final_accuracy"] == 0.9
    assert all("ts" in x for x in lines)


def test_jsonl_rows_visible_mid_run(tmp_path):
    # streaming means rows are flushed as logged, not at close
    path = tmp_path / "t.jsonl"
    t = JsonlTracker(str(path))
    t.log({"event": "round", "x": 1.0}, step=0)
    assert len(path.read_text().splitlines()) == 1
    t.finish()


def test_csv_tracker_round_trip(tmp_path):
    path = tmp_path / "t.csv"
    with CsvTracker(str(path)) as t:
        t.log({"accuracy": 0.5, "energy_j": 1.0}, step=0)
        t.log({"accuracy": 0.6, "energy_j": 2.0, "extra": 9.0}, step=1)
        t.log_summary({"accuracy": 0.6})
    lines = path.read_text().splitlines()
    assert lines[0].split(",")[:2] == ["step", "summary"]
    assert len(lines) == 4  # header + 2 rows + summary
    assert "9.0" not in lines[2]  # unseen key dropped, header is fixed


def test_composite_and_memory_trackers():
    a, b = MemoryTracker(), MemoryTracker()
    with CompositeTracker([a, b]) as t:
        t.log({"x": 1}, step=0)
        t.log_summary({"y": 2})
    assert a.rows == b.rows and len(a.rows) == 1
    assert a.summaries == [{"y": 2}]


def test_tracker_from_spec(tmp_path):
    assert isinstance(tracker_from_spec(None), NoopTracker)
    assert isinstance(tracker_from_spec(""), NoopTracker)
    assert isinstance(tracker_from_spec("noop"), NoopTracker)
    assert isinstance(
        tracker_from_spec(f"jsonl:{tmp_path}/a.jsonl"), JsonlTracker
    )
    assert isinstance(tracker_from_spec(f"csv:{tmp_path}/a.csv"), CsvTracker)
    both = tracker_from_spec(
        f"jsonl:{tmp_path}/b.jsonl,csv:{tmp_path}/b.csv"
    )
    assert isinstance(both, CompositeTracker)
    with pytest.raises(ValueError):
        tracker_from_spec("wandb:project")


# --------------------------------------------------------------------- #
# scan-engine tap
# --------------------------------------------------------------------- #
def test_tap_off_is_bitwise_identical():
    h0 = FedFogSimulator(_cfg()).run_scanned()
    h_none = FedFogSimulator(_cfg(), tap=None).run_scanned()
    # every=0 disables structurally — same trace as tap=None
    h_zero = FedFogSimulator(
        _cfg(), tap=MetricTap(MemoryTracker(), every=0)
    ).run_scanned()
    for k, v in h0.items():
        if isinstance(v, list):
            assert v == h_none[k] == h_zero[k], k


def test_tap_on_does_not_change_history():
    h0 = FedFogSimulator(_cfg()).run_scanned()
    h1 = FedFogSimulator(
        _cfg(), tap=MetricTap(MemoryTracker(), every=3)
    ).run_scanned()
    for k, v in h0.items():
        if isinstance(v, list):
            assert v == h1[k], k


def test_tap_streams_decimated_rows_matching_history():
    mt = MemoryTracker()
    tap = MetricTap(mt, every=4, const={"policy": "fedfog"})
    sim = FedFogSimulator(_cfg(), tap=tap)
    h = sim.run_scanned()
    rows = [r for r in mt.rows if r["event"] == "round"]
    assert [r["step"] for r in rows] == [0, 4, 8]
    assert tap.rows_emitted == len(rows)
    for r in rows:
        assert r["policy"] == "fedfog"
        np.testing.assert_allclose(
            r["accuracy"], h["accuracy"][r["step"]], rtol=1e-6
        )
        np.testing.assert_allclose(
            r["energy_j"], h["energy_j"][r["step"]], rtol=1e-6
        )
    # summary row carries the shared finalize schema
    (s,) = mt.summaries
    assert s["final_accuracy"] == h["final_accuracy"]
    assert s["total_energy_j"] == pytest.approx(h["total_energy_j"])


def test_tapped_scan_compiles_once():
    sim = FedFogSimulator(
        _cfg(), tap=MetricTap(MemoryTracker(), every=5)
    )
    sim.run_scanned()
    sim.run_scanned()
    assert sim._scan_jit._cache_size() == 1


def test_tap_on_loop_engine_matches_scanned_rows():
    mt_scan, mt_loop = MemoryTracker(), MemoryTracker()
    FedFogSimulator(
        _cfg(), tap=MetricTap(mt_scan, every=4)
    ).run_scanned()
    FedFogSimulator(_cfg(), tap=MetricTap(mt_loop, every=4)).run()
    assert [r["step"] for r in mt_scan.rows] == [
        r["step"] for r in mt_loop.rows
    ]
    for rs, rl in zip(mt_scan.rows, mt_loop.rows):
        np.testing.assert_allclose(rs["accuracy"], rl["accuracy"], rtol=1e-6)


def test_aot_rejects_tap():
    sim = FedFogSimulator(_cfg(), tap=MetricTap(MemoryTracker(), every=2))
    with pytest.raises(ValueError, match="tap"):
        sim.aot_scanned()


# --------------------------------------------------------------------- #
# async-engine tap + warnings
# --------------------------------------------------------------------- #
def test_async_tap_off_identical_and_shared_schema():
    h0 = AsyncFedFogSimulator(_cfg(rounds=6), AsyncConfig()).run()
    mt = MemoryTracker()
    h1 = AsyncFedFogSimulator(
        _cfg(rounds=6), AsyncConfig(),
        tap=MetricTap(mt, every=2, channel="flush"),
    ).run()
    for k, v in h0.items():
        if isinstance(v, list):
            assert v == h1[k], k
    # shared finalize schema: async histories now carry cold-start totals
    assert "total_cold_starts" in h1
    rows = [r for r in mt.rows if r["event"] == "flush"]
    assert rows, "tap should stream flush rows"
    for r in rows:
        np.testing.assert_allclose(
            r["accuracy"], h1["accuracy"][r["step"]], rtol=1e-6
        )
    (s,) = mt.summaries
    assert s["num_flushes"] == h1["num_flushes"]


def test_async_vmapped_sweep_path_rejects_tap():
    eng = AsyncFedFogSimulator(
        _cfg(rounds=4), AsyncConfig(),
        tap=MetricTap(MemoryTracker(), every=2),
    )
    with pytest.raises(RuntimeError, match="sweep"):
        eng.metrics_for_seed(0)


def _churny():
    return AsyncConfig.fedbuff(
        4, dispatch_interval_ms=300.0, straggler_sigma=0.4,
        churn=ChurnConfig(arrival_rate=0.2, departure_rate=0.8),
    )


def test_lost_inflight_warns_without_tracker():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        h = AsyncFedFogSimulator(
            _cfg(rounds=10, num_clients=16, top_k=12), _churny()
        ).run()
    assert h["lost_inflight"] > 0
    msgs = [
        str(x.message) for x in w if issubclass(x.category, RuntimeWarning)
    ]
    assert any("in-flight" in m for m in msgs)


def test_lost_inflight_goes_to_tracker_when_attached():
    mt = MemoryTracker()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        h = AsyncFedFogSimulator(
            _cfg(rounds=10, num_clients=16, top_k=12), _churny(),
            tap=MetricTap(mt, every=5, channel="flush"),
        ).run()
    assert h["lost_inflight"] > 0
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]
    warns = [r for r in mt.rows if r["event"] == "warning"]
    assert warns and warns[0]["kind"] == "lost_inflight"
    assert warns[0]["lost_inflight"] == h["lost_inflight"]


# --------------------------------------------------------------------- #
# shared history helpers
# --------------------------------------------------------------------- #
def test_finalize_history_schema():
    h = {"accuracy": [0.1, 0.8, 0.6], "energy_j": [1.0, 2.0, 3.0],
         "round_latency_ms": [10.0, 20.0, 30.0], "cold_starts": [2, 0, 1]}
    finalize_history(h)
    assert h["final_accuracy"] == 0.6
    assert h["peak_accuracy"] == 0.8
    assert h["total_energy_j"] == 6.0
    assert h["mean_latency_ms"] == 20.0
    assert h["total_cold_starts"] == 3
    # empty run degrades to zeros, no crash
    empty = finalize_history({"accuracy": [], "energy_j": []})
    assert empty["final_accuracy"] == 0.0 and empty["total_energy_j"] == 0


def test_summary_metrics_subset():
    h = finalize_history(
        {"accuracy": [0.5], "energy_j": [1.0], "irrelevant": [1, 2]}
    )
    s = summary_metrics(h)
    assert "irrelevant" not in s
    assert s["final_accuracy"] == 0.5


def test_engines_share_finalize_schema():
    h_sync = FedFogSimulator(_cfg(rounds=4)).run_scanned()
    h_async = AsyncFedFogSimulator(_cfg(rounds=4), AsyncConfig()).run()
    for k in ("final_accuracy", "peak_accuracy", "total_energy_j",
              "total_cold_starts"):
        assert k in h_sync and k in h_async, k


# --------------------------------------------------------------------- #
# sweep tracker events
# --------------------------------------------------------------------- #
def test_sweep_tracker_events_and_cache_hits():
    from repro.sim import clear_compile_cache, run_sweep

    clear_compile_cache()
    cfg = _cfg(rounds=4)
    mt = MemoryTracker()
    run_sweep(cfg, seeds=range(2), axes={"lr": [0.01, 0.05]}, tracker=mt)
    groups = [r for r in mt.rows if r["event"] == "sweep_group"]
    assert len(groups) == 1  # one structural signature
    assert groups[0]["n_members"] == 2
    assert groups[0]["cache_hit"] is False
    (s,) = mt.summaries
    assert s["n_points"] == 2 and s["n_compiles"] == 1

    mt2 = MemoryTracker()
    run_sweep(cfg, seeds=range(2), axes={"lr": [0.01, 0.05]}, tracker=mt2)
    assert [r["cache_hit"] for r in mt2.rows
            if r["event"] == "sweep_group"] == [True]
