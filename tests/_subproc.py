"""Shared fake-device subprocess harness for selftest-backed tests.

The fake-device selftests (``repro.dist.selftest``,
``repro.kernels.delta_pipeline.sharded_selftest``,
``repro.kernels.delta_pipeline.fog_selftest``) MUST run in their own
process: ``--xla_force_host_platform_device_count`` has to be set before
jax initializes its backend, and the pytest process has already locked
its backend to one device. Every caller runs ``python -m <module>
--json ...`` with src/ on PYTHONPATH and parses the last stdout line.
"""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_selftest_module(module: str, *extra: str, timeout: int = 600) -> dict:
    """Run ``python -m <module> --json *extra`` and return its parsed
    JSON result (last stdout line). Asserts a zero exit with the tail of
    both streams in the failure message."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-m", module, "--json", *extra],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO,
        timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"{module} failed\nstdout: {proc.stdout[-2000:]}\n"
        f"stderr: {proc.stderr[-2000:]}"
    )
    return json.loads(proc.stdout.strip().splitlines()[-1])
