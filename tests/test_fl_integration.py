"""End-to-end federated behaviour: convergence, scheduling dynamics,
attacks, baselines and the paper-faithful simulator."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import SchedulerConfig
from repro.fl import AttackConfig, FLConfig, init_fl_state, make_round_fn
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.models import Family, ModelConfig, build_model

KEY = jax.random.PRNGKey(0)

TINY = ModelConfig(
    name="tiny", family=Family.DENSE, num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128, remat=False,
    loss_chunk=0,
)


def _mk_batch(key, fl: FLConfig, gb=16, seq=32, vocab=128):
    ks = jax.random.split(key, 8)
    n = fl.num_clients
    return {
        "tokens": jax.random.randint(ks[0], (gb, seq + 1), 0, vocab),
        "slot_data_sizes": jnp.abs(jax.random.normal(ks[1], (fl.slots,))) * 100 + 10,
        "telemetry_cpu": jax.random.uniform(ks[2], (n,), minval=0.5, maxval=1.0),
        "telemetry_mem": jax.random.uniform(ks[3], (n,), minval=0.5, maxval=1.0),
        "telemetry_batt": jax.random.uniform(ks[4], (n,), minval=0.5, maxval=1.0),
        "telemetry_energy": jax.random.uniform(ks[5], (n,), minval=0.55, maxval=1.0),
        "hist": jnp.abs(jax.random.normal(ks[6], (n, fl.hist_bins))) + 1.0,
    }


def _run_rounds(fl, attack=AttackConfig(), rounds=6, model=None):
    model = model or build_model(TINY)
    state = init_fl_state(model, fl, KEY)
    fn = jax.jit(make_round_fn(model, fl, attack=attack,
                               flops_per_client_round=1e9))
    key = KEY
    hist = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, m = fn(state, _mk_batch(k, fl))
        hist.append({k2: float(v) for k2, v in m.items()})
    return state, hist


def test_round_metrics_structure_and_warmup():
    fl = FLConfig(num_clients=12, slots=4, local_steps=2, inner_lr=0.05)
    _, hist = _run_rounds(fl)
    assert hist[0]["cold_starts"] > 0  # first round: everyone cold
    assert hist[-1]["cold_starts"] <= hist[0]["cold_starts"]
    assert hist[-1]["round_latency_ms"] <= hist[0]["round_latency_ms"]
    for h in hist:
        assert np.isfinite(h["loss"])
        assert 0 <= h["slot_participation"] <= 4


def test_microbatch_equivalence():
    """Gradient accumulation must not change the learning trajectory."""
    fl1 = FLConfig(num_clients=8, slots=2, inner_lr=0.05, microbatch=1)
    fl2 = dataclasses.replace(fl1, microbatch=4)
    model = build_model(TINY)
    s1 = init_fl_state(model, fl1, KEY)
    s2 = init_fl_state(model, fl2, KEY)
    b = _mk_batch(KEY, fl1)
    s1, m1 = jax.jit(make_round_fn(model, fl1))(s1, b)
    s2, m2 = jax.jit(make_round_fn(model, fl2))(s2, b)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), atol=2e-2
        )


def test_policies_run():
    for policy in ("fedfog", "rcs", "fogfaas"):
        fl = FLConfig(num_clients=8, slots=4, policy=policy)
        _, hist = _run_rounds(fl, rounds=2)
        assert np.isfinite(hist[-1]["loss"])


def test_aggregators_run():
    for agg in ("fedavg", "median", "trimmed"):
        fl = FLConfig(num_clients=8, slots=4, aggregator=agg)
        _, hist = _run_rounds(fl, rounds=2)
        assert np.isfinite(hist[-1]["loss"])


def test_pod_round_pallas_agg_matches_reference():
    """use_pallas_agg fuses Eq. 6 + server apply over the (C, P) buffer;
    the resulting params must match the reference fedavg_stacked +
    _server_update path to the params dtype's precision (bf16 → 1 ulp)."""
    model = build_model(TINY)
    outs = {}
    for pallas in (False, True):
        fl = FLConfig(
            num_clients=8, slots=4, server_optimizer="fedavg",
            use_pallas_agg=pallas,
        )
        state = init_fl_state(model, fl, KEY)
        fn = jax.jit(make_round_fn(model, fl, flops_per_client_round=1e9))
        state, metrics = fn(state, _mk_batch(KEY, fl))
        outs[pallas] = (state, metrics)
    ref_leaves = jax.tree.leaves(outs[False][0].params)
    pal_leaves = jax.tree.leaves(outs[True][0].params)
    for a, b in zip(ref_leaves, pal_leaves):
        assert a.dtype == b.dtype
        tol = 1e-3 if a.dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=tol
        )
    assert int(outs[True][0].server_count) == int(outs[False][0].server_count)
    np.testing.assert_allclose(
        float(outs[True][1]["loss"]), float(outs[False][1]["loss"]),
        rtol=1e-6,
    )


def test_dp_and_compression_run():
    fl = FLConfig(
        num_clients=8, slots=4, clip_norm=1.0, dp_sigma=0.01, compression="int8"
    )
    _, hist = _run_rounds(fl, rounds=2)
    assert np.isfinite(hist[-1]["loss"])


def test_attacks_run_and_dropout_reduces_participation():
    fl = FLConfig(num_clients=8, slots=4, scheduler=SchedulerConfig(theta_d=10.0))
    _, clean = _run_rounds(fl, rounds=3)
    _, dropped = _run_rounds(
        fl, attack=AttackConfig(kind="dropout", fraction=0.5), rounds=3
    )
    assert (
        sum(h["slot_participation"] for h in dropped)
        <= sum(h["slot_participation"] for h in clean)
    )


# --------------------------------------------------------------------- #
# Paper-faithful simulator
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def sim_history():
    sim = FedFogSimulator(
        SimulatorConfig(task="emnist", num_clients=24, rounds=12, top_k=10, seed=1)
    )
    return sim.run()


def test_simulator_converges(sim_history):
    h = sim_history
    assert h["accuracy"][-1] > 0.5
    assert h["accuracy"][-1] > h["accuracy"][0]


def test_simulator_warm_containers_cut_latency(sim_history):
    h = sim_history
    assert h["cold_starts"][1] > 0
    assert min(h["cold_starts"][3:]) < h["cold_starts"][1]


def test_fedfog_beats_fogfaas_on_latency_and_energy():
    common = dict(task="emnist", num_clients=24, rounds=8, top_k=10, seed=2)
    fed = FedFogSimulator(SimulatorConfig(policy="fedfog", **common)).run()
    fog = FedFogSimulator(SimulatorConfig(policy="fogfaas", **common)).run()
    assert fed["mean_latency_ms"] < fog["mean_latency_ms"]
    assert fed["total_energy_j"] < fog["total_energy_j"]


def test_har_task_runs():
    h = FedFogSimulator(
        SimulatorConfig(task="har", num_clients=16, rounds=6, top_k=8, seed=3)
    ).run()
    assert h["accuracy"][-1] > 0.3
