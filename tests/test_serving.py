"""Continuous-batching serving engine: exactness, kernel oracle, invariants.

Contracts (docs/EXPERIMENTS.md §Serving):
  (a) with ``attn="dense"`` the slot-scheduled engine reproduces the
      sequential per-request oracle TOKEN-FOR-TOKEN on every non-MoE
      family (MoE routing is batch-coupled, so batching legitimately
      changes expert assignment — exempt by design);
  (b) the Pallas paged flash-decode kernel matches the dense-gather
      reference (same ``attention_decode`` the oracle runs) across GQA
      widths, sliding windows, ragged lengths and empty slots;
  (c) slot conservation: arrived == completed + rejected + in-flight +
      waiting at all times, pages return to the free list;
  (d) ONE decode executable serves everything — ``n_compiles`` is frozen
      at construction and stays put as slots churn and rates sweep.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.models import build_model
from repro.serve import (
    ContinuousBatchingEngine,
    EngineConfig,
    PageAllocator,
    SequentialOracle,
    TraceConfig,
    make_trace,
    sweep_rates,
)

KEY = jax.random.PRNGKey(11)


def _build(arch):
    cfg = get_reduced(arch, loss_chunk=0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _small(cfg, **kw):
    base = dict(
        n_requests=8, rate_per_s=400.0, slo_ms=8000.0, prompt_len=8,
        min_gen=1, max_gen=6,
    )
    base.update(kw)
    return make_trace(jax.random.PRNGKey(3), TraceConfig(**base), cfg)


ECFG = EngineConfig(
    slots=3, page_size=4, prompt_len=8, max_gen=6, max_requests=16
)


# --------------------------------------------------------------------- #
# (a) continuous == sequential per-request oracle, token-for-token
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "arch",
    ["llama3.2-1b", "hymba-1.5b", "rwkv6-1.6b", "internvl2-2b"],
)
def test_continuous_matches_oracle_exactly(arch):
    cfg, model, params = _build(arch)
    trace = _small(cfg)
    ref = SequentialOracle(model, params, ECFG).serve(trace)
    rep = ContinuousBatchingEngine(model, params, ECFG).serve(trace)
    assert rep.completed == trace.n_requests == ref.completed
    for req in range(trace.n_requests):
        assert rep.tokens_for(req) == ref.tokens_for(req), (arch, req)
    # Batching must never hurt virtual-time throughput vs one-at-a-time.
    assert rep.virtual_ms <= ref.virtual_ms + 1e-6
    assert np.isfinite(rep.latency_ms).all()


def test_gen_len_one_finishes_at_prefill():
    """Requests whose whole budget is the prefill token still complete,
    still match the oracle, and never occupy a decode slot."""
    cfg, model, params = _build("llama3.2-1b")
    trace = _small(cfg, min_gen=1, max_gen=1)
    ref = SequentialOracle(model, params, ECFG).serve(trace)
    rep = ContinuousBatchingEngine(model, params, ECFG).serve(trace)
    assert rep.completed == trace.n_requests
    assert rep.decode_steps == 0
    for req in range(trace.n_requests):
        assert rep.tokens_for(req) == ref.tokens_for(req)


# --------------------------------------------------------------------- #
# (b) paged kernel vs dense-gather reference
# --------------------------------------------------------------------- #
PAGED_CASES = [
    # (slots, hkv, group, hd, page, pages_per_slot, window)
    (4, 2, 1, 64, 8, 3, -1),
    (4, 2, 4, 64, 8, 3, -1),  # GQA
    (3, 1, 2, 128, 16, 2, -1),  # wide head
    (4, 2, 2, 64, 8, 4, 12),  # sliding window
    (5, 2, 2, 64, 4, 5, 6),  # window < page span
]


@pytest.mark.parametrize("case", PAGED_CASES, ids=str)
def test_paged_kernel_matches_dense_ref(case):
    s, hkv, g, hd, page, n, window = case
    num_pages = s * n + 1
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (s, hkv * g, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (num_pages, page, hkv, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (num_pages, page, hkv, hd), jnp.float32)
    table = (
        jax.random.permutation(ks[3], num_pages - 1)[: s * n] + 1
    ).reshape(s, n).astype(jnp.int32)
    # Ragged: every fill level from 1 token up to the full span, plus one
    # empty (evicted) slot that must come back as exact zeros.
    lengths = jnp.linspace(1, n * page, s).round().astype(jnp.int32)
    lengths = lengths.at[s // 2].set(0)
    out = paged_attention(
        q, k_pages, v_pages, table, lengths, window, interpret=True
    )
    ref = paged_attention_ref(q, k_pages, v_pages, table, lengths, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    assert not np.asarray(out[s // 2]).any()


def test_paged_kernel_ignores_dead_pages():
    """Entries past ``lengths`` — stale data from an evicted request —
    must not leak into the output (continuous batching reuses pages
    without zeroing them)."""
    s, hkv, g, hd, page, n = 2, 2, 2, 64, 8, 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (s, hkv * g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (s * n + 1, page, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (s * n + 1, page, hkv, hd), jnp.float32)
    table = jnp.arange(1, s * n + 1, dtype=jnp.int32).reshape(s, n)
    lengths = jnp.array([5, page * n], jnp.int32)
    out = paged_attention(q, k, v, table, lengths, interpret=True)
    # Scribble over every position at/after each slot's length.
    mask = jnp.arange(page * n).reshape(n, page)[None] >= lengths[:, None, None]
    k2 = k.at[table].set(jnp.where(mask[..., None, None], 1e4, k[table]))
    v2 = v.at[table].set(jnp.where(mask[..., None, None], -1e4, v[table]))
    out2 = paged_attention(q, k2, v2, table, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_paged_engine_completes_trace():
    cfg, model, params = _build("llama3.2-1b")
    trace = _small(cfg)
    rep = ContinuousBatchingEngine(
        model, params, dataclasses.replace(ECFG, attn="paged")
    ).serve(trace)
    assert rep.completed == trace.n_requests
    assert rep.counters["arrived"] == rep.completed + rep.rejected
    toks = rep.tokens[: trace.n_requests]
    assert ((toks >= 0) & (toks < cfg.vocab_size)).all()


# --------------------------------------------------------------------- #
# (c) slot conservation + admission control
# --------------------------------------------------------------------- #
def test_slot_conservation_under_rejection():
    cfg, model, params = _build("llama3.2-1b")
    # Burst 12 arrivals into 2 slots with a 1-deep waiting queue: the
    # scheduler must reject the overflow, and every arrival must be
    # accounted for exactly once.
    ecfg = dataclasses.replace(ECFG, slots=2, max_queue=1, policy="edf")
    trace = _small(cfg, n_requests=12, rate_per_s=5000.0)
    rep = ContinuousBatchingEngine(model, params, ecfg).serve(trace)
    assert rep.rejected > 0
    c = rep.counters  # conservation() already asserted inside serve()
    assert c["arrived"] == trace.n_requests
    assert c["arrived"] == rep.completed + rep.rejected
    # Completed requests still carry oracle-exact tokens.
    ref = SequentialOracle(model, params, ecfg).serve(trace)
    done = np.nonzero(~np.isnan(rep.latency_ms))[0]
    assert done.size == rep.completed
    for req in done:
        assert rep.tokens_for(int(req)) == ref.tokens_for(int(req))


def test_page_allocator_roundtrip():
    alloc = PageAllocator(6)
    a = alloc.alloc(4)
    assert a is not None and len(set(a)) == 4 and 0 not in a
    assert alloc.alloc(3) is None  # only 2 left — all-or-nothing
    b = alloc.alloc(2)
    assert b is not None and not (set(a) & set(b))
    alloc.free(a)
    alloc.free(b)
    assert alloc.alloc(6) is not None  # everything came back


# --------------------------------------------------------------------- #
# (d) one-executable contract
# --------------------------------------------------------------------- #
def test_one_decode_executable_across_traces():
    cfg, model, params = _build("llama3.2-1b")
    eng = ContinuousBatchingEngine(model, params, ECFG)
    assert eng.n_compiles == {"admit": 1, "decode": 1}
    for seed in (3, 4):
        trace = make_trace(
            jax.random.PRNGKey(seed),
            TraceConfig(n_requests=6, rate_per_s=300.0, prompt_len=8,
                        min_gen=1, max_gen=6, slo_ms=8000.0),
            cfg,
        )
        rep = eng.serve(trace)
        assert rep.completed == 6
    # Slots churned through two traces on the same two executables.
    assert eng.n_compiles == {"admit": 1, "decode": 1}


def test_sweep_rates_compile_once():
    cfg, model, params = _build("llama3.2-1b")
    eng = ContinuousBatchingEngine(model, params, ECFG)
    res = sweep_rates(
        eng,
        TraceConfig(n_requests=6, prompt_len=8, min_gen=1, max_gen=6,
                    slo_ms=8000.0),
        rates_per_s=[20.0, 2000.0],
    )
    assert eng.n_compiles == {"admit": 1, "decode": 1}
    p95 = res.column("percentiles")  # -> the p95 column
    assert len(p95) == 2 and all(np.isfinite(p95))
    # Saturating arrivals can only raise queueing latency.
    assert p95[1] >= p95[0]


def test_encdec_family_rejected():
    cfg, model, params = _build("seamless-m4t-medium")
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(model, params, ECFG)
