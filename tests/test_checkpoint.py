"""Fault-tolerance substrate: atomic save, journaled resume, async writer."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.fl import FLConfig, init_fl_state
from repro.models import Family, ModelConfig, build_model

TINY = ModelConfig(
    name="tiny", family=Family.DENSE, num_layers=1, d_model=32, num_heads=2,
    num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64, remat=False,
    loss_chunk=0,
)


def _state():
    model = build_model(TINY)
    return init_fl_state(model, FLConfig(num_clients=4, slots=2), jax.random.PRNGKey(0))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    ckpt.save(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_bf16_leaves_roundtrip(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5, "s": jnp.int32(3)}
    ckpt.save(str(tmp_path), 1, tree)
    out = ckpt.restore(str(tmp_path), 1, tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"], np.float32), np.asarray(tree["w"], np.float32)
    )


def test_latest_step_ignores_incomplete(tmp_path):
    state = _state()
    ckpt.save(str(tmp_path), 3, state)
    # simulate a crash mid-save: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_async_checkpointer_gc(tmp_path):
    state = _state()
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ac.save(step, state)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    steps = sorted(os.listdir(tmp_path))
    assert "step_00000001" not in steps and len([s for s in steps if s.startswith("step_")]) == 2


def test_resume_continues_training(tmp_path):
    """Kill-and-restart: restored state continues bit-identically."""
    from repro.fl import make_round_fn

    model = build_model(TINY)
    fl = FLConfig(num_clients=4, slots=2)
    fn = jax.jit(make_round_fn(model, fl))
    key = jax.random.PRNGKey(1)

    def batch(k):
        ks = jax.random.split(k, 7)
        return {
            "tokens": jax.random.randint(ks[0], (4, 17), 0, 64),
            "slot_data_sizes": jnp.ones((2,)) * 10,
            "telemetry_cpu": jnp.full((4,), 0.9),
            "telemetry_mem": jnp.full((4,), 0.9),
            "telemetry_batt": jnp.full((4,), 0.9),
            "telemetry_energy": jnp.full((4,), 0.9),
            "hist": jnp.ones((4, fl.hist_bins)),
        }

    s = init_fl_state(model, fl, jax.random.PRNGKey(0))
    s, _ = fn(s, batch(key))
    ckpt.save(str(tmp_path), 1, s)
    s_next, _ = fn(s, batch(key))  # original continues

    restored = ckpt.restore(str(tmp_path), 1, s)
    s_resumed, _ = fn(restored, batch(key))
    for a, b in zip(jax.tree.leaves(s_next.params), jax.tree.leaves(s_resumed.params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
