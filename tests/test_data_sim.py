"""Data pipeline + DES simulator invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.drift import kl_divergence
from repro.data import emnist_like, har_like
from repro.data.synthetic import (
    FedDataConfig,
    all_client_histograms,
    client_histogram,
    client_tokens,
)
from repro.data.telemetry import (
    TelemetryConfig,
    init_telemetry,
    make_profiles,
    step_telemetry,
)
from repro.sim.faas import FaasSimConfig, round_energy_j, round_times_ms

KEY = jax.random.PRNGKey(0)


def test_tokens_deterministic_per_client_round():
    cfg = FedDataConfig(vocab_size=128)
    a = client_tokens(cfg, jnp.int32(3), jnp.int32(5), KEY, 4, 16)
    b = client_tokens(cfg, jnp.int32(3), jnp.int32(5), KEY, 4, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = client_tokens(cfg, jnp.int32(4), jnp.int32(5), KEY, 4, 16)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < 128).all()


def test_clients_are_non_iid():
    cfg = FedDataConfig(vocab_size=128, dirichlet_alpha=0.3)
    h = all_client_histograms(cfg, 8, jnp.int32(0), bins=16)
    kls = [
        float(kl_divergence(h[i], h[j]))
        for i in range(8)
        for j in range(i + 1, 8)
    ]
    assert max(kls) > 0.05  # distinct client distributions


def test_drift_moves_histograms_only_after_period():
    cfg = FedDataConfig(vocab_size=128, drift_period=10, drift_fraction=1.0)
    h0 = client_histogram(cfg, jnp.int32(2), jnp.int32(0), 16)
    h5 = client_histogram(cfg, jnp.int32(2), jnp.int32(5), 16)
    h15 = client_histogram(cfg, jnp.int32(2), jnp.int32(15), 16)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h5), atol=1e-6)
    assert float(kl_divergence(h15, h0)) > 1e-3


def test_emnist_like_batches():
    cfg = emnist_like.EmnistLikeConfig()
    x, y = emnist_like.client_batch(cfg, jnp.int32(0), jnp.int32(0), KEY, 8)
    assert x.shape == (8, 784) and y.shape == (8,)
    assert (np.asarray(y) >= 0).all() and (np.asarray(y) < 62).all()
    prior = emnist_like.client_histogram(cfg, jnp.int32(0), jnp.int32(0))
    np.testing.assert_allclose(float(prior.sum()), 1.0, rtol=1e-5)


def test_har_like_batches():
    cfg = har_like.HarLikeConfig()
    x, y = har_like.client_batch(cfg, jnp.int32(1), jnp.int32(0), KEY, 4)
    assert x.shape == (4, har_like.WINDOW * har_like.CHANNELS)
    assert (np.asarray(y) < har_like.NUM_CLASSES).all()


def test_telemetry_bounds_and_drain():
    cfg = TelemetryConfig(num_clients=16)
    tel = init_telemetry(cfg)
    prof = make_profiles(cfg)
    participated = jnp.arange(16) < 8
    tel2 = step_telemetry(cfg, tel, participated, jnp.zeros(16), prof, KEY)
    for f in (tel2.cpu, tel2.mem, tel2.batt):
        arr = np.asarray(f)
        assert (arr >= 0).all() and (arr <= 1).all()
    # participants drain, idlers recharge
    b1, b2 = np.asarray(tel.batt), np.asarray(tel2.batt)
    assert (b2[:8] <= b1[:8] + 1e-6).all()
    assert (b2[8:] >= b1[8:] - 1e-6).all()


def test_des_latency_structure():
    cfg = FaasSimConfig()
    tcfg = TelemetryConfig(num_clients=32)
    prof = make_profiles(tcfg)
    sel = jnp.ones(32, bool)
    cold = jnp.zeros(32, bool)
    warm = jnp.ones(32, bool)
    per_c, round_c, _ = round_times_ms(cfg, prof, sel, cold, 1e9, 1e6, 1e6)
    per_w, round_w, _ = round_times_ms(cfg, prof, sel, warm, 1e9, 1e6, 1e6)
    assert round_c > round_w  # cold starts dominate
    assert round_c >= np.asarray(per_c).max() - 1e-3  # straggler defines round


def test_fogfaas_orchestration_scales_quadratically():
    cfg = FaasSimConfig()
    orcs = {}
    for n in (16, 64, 256):
        tcfg = TelemetryConfig(num_clients=n)
        prof = make_profiles(tcfg)
        sel = jnp.ones(n, bool)
        warm = jnp.zeros(n, bool)
        _, _, orch_fed = round_times_ms(
            cfg, prof, sel, warm, 1e9, 1e6, 1e6, policy="fedfog"
        )
        _, _, orch_fog = round_times_ms(
            cfg, prof, sel, warm, 1e9, 1e6, 1e6, policy="fogfaas"
        )
        orcs[n] = (float(orch_fed), float(orch_fog))
    # FogFaaS grows ~quadratically, FedFog ~n·log n
    assert orcs[256][1] / orcs[64][1] > 8  # quadratic-ish
    assert orcs[256][0] / orcs[64][0] < 8  # sub-quadratic


def test_energy_cold_start_penalty():
    cfg = FaasSimConfig()
    tcfg = TelemetryConfig(num_clients=8)
    prof = make_profiles(tcfg)
    sel = jnp.ones(8, bool)
    e_cold = round_energy_j(cfg, prof, sel, jnp.zeros(8, bool), 1e9, 1e6)
    e_warm = round_energy_j(cfg, prof, sel, jnp.ones(8, bool), 1e9, 1e6)
    assert float(e_cold.sum()) > float(e_warm.sum())
