"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one prefill/decode step on CPU; asserts output shapes
and absence of NaNs. (Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.shapes import ShapeSpec, concrete_batch
from repro.models import build_model
from repro.models.config import Family

KEY = jax.random.PRNGKey(0)
SMOKE_SHAPE = ShapeSpec("smoke", "train", seq_len=24, global_batch=2)


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = concrete_batch(cfg, SMOKE_SHAPE, KEY)
    return arch, cfg, model, params, batch


def test_reduced_config_same_family(arch_setup):
    arch, cfg, *_ = arch_setup
    assert cfg.family == get_config(arch).family
    assert cfg.name.endswith("-reduced")


def test_train_loss_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # roughly ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)


def test_train_grads_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    g = jax.grad(lambda p: model.loss(p, batch))(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in flat), arch
    assert any(float(jnp.abs(l).max()) > 0 for l in flat), f"{arch}: all-zero grads"


def test_prefill_and_decode_shapes(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    b = SMOKE_SHAPE.global_batch
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :16]
    cache_len = 24
    if cfg.family is Family.VLM:
        cache_len += batch["patch_embeds"].shape[1]
    logits, cache = model.prefill(params, pre_batch, cache_len=cache_len)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    toks = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, toks)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_param_count_positive_and_active_bounded(arch_setup):
    arch, cfg, model, *_ = arch_setup
    n, na = model.param_count(), model.active_param_count()
    assert 0 < na <= n
    if cfg.num_experts:
        assert na < n  # MoE: active strictly smaller
