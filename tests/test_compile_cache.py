"""Persistent warm-start compile cache + benchmark-compare plumbing.

The contract of ``REPRO_COMPILE_CACHE_DIR``: a SECOND PROCESS running a
structurally identical sweep deserializes the first process's AOT
executables — zero traces, zero compiles (``n_compiles=0``,
``disk_hits>0``) — and reproduces its histories bit-for-bit.
"""
import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SWEEP_SCRIPT = """
import json, sys
import numpy as np
from repro.fl.simulator import SimulatorConfig
from repro.sim import run_sweep

cfg = SimulatorConfig(task="emnist", num_clients=4, rounds=2, top_k=2,
                      hidden=(8,), seed=0)
tm = {}
res = run_sweep(cfg, seeds=[0, 1], axes={"lr": [0.03, 0.05]}, timings=tm)
out = {k: tm[k] for k in ("n_compiles", "cache_hits", "disk_hits")}
out["accuracy"] = np.asarray(res.metric("accuracy")).tolist()
print("RESULT:" + json.dumps(out))
"""


def _run_sweep_process(cache_dir, engine_script=_SWEEP_SCRIPT):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["REPRO_COMPILE_CACHE_DIR"] = str(cache_dir)
    proc = subprocess.run(
        [sys.executable, "-c", engine_script], capture_output=True,
        text=True, env=env, cwd=REPO, timeout=900,
    )
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[-1][len("RESULT:"):])


def test_second_process_warm_starts_with_zero_compiles(tmp_path):
    cold = _run_sweep_process(tmp_path)
    assert cold["n_compiles"] == 1 and cold["disk_hits"] == 0
    assert any(f.endswith(".jaxexe") for f in os.listdir(tmp_path))
    warm = _run_sweep_process(tmp_path)
    assert warm["n_compiles"] == 0, warm
    assert warm["disk_hits"] == 1 and warm["cache_hits"] == 1, warm
    # replaying the serialized executable is exact
    np.testing.assert_array_equal(
        np.asarray(cold["accuracy"]), np.asarray(warm["accuracy"])
    )


def test_corrupt_disk_entry_falls_back_to_compile(tmp_path):
    cold = _run_sweep_process(tmp_path)
    assert cold["n_compiles"] == 1
    for f in os.listdir(tmp_path):
        if f.endswith(".jaxexe"):
            with open(os.path.join(tmp_path, f), "wb") as fh:
                fh.write(b"not an executable")
    recovered = _run_sweep_process(tmp_path)
    assert recovered["n_compiles"] == 1 and recovered["disk_hits"] == 0
    np.testing.assert_array_equal(
        np.asarray(cold["accuracy"]), np.asarray(recovered["accuracy"])
    )


# --------------------------------------------------------------------- #
# benchmarks/run.py --compare row tolerance (satellite)
# --------------------------------------------------------------------- #
def _compare(records, baseline, tmp_path, tolerance=25.0):
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import compare_to_baseline
    finally:
        sys.path.pop(0)
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"rows": baseline}))
    return compare_to_baseline(records, str(path), tolerance)


def test_compare_tolerates_missing_and_renamed_rows(tmp_path, capsys):
    baseline = [
        {"suite": "s", "name": "s/kept", "us_per_call": 100.0},
        {"suite": "s", "name": "s/renamed_away", "us_per_call": 50.0},
        {"suite": "other", "name": "other/not_run", "us_per_call": 10.0},
    ]
    records = [
        {"suite": "s", "name": "s/kept", "us_per_call": 110.0},
        {"suite": "s", "name": "s/brand_new", "us_per_call": 5.0},
    ]
    # renamed/missing baseline rows warn but do NOT count as regressions
    assert _compare(records, baseline, tmp_path) == 0
    out = capsys.readouterr().out
    assert "s/renamed_away" in out and "skipped" in out
    # rows from suites that were not part of this run are not flagged
    assert "other/not_run" not in out


def test_compare_still_fails_on_shared_row_regressions(tmp_path):
    baseline = [
        {"suite": "s", "name": "s/kept", "us_per_call": 100.0},
        {"suite": "s", "name": "s/renamed_away", "us_per_call": 50.0},
    ]
    records = [{"suite": "s", "name": "s/kept", "us_per_call": 200.0}]
    assert _compare(records, baseline, tmp_path) == 1
