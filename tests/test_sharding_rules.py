"""Host-side validation of the sharding rules for every (arch × mesh):
every parameter/batch/cache PartitionSpec must divide its dimension by the
product of the mesh axes it names. Catches divisibility regressions without
compiling anything."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, batch_specs, cache_specs
from repro.dist.meshes import plan_for
from repro.models import build_model

# We cannot build 256 fake devices inside the main test process (device
# count is locked at first jax use), so validate the PLAN arithmetic and
# spec/dimension divisibility against the abstract mesh shape instead.


def _mesh_shape(plan, multi_pod):
    shape = {}
    if multi_pod:
        shape["pod"] = 2
    shape["client"] = plan.num_clients // (2 if multi_pod else 1)
    shape["zero"] = plan.zero
    for name, size in zip(plan.model_axes, plan.model_split):
        shape[name] = size
    return {k: v for k, v in shape.items() if v > 1}


def _check_spec(spec: P, dims, mesh_shape, where):
    assert len(spec) <= len(dims), (where, spec, dims)
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a in mesh_shape, f"{where}: axis {a} missing from mesh"
            prod *= mesh_shape[a]
        assert dims[i] % prod == 0, (
            f"{where}: dim {dims[i]} not divisible by {prod} ({spec})"
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_and_cache_specs_divide(arch, multi_pod):
    from repro.dist.sharding import ShardingRules

    cfg = get_config(arch)
    plan = plan_for(cfg, multi_pod=multi_pod)
    mesh_shape = _mesh_shape(plan, multi_pod)

    class FakeMesh:
        shape = mesh_shape

    rules = ShardingRules.__new__(ShardingRules)
    object.__setattr__(rules, "cfg", cfg)
    object.__setattr__(rules, "plan", plan)
    object.__setattr__(rules, "mesh", FakeMesh())

    model = build_model(cfg)
    shapes, laxes = model.param_shapes(), model.param_axes()

    for stacked in (False, True):
        specs = rules.param_specs(shapes, laxes, stacked=stacked)
        flat_s, _ = jax.tree.flatten(shapes)
        flat_p, _ = jax.tree.flatten(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        for sds, spec in zip(flat_s, flat_p):
            dims = ((plan.num_clients,) if stacked else ()) + sds.shape
            _check_spec(spec, dims, mesh_shape, f"{arch} param stacked={stacked}")

    for shape_name, shape in SHAPES.items():
        bspecs = batch_specs(cfg, shape)
        for k, spec in rules.serve_batch_specs(bspecs).items():
            _check_spec(spec, bspecs[k].shape, mesh_shape, f"{arch} batch {k}")
        if shape.kind == "decode":
            cspecs = cache_specs(model, shape)
            flat_c, _ = jax.tree.flatten(cspecs)
            flat_cs, _ = jax.tree.flatten(
                rules.cache_specs(cspecs), is_leaf=lambda x: isinstance(x, P)
            )
            for sds, spec in zip(flat_c, flat_cs):
                _check_spec(
                    spec, sds.shape, mesh_shape, f"{arch} cache {shape_name}"
                )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_plan_arithmetic(arch):
    cfg = get_config(arch)
    for multi_pod in (False, True):
        plan = plan_for(cfg, multi_pod=multi_pod)
        data = 16 * (2 if multi_pod else 1)
        assert plan.num_clients * plan.zero == data
        assert plan.model_split[0] * plan.model_split[1] == 16
        if cfg.num_experts:
            assert cfg.num_experts % plan.model_split[0] == 0
        elif plan.model_split[0] > 1:
            assert cfg.num_heads % plan.model_split[0] == 0
