"""Fault-injection & recovery layer (repro.sim.faults).

Acceptance contracts (ISSUE 9):
  (a) faults-off bitwise identity: ``faults=None`` and an all-inert
      ``FaultConfig()`` produce byte-identical histories on every
      engine path — sync scanned, async (coalesced AND single-pop),
      grouped sweeps, and the shard_map selftest path;
  (b) with faults on, the counters conserve:
      dispatched == completed + failed-terminal + lost;
  (c) a fault-rate grid is a compile-once sweep (rates are lifted
      numerics; the fault gate is the only structural bit);
  (d) deterministic fault replay: seed s of a faulted sweep reproduces
      a standalone faulted run bitwise;
  (e) recovery semantics: retries scale with failure rate, backoff
      latency folds into §IV.F round totals, below-quorum rounds carry
      the model bitwise, fog failover reroutes instead of losing.
"""
import dataclasses

import numpy as np
import pytest

from _subproc import run_selftest_module
from repro.fl.simulator import FedFogSimulator, SimulatorConfig
from repro.sim import run_sweep
from repro.sim.events import AsyncConfig, AsyncFedFogSimulator
from repro.sim.faults import COUNTER_KEYS, FaultConfig
from repro.sim.faults.config import active, backoff_ms


def _cfg(**kw) -> SimulatorConfig:
    base = dict(
        task="emnist", num_clients=8, rounds=4, top_k=4, hidden=(16,), seed=0
    )
    base.update(kw)
    return SimulatorConfig(**base)


def _assert_histories_equal(a, b):
    assert set(a) == set(b)
    for name in a:
        np.testing.assert_array_equal(
            np.asarray(a[name]), np.asarray(b[name]), err_msg=name
        )


# --------------------------------------------------------------------- #
# (a) faults-off bitwise identity on every engine path
# --------------------------------------------------------------------- #
def test_inert_fault_config_is_inactive():
    assert not active(None)
    assert not active(FaultConfig())
    assert active(FaultConfig(crash_rate=0.1))
    assert active(FaultConfig(deadline_ms=500.0))


def test_faults_off_bitwise_sync_scanned():
    h_none = FedFogSimulator(_cfg(faults=None)).run_scanned()
    h_inert = FedFogSimulator(_cfg(faults=FaultConfig())).run_scanned()
    _assert_histories_equal(h_none, h_inert)


@pytest.mark.parametrize("coalesce", (True, False))
def test_faults_off_bitwise_async(coalesce):
    acfg = AsyncConfig(staleness_exponent=0.0, coalesce=coalesce)
    h_none = AsyncFedFogSimulator(_cfg(faults=None), acfg).run()
    h_inert = AsyncFedFogSimulator(_cfg(faults=FaultConfig()), acfg).run()
    _assert_histories_equal(h_none, h_inert)


def test_faults_off_bitwise_grouped_sweep():
    cases = [{"lr": 0.03}, {"lr": 0.07}]
    r_none = run_sweep(_cfg(rounds=3, faults=None), seeds=[0, 1], cases=cases)
    r_inert = run_sweep(
        _cfg(rounds=3, faults=FaultConfig()), seeds=[0, 1], cases=cases
    )
    for name in r_none.history:
        np.testing.assert_array_equal(
            r_none.history[name], r_inert.history[name], err_msg=name
        )


def test_faults_sharded_selftest():
    """shard_map path: faults-off bitwise vs today's sharded round, a
    faulted sharded 2-round run matches its single-host replay, and the
    counters conserve (subprocess: fake devices must precede jax init)."""
    res = run_selftest_module(
        "repro.dist.selftest", "--devices", "8", "--faults-check"
    )
    assert res["faults_bitwise_ok"], res
    assert res["faults_conserved"], res["faults_counters"]
    assert res["faults_equiv_diff"] < 1e-4, res
    assert res["ok"], res


# --------------------------------------------------------------------- #
# (b) counter conservation under live faults
# --------------------------------------------------------------------- #
def test_sync_counters_conserve_and_always_emitted():
    fc = FaultConfig(crash_rate=0.4, drop_rate=0.1, max_retries=2)
    h = FedFogSimulator(_cfg(rounds=5, faults=fc)).run_scanned()
    for k in COUNTER_KEYS:
        assert k in h, f"missing counter channel {k}"
    disp = np.asarray(h["fault_dispatched"])
    comp = np.asarray(h["fault_completed"])
    term = np.asarray(h["fault_terminal"])
    lost = np.asarray(h["fault_lost"])
    np.testing.assert_array_equal(disp, comp + term + lost)
    assert sum(h["fault_retries"]) > 0, "crash storm produced no retries?"
    # faults-off histories carry the same schema, as zeros
    h0 = FedFogSimulator(_cfg(rounds=2)).run_scanned()
    for k in COUNTER_KEYS:
        assert k in h0 and sum(h0[k]) == 0


def test_async_counters_conserve():
    fc = FaultConfig(crash_rate=0.4, max_retries=2)
    h = AsyncFedFogSimulator(
        _cfg(rounds=6, faults=fc), AsyncConfig(staleness_exponent=0.0)
    ).run()
    admitted = int(sum(h["dispatch_num_admitted"]))
    completed = int(h["num_completions"])
    assert admitted == (
        completed
        + h["fault_terminal"]
        + h["lost_inflight"]
        + h["fault_lost_deadline"]
    ), h
    assert h["fault_retries"] > 0


def test_async_deadline_loses_updates():
    fc = FaultConfig(deadline_ms=1.0)  # nothing can arrive in time
    h = AsyncFedFogSimulator(
        _cfg(rounds=4, faults=fc), AsyncConfig(staleness_exponent=0.0)
    ).run()
    assert h["fault_lost_deadline"] > 0
    admitted = int(sum(h["dispatch_num_admitted"]))
    completed = int(h["num_completions"])
    assert admitted == (
        completed
        + h["fault_terminal"]
        + h["lost_inflight"]
        + h["fault_lost_deadline"]
    ), h


# --------------------------------------------------------------------- #
# (c) fault-rate grids stay compile-once sweeps
# --------------------------------------------------------------------- #
def test_fault_rate_grid_single_compile():
    from repro.sim import clear_compile_cache

    cfg = _cfg(rounds=3)
    cases = [
        {"faults": FaultConfig(crash_rate=r, max_retries=1)}
        for r in (0.0, 0.3, 0.8)
    ]
    clear_compile_cache()
    tm: dict = {}
    r = run_sweep(cfg, seeds=[0], cases=cases, timings=tm)
    # One ACTIVE fault gate (crash_rate>0 on some point makes the plan
    # structural once; the rates themselves are lifted numerics). All
    # three grid points share one compiled program. NOTE: the r=0.0
    # point still runs the gated program — active() is decided per grid
    # point, and crash_rate=0.0 with max_retries=1 set keeps the gate
    # off, giving a second structural group.
    assert tm["n_compiles"] <= 2, tm
    retries = [
        float(np.asarray(r.history["fault_retries"])[i].sum()) for i in range(3)
    ]
    assert retries[0] == 0
    assert retries[1] <= retries[2] or retries[2] > 0


def test_active_fault_grid_is_one_program():
    from repro.sim import clear_compile_cache

    cfg = _cfg(rounds=3)
    cases = [
        {"faults": FaultConfig(crash_rate=r, max_retries=1)}
        for r in (0.1, 0.4, 0.9)
    ]
    clear_compile_cache()
    tm: dict = {}
    run_sweep(cfg, seeds=[0], cases=cases, timings=tm)
    assert tm["n_compiles"] == 1, tm


# --------------------------------------------------------------------- #
# (d) deterministic fault replay: sweep slice == standalone run
# --------------------------------------------------------------------- #
def test_faulted_sweep_slice_matches_standalone():
    fc = FaultConfig(crash_rate=0.5, corrupt_rate=0.3, max_retries=2)
    cfg = _cfg(rounds=3, faults=fc)
    r = run_sweep(cfg, seeds=[0, 1])
    solo = FedFogSimulator(dataclasses.replace(cfg, seed=1)).run_scanned()
    for name, vals in solo.items():
        if name not in r.history:
            continue
        np.testing.assert_array_equal(
            np.asarray(r.history[name])[0, 1],
            np.asarray(vals),
            err_msg=name,
        )


def test_faulted_run_is_seed_deterministic():
    fc = FaultConfig(crash_rate=0.5, max_retries=1)
    h1 = FedFogSimulator(_cfg(faults=fc)).run_scanned()
    h2 = FedFogSimulator(_cfg(faults=fc)).run_scanned()
    _assert_histories_equal(h1, h2)


# --------------------------------------------------------------------- #
# (e) recovery semantics
# --------------------------------------------------------------------- #
def test_retries_scale_with_crash_rate():
    totals = []
    for rate in (0.0, 0.5, 0.95):
        fc = FaultConfig(crash_rate=rate, max_retries=3)
        h = FedFogSimulator(_cfg(rounds=4, faults=fc)).run_scanned()
        totals.append(sum(h["fault_retries"]))
    assert totals[0] == 0
    assert totals[2] > totals[1] >= totals[0], totals


def test_backoff_latency_folds_into_round_totals():
    base = FedFogSimulator(_cfg(rounds=4)).run_scanned()
    fc = FaultConfig(
        crash_rate=0.9, max_retries=3,
        backoff_base_ms=5000.0, backoff_mult=2.0,
    )
    faulted = FedFogSimulator(_cfg(rounds=4, faults=fc)).run_scanned()
    assert sum(faulted["round_latency_ms"]) > sum(base["round_latency_ms"])
    # retried invocations repay energy too (attempt multiplier)
    assert sum(faulted["energy_j"]) > sum(base["energy_j"])


def test_backoff_schedule_is_exponential():
    fc = FaultConfig(max_retries=3, backoff_base_ms=100.0, backoff_mult=3.0)
    assert float(backoff_ms(fc, 1)) == 100.0
    assert float(backoff_ms(fc, 2)) == 300.0
    assert float(backoff_ms(fc, 3)) == 900.0


def test_quorum_skip_carries_model_bitwise():
    """Crash storm + quorum: every post-warm-up round misses quorum, so
    the model must carry over bitwise and be marked skipped."""
    fc = FaultConfig(crash_rate=1.0, quorum_frac=0.5)
    sim = FedFogSimulator(_cfg(rounds=3, faults=fc))
    init = [np.asarray(p) for p in np.asarray(sim.params[0]["w"]).ravel()[:64]]
    h = sim.run_scanned()
    after = [np.asarray(p) for p in np.asarray(sim.params[0]["w"]).ravel()[:64]]
    np.testing.assert_array_equal(init, after)
    # nothing ever arrives -> every dispatching round is skipped
    skipped = np.asarray(h["round_skipped"])
    disp = np.asarray(h["fault_dispatched"])
    np.testing.assert_array_equal(skipped, (disp > 0).astype(skipped.dtype))


def test_fog_failover_reroutes_instead_of_losing():
    kw = dict(rounds=4, fog_nodes=2)
    fc_lose = FaultConfig(fog_outage_rate=1.0)
    h_lose = FedFogSimulator(_cfg(faults=fc_lose, **kw)).run_scanned()
    assert sum(h_lose["fog_outages"]) > 0
    assert sum(h_lose["fault_lost"]) > 0, "outage without failover must lose"
    fc_safe = FaultConfig(fog_outage_rate=1.0, fog_failover=True)
    h_safe = FedFogSimulator(_cfg(faults=fc_safe, **kw)).run_scanned()
    assert sum(h_safe["fault_lost"]) == 0
    assert sum(h_safe["fault_failed_over"]) > 0
    # the detour is paid in latency
    assert sum(h_safe["round_latency_ms"]) > 0


def test_history_summary_totals_present():
    fc = FaultConfig(crash_rate=0.5, corrupt_rate=0.3, max_retries=2)
    h = FedFogSimulator(_cfg(rounds=4, faults=fc)).run_scanned()
    assert h["total_fault_retries"] == sum(h["fault_retries"])
    assert h["total_fault_corrupt"] == sum(h["fault_corrupt"])
    assert h["total_rounds_skipped"] == sum(h["round_skipped"])
