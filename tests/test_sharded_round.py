"""Fake-device integration test: one FedFog round on an 8-device host
mesh (client=4 × zero=2) must reproduce the single-device round within
float tolerance, and its compiled body must contain exactly ONE
inter-client all-reduce carrying the delta payload (the paper's §III
communication contract; see PAPER.md).

Runs ``repro.dist.selftest`` in a SUBPROCESS because the fake-device
count must be fixed before jax initializes — this test process has
already locked its backend to one device.
"""
from _subproc import run_selftest_module


def _run_selftest(*extra):
    return run_selftest_module("repro.dist.selftest", *extra)


def test_sharded_round_equivalence_and_one_all_reduce():
    res = _run_selftest("--devices", "8")
    assert res["plan"]["num_clients"] == 4 and res["plan"]["zero"] == 2
    # The paper's contract: ONE inter-client all-reduce per round.
    assert res["inter_client_all_reduces"] == 1
    # Sharded and single-device rounds agree on metrics AND params.
    assert res["equivalence_ok"], res
    assert res["max_param_diff"] < 1e-4, res
    for k, v in res["metric_diffs"].items():
        assert v < 1e-2, (k, v)
    assert res["ok"]


def _check_pallas(res):
    assert res["pallas_agg"] is True
    assert res["contract_error"] is None, res
    # Routing through the sharded shard_map kernel entry keeps the
    # paper's ONE inter-client all-reduce contract.
    assert res["inter_client_all_reduces"] == 1
    assert res["equivalence_ok"], res
    # Three-way agreement: sharded kernel == single-device kernel
    # (max_param_diff) == reference aggregation (max_param_diff_ref).
    assert res["max_param_diff"] < 1e-4, res
    assert res["max_param_diff_ref"] < 1e-4, res
    assert res["ok"], res


def test_sharded_round_pallas_agg_plain():
    """Plain FedAvg round routes through delta_pipeline_apply_sharded
    under mesh rules and matches both the unsharded kernel and the
    reference round."""
    _check_pallas(
        _run_selftest("--devices", "8", "--pallas-agg", "--gates", "plain")
    )


def test_sharded_round_pallas_agg_full_gates():
    """DP + momentum + compression + clipping round through the sharded
    kernel: still one all-reduce, still matches the reference."""
    _check_pallas(
        _run_selftest("--devices", "8", "--pallas-agg", "--gates", "full")
    )
