"""Serving-path correctness: prefill + step-by-step decode must reproduce
the full-sequence forward logits, for every family (incl. SWA windows,
hybrid SSM state carry-over, RWKV recurrence, enc-dec cross-attention and
VLM embedding prefixes). fp32 configs so tolerances are tight."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Family, GLOBAL, ModelConfig, build_model
from repro.models import encdec, rwkv6, transformer

KEY = jax.random.PRNGKey(1)
B, S = 2, 12

COMMON = dict(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, loss_chunk=0,
    param_dtype="float32", compute_dtype="float32",
)

CASES = {
    "dense-bias": ModelConfig(name="t", family=Family.DENSE, qkv_bias=True, **COMMON),
    "swa-interleave": ModelConfig(
        name="s", family=Family.DENSE, window_pattern=(4, GLOBAL), **COMMON
    ),
    "gemma-style": ModelConfig(
        name="g", family=Family.DENSE, window_pattern=(4, 4, GLOBAL),
        qk_norm=True, scale_embeddings=True, tie_embeddings=True,
        logit_softcap=30.0, act="gelu", **COMMON
    ),
    "moe": ModelConfig(
        name="m", family=Family.MOE, num_experts=4, experts_per_token=2,
        moe_capacity_factor=4.0, **{**COMMON, "d_ff": 64}
    ),
    "hybrid": ModelConfig(
        name="h", family=Family.HYBRID, ssm_state=8, ssm_dt_rank=8,
        window_pattern=(GLOBAL, 4), **COMMON
    ),
    "rwkv6": ModelConfig(
        name="r", family=Family.SSM,
        **{**COMMON, "d_model": 128, "num_heads": 0, "num_kv_heads": 0, "head_dim": 0}
    ),
    "encdec": ModelConfig(
        name="e", family=Family.ENCDEC, num_encoder_layers=2,
        **{**COMMON, "num_kv_heads": 4}
    ),
    "vlm": ModelConfig(name="v", family=Family.VLM, **COMMON),
}


def full_logits(cfg, params, batch):
    if cfg.family is Family.ENCDEC:
        enc_h = encdec.encode(params, cfg, batch["frames"])
        h = encdec.decode_train(params, cfg, batch["tokens"], enc_h)
        return (h @ params["lm_head"]).astype(jnp.float32)
    if cfg.family is Family.SSM:
        h = rwkv6.forward_hidden(params, cfg, tokens=batch["tokens"])
        return rwkv6._head_logits(params, cfg, h)
    kw = {"embeds": batch["patch_embeds"]} if cfg.family is Family.VLM else {}
    h = transformer.forward_hidden(params, cfg, tokens=batch["tokens"], **kw)
    return transformer._head_logits(params, cfg, h)


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_full_forward(name):
    cfg = CASES[name]
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    offset = 0
    if cfg.family is Family.VLM:
        batch["patch_embeds"] = jax.random.normal(KEY, (B, 4, cfg.d_model))
        offset = 4
    if cfg.family is Family.ENCDEC:
        batch["frames"] = jax.random.normal(KEY, (B, 8, cfg.d_model))

    ref = np.asarray(full_logits(cfg, params, batch))

    split = S - 4
    pre = dict(batch)
    pre["tokens"] = toks[:, :split]
    logits, cache = model.prefill(params, pre, cache_len=S + offset)
    errs = [
        np.abs(np.asarray(logits[:, -1]) - ref[:, offset + split - 1]).max()
    ]
    for i in range(split, S):
        logits, cache = model.decode_step(params, cache, toks[:, i : i + 1])
        errs.append(np.abs(np.asarray(logits[:, 0]) - ref[:, offset + i]).max())
    assert max(errs) < 2e-4, f"{name}: decode divergence {max(errs):.2e}"


def test_scan_vs_unrolled_layers_identical():
    cfg = CASES["swa-interleave"]
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    h_scan = transformer.forward_hidden(params, cfg, tokens=toks)
    import dataclasses

    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    h_unroll = transformer.forward_hidden(params, cfg2, tokens=toks)
    np.testing.assert_allclose(
        np.asarray(h_scan), np.asarray(h_unroll), atol=1e-5
    )


def test_scan_block_remat_matches_flat():
    import dataclasses

    cfg = dataclasses.replace(CASES["dense-bias"], remat=True, num_layers=4)
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    l_flat = model.loss(params, {"tokens": toks})
    cfg_b = dataclasses.replace(cfg, scan_block=2)
    l_block = build_model(cfg_b).loss(params, {"tokens": toks})
    np.testing.assert_allclose(float(l_flat), float(l_block), rtol=1e-5)
    g1 = jax.grad(lambda p: build_model(cfg).loss(p, {"tokens": toks}))(params)
    g2 = jax.grad(lambda p: build_model(cfg_b).loss(p, {"tokens": toks}))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_chunked_loss_matches_unchunked():
    import dataclasses

    cfg = CASES["dense-bias"]
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, 17), 0, cfg.vocab_size)
    l0 = model.loss(params, {"tokens": toks})
    cfg_c = dataclasses.replace(cfg, loss_chunk=4)
    l1 = build_model(cfg_c).loss(params, {"tokens": toks})
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
